"""Table 3+4 analogue: resource/energy accounting of the compiled pipelines.

Power cannot be measured in this container; we report the paper's static
power MODEL (CPU 150W / PipeRec 17W+~8W dynamic) applied to measured wall
time as an energy PROXY, clearly labeled, plus the Table-4-style resource
summary (VMEM/HBM table placement, fused-stage count) from the planner."""

from __future__ import annotations

from benchmarks.common import block, emit, timeit
from repro.core.pipeline import paper_pipeline
from repro.data import synth

ROWS = 50_000
POWER_MODEL_W = {"numpy": 150.0 + 144.0, "jnp": 150.0 + 60.0,
                 "pallas": 17.0 + 8.0}  # paper Table 3 static+dynamic classes


def main():
    raw = next(synth.dataset_batches("I", rows=ROWS, batch_size=ROWS))
    for which in ["I", "II", "III"]:
        for backend in ["numpy", "jnp"]:
            p = paper_pipeline(which, small_vocab=8192,
                               large_vocab=524288).compile(backend=backend)
            p.fit(synth.dataset_batches("I", rows=20_000, batch_size=10_000))
            t = timeit(lambda: block(p(raw)), iters=2)
            joules = t * POWER_MODEL_W[backend]
            emit(f"table3/P-{which}/{backend}", t,
                 f"energy_proxy={joules:.1f}J@{POWER_MODEL_W[backend]:.0f}W")
        rs = paper_pipeline(which, small_vocab=8192,
                            large_vocab=524288).compile("jnp").resource_summary()
        emit(f"table4/P-{which}/resources", 0.0,
             f"stages={rs['n_stages']}|vmem_tables={rs['vmem_table_bytes']}"
             f"|hbm_tables={rs['hbm_table_bytes']}"
             f"|flops_per_row={rs['flops_per_row']:.0f}")


if __name__ == "__main__":
    main()