"""Fig 12 analogue: single-feature single-thread pipeline decomposition.

LoadOnly / Stateless / VocabGen / VocabMap per feature type, numpy path
(the paper's single-CPU-thread measurement)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timeit
from repro.core import operators as O
from repro.data import synth

ROWS = 200_000


def main(rows: int = ROWS):
    rng = np.random.default_rng(1)
    dense = rng.lognormal(1.0, 2.0, rows).astype(np.float32)
    ids = synth._zipf_ids(rng, rows, 1 << 22)
    hexs = synth._hex_encode(ids, 8).reshape(rows, 1, 8)

    emit("fig12/Dense/LoadOnly", timeit(lambda: dense.copy()),
         f"{rows/1e6:.1f}Mrows")
    emit("fig12/Sparse/LoadOnly", timeit(lambda: hexs.copy()),
         f"{rows/1e6:.1f}Mrows")

    clamp, log = O.Clamp(0.0), O.Logarithm()
    emit("fig12/Dense/Stateless",
         timeit(lambda: log.numpy(clamp.numpy(dense))), "Clamp+Log")
    h2i, mod = O.Hex2Int(8), O.Modulus(8192)
    sparse_stateless = lambda: mod.numpy(h2i.numpy(hexs))
    emit("fig12/Sparse/Stateless", timeit(sparse_stateless), "Hex2Int+Mod")

    bounded = sparse_stateless().reshape(-1)
    for cap, tag in [(8192, "Small"), (524288, "Large")]:
        vals = (bounded % cap).astype(np.int32)
        vg = O.VocabGen(cap)
        emit(f"fig12/{tag}/VocabGen",
             timeit(lambda: vg.finalize(vg.update(vg.init_state(), vals, 0)),
                    iters=2), f"cap={cap}")
        table = vg.finalize(vg.update(vg.init_state(), vals, 0))
        vm = O.VocabMap(cap)
        emit(f"fig12/{tag}/VocabMap",
             timeit(lambda: vm.numpy_apply(vals, table)), f"cap={cap}")


if __name__ == "__main__":
    main()