"""Fig 17 analogue: concurrent pipeline scaling (1/2/4/7 tenants)."""

from __future__ import annotations

from benchmarks.common import emit
from repro.core.pipeline import paper_pipeline
from repro.data.source import Source
from repro.etl_runtime.multitenant import PipelineManager

BATCH = 8192
N_BATCHES = 4


def main():
    for n in [1, 2, 4, 7]:
        mgr = PipelineManager()
        for i in range(n):
            pipe = paper_pipeline("I", modulus=65536,
                                  batch_size=BATCH).compile(backend="jnp")
            mgr.add(f"p{i}", pipe,
                    Source.synth("I", rows=N_BATCHES * BATCH,
                                 batch_size=BATCH, seed=i))
        res = mgr.run(n_batches=N_BATCHES)
        total_rows = sum(r.rows for r in res.values())
        wall = max(r.seconds for r in res.values())
        emit(f"fig17/{n}_pipelines", wall,
             f"{total_rows / wall / 1e6:.2f}Mrows_s_aggregate")


if __name__ == "__main__":
    main()