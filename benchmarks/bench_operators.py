"""Table 2 analogue: per-operator runtime across implementations.

Paper: CPU vs RTX3090 vs A100 vs PipeRec per operator on Dataset I (45M rows).
Here: numpy-CPU baseline vs XLA-jit vs fused-Pallas on a scaled Dataset-I
column; derived column reports Mrows/s so numbers are scale-free.  The
Pallas row runs in the backend-resolved mode (compiled on TPU/GPU,
interpret on CPU — ``kernels.backend.default_interpret``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import operators as O
from repro.data import synth
from repro.kernels import ops as kops, ref as kref

ROWS = 200_000


def main(rows: int = ROWS):
    rng = np.random.default_rng(0)
    dense = (rng.lognormal(1.0, 2.0, rows).astype(np.float32)
             * np.where(rng.random(rows) < 0.15, -1, 1))
    ids = synth._zipf_ids(rng, rows, 1 << 22)
    hexs = synth._hex_encode(ids, 8).reshape(rows, 1, 8)
    hex_dm = np.ascontiguousarray(np.moveaxis(hexs, -1, 0))  # digit-major
    ints = rng.integers(0, 512 * 1024, rows).astype(np.int32)

    cases = [
        ("Clamp", O.Clamp(0.0), dense.reshape(rows, 1)),
        ("Logarithm", O.Logarithm(), np.abs(dense).reshape(rows, 1)),
        ("Hex2Int", O.Hex2Int(8), hexs),
        ("Modulus", O.Modulus(512 * 1024), ints.reshape(rows, 1)),
        ("SigridHash", O.SigridHash(512 * 1024), ints.reshape(rows, 1)),
        ("Bucketize", O.Bucketize([1.0, 10.0, 100.0]), dense.reshape(rows, 1)),
    ]
    for name, op, x in cases:
        t_np = timeit(lambda: op.numpy(x))
        jx = jnp.asarray(x)
        jit_fn = jax.jit(op.jnp_expr)
        t_jit = timeit(lambda: jit_fn(jx).block_until_ready())
        emit(f"table2/{name}/numpy", t_np, f"{rows / t_np / 1e6:.1f}Mrows_s")
        emit(f"table2/{name}/xla", t_jit, f"{rows / t_jit / 1e6:.1f}Mrows_s")

    # fused pallas stage (Hex2Int|Modulus — the sparse hot path)
    mod = O.Modulus(512 * 1024)
    chain = lambda v: mod.jnp_expr(kref.hex2int_digit_major(v))
    fn = kops.fused_stage(chain, in_dtype=np.uint8, out_dtype=np.int32,
                          hex_width=8)
    jhex = jnp.asarray(hex_dm)
    t = timeit(lambda: fn(jhex).block_until_ready(), iters=2)
    emit("table2/Hex2Int+Modulus/pallas_fused", t,
         f"{rows / t / 1e6:.2f}Mrows_s")

    # VocabGen / VocabMap (8K and 512K — paper's two table sizes)
    for cap, tag in [(8192, "8K"), (524288, "512K")]:
        vals = (ids % cap).astype(np.int32)
        vg = O.VocabGen(cap)
        t_gen_np = timeit(lambda: vg.finalize(
            vg.update(vg.init_state(), vals, 0)), iters=2)
        emit(f"table2/VocabGen-{tag}/numpy", t_gen_np,
             f"{rows / t_gen_np / 1e6:.2f}Mrows_s")
        jv = jnp.asarray(vals)
        build = jax.jit(lambda v: kref.vocab_finalize(kref.vocab_merge(
            kref.vocab_state_init(cap), kref.vocab_build_chunk(v, cap), 0)))
        t_gen = timeit(lambda: build(jv).block_until_ready(), iters=2)
        emit(f"table2/VocabGen-{tag}/xla", t_gen,
             f"{rows / t_gen / 1e6:.2f}Mrows_s")

        table = vg.finalize(vg.update(vg.init_state(), vals, 0))
        vm = O.VocabMap(cap)
        x2 = vals.reshape(rows, 1)
        t_map_np = timeit(lambda: vm.numpy_apply(x2, table))
        emit(f"table2/VocabMap-{tag}/numpy", t_map_np,
             f"{rows / t_map_np / 1e6:.2f}Mrows_s")
        jt, jx2 = jnp.asarray(table), jnp.asarray(x2)
        n = O.VocabGen.n_unique(table)
        lk = jax.jit(lambda x, t: kref.vocab_lookup(x, t, n))
        t_map = timeit(lambda: lk(jx2, jt).block_until_ready())
        emit(f"table2/VocabMap-{tag}/xla", t_map,
             f"{rows / t_map / 1e6:.2f}Mrows_s")


if __name__ == "__main__":
    main()