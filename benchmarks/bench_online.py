"""Online-training service: sustained throughput and freshness under load.

The measurement for the continuous-training subsystem (``repro/online``):
a producer replays a synthetic Criteo-like event stream onto the bus at a
multiple of the trainer's sustainable rate, and the ``OnlineTrainer``
consumes it while refitting the vocabulary incrementally every
``refit_every`` steps and shedding globally-oldest events to hold a
freshness bound.

Each cell sweeps producer pressure (rate multiplier x shed bound) over a
fixed wall-clock window and reports:

- ``steps_per_s``   : sustained train-step rate under that pressure.
- ``swaps``         : incremental vocab refits applied (each an atomic
  ``PipelineState`` swap with a version bump).
- ``p95_ms``        : delivered event-age p95 vs the configured bound —
  the freshness acceptance surface (``p95 <= bound`` when shedding).
- ``shed``          : events dropped oldest-first by the global shedder.

``--json [PATH]`` writes the machine-readable trajectory (default
``BENCH_8.json`` at the repo root), every record stamped with the git
SHA; ``--smoke`` runs the single bursty acceptance cell (nightly CI).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import threading
import time

from benchmarks.common import emit, git_sha
from repro.launch.online import build_parser, build_service

# (rate_mult, shed_bound_s) cells: calm, saturated, bursty-with-shedding
CELLS = [(0.5, 0.0), (1.5, 0.0), (2.0, 0.5), (3.0, 0.25)]
SMOKE = [(2.0, 0.5)]


def run_cell(mult: float, bound_s: float, duration: float,
             backend: str) -> dict:
    argv = ["--duration", str(duration), "--batch", "128",
            "--vocab", "2048", "--d-emb", "16", "--rate", "30",
            "--rate-mult", str(mult), "--refit-every", "10",
            "--shed-max-staleness", str(bound_s), "--log-every", "0",
            "--etl-backend", backend]
    args = build_parser().parse_args(argv)
    trainer, bus, producer = build_service(args)
    t = threading.Thread(target=producer, name="bench-producer")
    t0 = time.perf_counter()
    t.start()
    trainer.run(deadline_s=duration + 5.0)
    t.join()
    wall = time.perf_counter() - t0
    pct = trainer.staleness_percentiles()
    rec = {
        "rate_mult": mult,
        "shed_bound_s": bound_s,
        "wall_s": round(wall, 2),
        "steps": trainer.stats.steps,
        "steps_per_s": round(trainer.stats.steps / max(wall, 1e-9), 2),
        "swaps": trainer.stats.swaps,
        "refit_batches": trainer.stats.refit_batches,
        "p50_ms": round(pct["p50"] * 1e3, 1),
        "p95_ms": round(pct["p95"] * 1e3, 1),
        "p99_ms": round(pct["p99"] * 1e3, 1),
        "shed": trainer.shed_stats().dropped,
        "bus": bus.counts(),
    }
    emit(f"online[x{mult},bound={bound_s}]", wall,
         f"{rec['steps_per_s']}steps/s swaps={rec['swaps']} "
         f"p95={rec['p95_ms']}ms shed={rec['shed']}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=10.0,
                    help="wall-clock per cell (s)")
    ap.add_argument("--etl-backend", default="numpy",
                    choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--smoke", action="store_true",
                    help="run only the bursty acceptance cell (nightly CI)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write machine-readable results to PATH "
                         "(default: BENCH_8.json at the repo root)")
    args = ap.parse_args(argv)

    cells = SMOKE if args.smoke else CELLS
    records = [run_cell(m, b, args.duration, args.etl_backend)
               for m, b in cells]

    for r in records:
        if r["shed_bound_s"] > 0:
            ok = r["p95_ms"] <= r["shed_bound_s"] * 1e3
            print(f"# freshness x{r['rate_mult']}: p95 {r['p95_ms']}ms "
                  f"vs bound {r['shed_bound_s']*1e3:.0f}ms -> "
                  f"{'OK' if ok else 'OVER'}")

    if args.json is not None:
        path = pathlib.Path(args.json) if args.json else (
            pathlib.Path(__file__).resolve().parent.parent / "BENCH_8.json")
        path.write_text(json.dumps({
            "bench": "online", "git_sha": git_sha(),
            "backend": args.etl_backend, "duration_s": args.duration,
            "records": records}, indent=2))
        print(f"# wrote {path}")
    return records


if __name__ == "__main__":
    main()
