"""Fig 13/15/16 analogue: Pipeline I/II/III latency across implementations
and datasets (scaled; derived column = Mrows/s and MB/s, scale-free)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import block, emit, timeit
from repro.core.pipeline import paper_pipeline
from repro.data import synth

ROWS = {"I": 100_000, "II": 20_000}  # II is ~6x wider per row


def bytes_per_row(which: str) -> int:
    schema = synth.dataset_schema(which)
    return sum(f.raw_dtype().itemsize * (f.hex_width or 1) for f in schema)


def main():
    for ds in ["I", "II"]:
        rows = ROWS[ds]
        raw = next(synth.dataset_batches(ds, rows=rows, batch_size=rows))
        fit = lambda: synth.dataset_batches(ds, rows=20_000, batch_size=10_000)
        bpr = bytes_per_row(ds)
        for which in ["I", "II", "III"]:
            for backend in ["numpy", "jnp", "pallas"]:
                if backend == "pallas" and ds == "II":
                    continue  # interpret-mode cost not informative at width 504
                p = paper_pipeline(which, schema=synth.dataset_schema(ds),
                                   small_vocab=8192, large_vocab=524288,
                                   modulus=65536).compile(backend=backend)
                p.fit(fit())
                t = timeit(lambda: block(p(raw)), warmup=1, iters=2)
                emit(f"fig13_15_16/D-{ds}+P-{which}/{backend}", t,
                     f"{rows / t / 1e6:.2f}Mrows_s|{rows * bpr / t / 1e6:.0f}MB_s")


if __name__ == "__main__":
    main()