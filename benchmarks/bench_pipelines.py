"""Fig 13/15/16 analogue: Pipeline I/II/III latency across implementations
and datasets (scaled; derived column = Mrows/s and MB/s, scale-free).

The pallas rows walk the lowering ladder introduced by the relational
optimizer: ``pallas_grouped`` is the optimized path (``optimize="auto"`` —
CSE + multi-output DataflowGroups, one ``pallas_call`` per group),
``pallas_fused`` disables the optimizer but keeps per-output fused
dataflows (one kernel per PackOutput, the pre-optimizer default), and
``pallas_staged`` forces the stage-at-a-time lowering (``fuse="off"``, the
NVTabular-style baseline).  ``grouped_vs_fused`` / ``grouped_vs_staged`` /
``fused_vs_staged`` rows report the speedups so each rung's win is
measurable on the Criteo-shaped workloads.

The vocab pipelines (II/III) additionally emit ``fit_*`` rows timing the
fit phase end to end (projected read through the prefetching read stage +
chunk build + merge/finalize) and a ``fit_fused_vs_staged`` ratio — the
fused per-vocab fit kernel vs the stage-at-a-time build.

The paper pipelines' outputs share no stages, so ``grouped_vs_fused`` is
~1.0 there (grouping only saves per-kernel dispatch); the
``shared-prefix`` scenario rows measure the optimizer on the workload it
exists for — N outputs re-deriving the same decode/bound/vocab chains —
where CSE + one grouped kernel beats N fused kernels ~Nx.

``--json [PATH]`` additionally writes the machine-readable perf trajectory
(default ``BENCH_6.json`` at the repo root) that the nightly CI job
regenerates as an artifact; reviewers diff it to catch lowering
regressions that the CSV stdout stream makes easy to miss.  Every record
is stamped with the resolved interpret mode, and ``--baseline PATH``
compares the fresh run against a previous trajectory — REFUSING the
comparison when the two were measured in different interpret modes
(compiled-vs-interpret deltas are lowering differences, not regressions).
"""

from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import block, emit, git_sha, timeit
from repro.core.pipeline import paper_pipeline
from repro.data import synth
from repro.data.source import Source
from repro.session import EtlJob

ROWS = {"I": 100_000, "II": 20_000, "III": 100_000}  # II is ~6x wider

VARIANTS = [  # (row label, EtlJob compile knobs)
    ("numpy", dict(backend="numpy")),
    ("jnp", dict(backend="jnp")),
    ("pallas_grouped", dict(backend="pallas", fuse="auto", optimize="auto")),
    ("pallas_fused", dict(backend="pallas", fuse="auto", optimize="off")),
    ("pallas_staged", dict(backend="pallas", fuse="off", optimize="off")),
]

SPEEDUPS = [  # (row label, numerator variant, denominator variant)
    ("grouped_vs_fused", "pallas_fused", "pallas_grouped"),
    ("grouped_vs_staged", "pallas_staged", "pallas_grouped"),
    ("fused_vs_staged", "pallas_staged", "pallas_fused"),
]

FIT_ROWS = 20_000


def bytes_per_row(which: str) -> int:
    schema = synth.dataset_schema(which)
    return sum(f.raw_dtype().itemsize * (f.hex_width or 1) for f in schema)


def shared_prefix_pipeline(n_outputs: int = 3):
    """n outputs each re-deriving the SAME dense chain and the SAME
    sparse decode+bound+vocab chain from fresh source nodes — the
    duplication the relational optimizer exists to recover."""
    import numpy as np

    from repro.core import operators as O
    from repro.core.pipeline import Pipeline, Vocab
    from repro.core.schema import Schema

    p = Pipeline(Schema.criteo_kaggle())
    for i in range(n_outputs):
        d = (p.dense("dense_*") | O.FillMissing(0.0) | O.Clamp(0.0, 50.0)
             | O.Logarithm())
        s = (p.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(8192)
             | Vocab(8192))
        p.output(f"out{i}", [d, s], dtype=np.float32)
    return p


def run_shared_prefix(records, rows: int = 100_000) -> None:
    """The optimizer's headline scenario: CSE folds the duplicated chains
    and grouping lowers all outputs to ONE kernel (vs n fused kernels
    re-executing every copy with ``optimize="off"``)."""
    raw = next(iter(Source.synth("I", rows=rows, batch_size=rows)))
    times = {}
    for label, knobs in VARIANTS:
        job = EtlJob(shared_prefix_pipeline(),
                     fit_source=Source.synth("I", rows=FIT_ROWS,
                                             batch_size=FIT_ROWS // 2),
                     **knobs)
        job.fit()
        t = timeit(lambda: block(job.apply(raw)), warmup=1, iters=2)
        times[label] = t
        emit(f"fig13_15_16/shared-prefix/{label}", t,
             f"{rows / t / 1e6:.2f}Mrows_s")
        records.append(dict(dataset="I", pipeline="shared-prefix",
                            variant=label, seconds=t,
                            mrows_per_s=rows / t / 1e6))
    for label, num, den in SPEEDUPS:
        ratio = times[num] / times[den]
        print(f"fig13_15_16/shared-prefix/{label},"
              f"{ratio:.2f},{ratio:.2f}x_{label}", flush=True)
        records.append(dict(dataset="I", pipeline="shared-prefix",
                            variant=label, speedup=ratio))


def run(datasets=("I", "II", "III")) -> list[dict]:
    """Run the matrix, emit CSV rows, and return JSON-ready records."""
    records = []

    def record(ds, which, label, **kw):
        records.append(dict(dataset=ds, pipeline=which, variant=label, **kw))

    for ds in datasets:
        rows = ROWS[ds]
        raw = next(iter(Source.synth(ds, rows=rows, batch_size=rows)))
        bpr = bytes_per_row(ds)
        for which in ["I", "II", "III"]:
            times = {}
            fit_times = {}
            for label, knobs in VARIANTS:
                job = EtlJob(
                    paper_pipeline(which, schema=synth.dataset_schema(ds),
                                   small_vocab=8192, large_vocab=524288,
                                   modulus=65536),
                    fit_source=Source.synth(ds, rows=FIT_ROWS,
                                            batch_size=FIT_ROWS // 2),
                    **knobs)
                job.fit()
                if which != "I" and knobs["backend"] == "pallas":
                    # fit phase (vocab pipelines): prefetched read + chunk
                    # build + merge/finalize; the first fit above was warmup
                    tf = timeit(lambda: job.fit(), warmup=0, iters=2)
                    fit_times[label] = tf
                    emit(f"fig13_15_16/D-{ds}+P-{which}/fit_{label}", tf,
                         f"{FIT_ROWS / tf / 1e6:.2f}Mrows_s")
                    record(ds, which, f"fit_{label}", seconds=tf,
                           mrows_per_s=FIT_ROWS / tf / 1e6)
                t = timeit(lambda: block(job.apply(raw)), warmup=1, iters=2)
                times[label] = t
                emit(f"fig13_15_16/D-{ds}+P-{which}/{label}", t,
                     f"{rows / t / 1e6:.2f}Mrows_s|{rows * bpr / t / 1e6:.0f}MB_s")
                record(ds, which, label, seconds=t,
                       mrows_per_s=rows / t / 1e6,
                       mb_per_s=rows * bpr / t / 1e6)
            for label, num, den in SPEEDUPS:
                if num not in times or den not in times:
                    continue
                # value column IS the ratio here (not microseconds)
                ratio = times[num] / times[den]
                print(f"fig13_15_16/D-{ds}+P-{which}/{label},"
                      f"{ratio:.2f},{ratio:.2f}x_{label}", flush=True)
                record(ds, which, label, speedup=ratio)
            if "pallas_fused" in fit_times and "pallas_staged" in fit_times:
                ratio = fit_times["pallas_staged"] / fit_times["pallas_fused"]
                print(f"fig13_15_16/D-{ds}+P-{which}/fit_fused_vs_staged,"
                      f"{ratio:.2f},{ratio:.2f}x_staged_over_fused",
                      flush=True)
                record(ds, which, "fit_fused_vs_staged", speedup=ratio)
    run_shared_prefix(records)
    return records


def compare_to_baseline(fresh: dict, baseline: dict,
                        *, tolerance: float = 0.30) -> list[str]:
    """Speedup-row regressions of ``fresh`` against ``baseline``.

    Raises ``SystemExit`` when the trajectories were measured in different
    interpret modes: a compiled-vs-interpret delta is a *lowering*
    difference, not a perf regression, and comparing across modes would
    bury real regressions under it (or invent phantom ones).
    """
    fm, bm = fresh.get("interpret"), baseline.get("interpret")
    if fm != bm:
        raise SystemExit(
            f"refusing cross-interpret-mode comparison: fresh run is "
            f"interpret={fm}, baseline is interpret={bm}; regenerate the "
            "baseline on this backend first")
    def speedups(doc):
        return {(r["dataset"], r["pipeline"], r["variant"]): r["speedup"]
                for r in doc.get("records", []) if "speedup" in r}
    fresh_s, base_s = speedups(fresh), speedups(baseline)
    regressions = []
    for key, base_v in sorted(base_s.items()):
        fresh_v = fresh_s.get(key)
        if fresh_v is not None and fresh_v < base_v * (1 - tolerance):
            regressions.append(
                f"{'/'.join(key)}: {fresh_v:.2f}x vs baseline "
                f"{base_v:.2f}x")
    return regressions


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also write the machine-readable trajectory "
                         "(default: BENCH_6.json at the repo root)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="compare speedup rows against a previous --json "
                         "trajectory; exits non-zero on regression and "
                         "refuses cross-interpret-mode comparisons")
    ap.add_argument("--datasets", default="I,II,III",
                    help="comma-separated dataset subset (default: I,II,III)")
    args = ap.parse_args(argv)
    records = run(tuple(args.datasets.split(",")))
    if args.json is None and args.baseline is None:
        return
    from repro.kernels.ops import default_interpret
    sha, interpret = git_sha(), default_interpret()
    # every record is self-describing: trajectory diffs stay attributable
    # even when records are merged across runs/commits
    for r in records:
        r["git_sha"] = sha
        r["interpret"] = interpret
    doc = {
        "bench": "fig13_15_16",
        "git_sha": sha,
        "interpret": interpret,
        "rows": ROWS,
        "fit_rows": FIT_ROWS,
        "records": records,
    }
    if args.json is not None:
        path = pathlib.Path(args.json) if args.json else (
            pathlib.Path(__file__).resolve().parent.parent / "BENCH_6.json")
        path.write_text(json.dumps(doc, indent=2) + "\n")
        print(f"wrote {path}", flush=True)
    if args.baseline is not None:
        baseline = json.loads(pathlib.Path(args.baseline).read_text())
        regressions = compare_to_baseline(doc, baseline)
        if regressions:
            for line in regressions:
                print(f"REGRESSION {line}", flush=True)
            raise SystemExit(1)
        print(f"no regressions vs {args.baseline} "
              f"(interpret={interpret})", flush=True)


if __name__ == "__main__":
    main()
