"""Fig 13/15/16 analogue: Pipeline I/II/III latency across implementations
and datasets (scaled; derived column = Mrows/s and MB/s, scale-free).

The ``pallas`` rows use the fused per-output streaming dataflow lowering
(one kernel per PackOutput); ``pallas_staged`` forces the stage-at-a-time
lowering (``fuse="off"``, the NVTabular-style baseline), and a
``fused_vs_staged`` row reports the speedup so the plan-level-fusion win is
measurable on the Criteo-shaped workload (dataset I).

The vocab pipelines (II/III) additionally emit ``fit_*`` rows timing the
fit phase end to end (projected read through the prefetching read stage +
chunk build + merge/finalize) and a ``fit_fused_vs_staged`` ratio — the
fused per-vocab fit kernel vs the stage-at-a-time build.
"""

from __future__ import annotations

from benchmarks.common import block, emit, timeit
from repro.core.pipeline import paper_pipeline
from repro.data import synth
from repro.data.source import Source
from repro.session import EtlJob

ROWS = {"I": 100_000, "II": 20_000}  # II is ~6x wider per row

VARIANTS = [  # (row label, backend, fuse mode)
    ("numpy", "numpy", "auto"),
    ("jnp", "jnp", "auto"),
    ("pallas", "pallas", "auto"),
    ("pallas_staged", "pallas", "off"),
]


def bytes_per_row(which: str) -> int:
    schema = synth.dataset_schema(which)
    return sum(f.raw_dtype().itemsize * (f.hex_width or 1) for f in schema)


def main():
    for ds in ["I", "II"]:
        rows = ROWS[ds]
        raw = next(iter(Source.synth(ds, rows=rows, batch_size=rows)))
        bpr = bytes_per_row(ds)
        for which in ["I", "II", "III"]:
            times = {}
            fit_times = {}
            for label, backend, fuse in VARIANTS:
                if backend == "pallas" and ds == "II":
                    continue  # interpret-mode cost not informative at width 504
                job = EtlJob(
                    paper_pipeline(which, schema=synth.dataset_schema(ds),
                                   small_vocab=8192, large_vocab=524288,
                                   modulus=65536),
                    backend=backend, fuse=fuse,
                    fit_source=Source.synth(ds, rows=20_000,
                                            batch_size=10_000))
                job.fit()
                if which != "I" and backend == "pallas":
                    # fit phase (vocab pipelines): prefetched read + chunk
                    # build + merge/finalize; the first fit above was warmup
                    tf = timeit(lambda: job.fit(), warmup=0, iters=2)
                    fit_times[label] = tf
                    emit(f"fig13_15_16/D-{ds}+P-{which}/fit_{label}", tf,
                         f"{20_000 / tf / 1e6:.2f}Mrows_s")
                t = timeit(lambda: block(job.apply(raw)), warmup=1, iters=2)
                times[label] = t
                emit(f"fig13_15_16/D-{ds}+P-{which}/{label}", t,
                     f"{rows / t / 1e6:.2f}Mrows_s|{rows * bpr / t / 1e6:.0f}MB_s")
            if "pallas" in times and "pallas_staged" in times:
                # value column IS the ratio here (not microseconds): the
                # acceptance criterion "fused >= staged" tracks this number
                ratio = times["pallas_staged"] / times["pallas"]
                print(f"fig13_15_16/D-{ds}+P-{which}/fused_vs_staged,"
                      f"{ratio:.2f},{ratio:.2f}x_staged_over_fused",
                      flush=True)
            if "pallas" in fit_times and "pallas_staged" in fit_times:
                ratio = fit_times["pallas_staged"] / fit_times["pallas"]
                print(f"fig13_15_16/D-{ds}+P-{which}/fit_fused_vs_staged,"
                      f"{ratio:.2f},{ratio:.2f}x_staged_over_fused",
                      flush=True)


if __name__ == "__main__":
    main()
