"""Roofline report: renders experiments/dryrun/*.json into the EXPERIMENTS.md
§Roofline table (per arch x shape x mesh: three terms, dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs ratio, improvement note).

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

NOTES = {
    ("compute",): "raise MXU occupancy: larger per-chip tiles / fewer remat "
                  "recomputes",
    ("memory",): "cut HBM traffic: bf16 intermediates, fuse elementwise "
                 "chains, avoid materializing expanded tensors",
    ("collective",): "cut wire bytes: shard-local dispatch, overlap "
                     "collectives with compute, compress payloads",
}


def load(dir_: str):
    recs = []
    for f in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_row(r) -> str:
    if not r.get("ok"):
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | "
                f"{r.get('error', '?')[:60]} | | | | |")
    rf = r["roofline"]
    t = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_wire_s"])
    # roofline fraction: ideal (compute-only) time / bound time
    frac = rf["t_compute_s"] / t if t > 0 else 0.0
    mem_gib = r["memory"]["per_device_bytes"] / 2 ** 30
    ratio = r.get("hlo_vs_model_flops") or 0.0
    return ("| {arch} | {shape} | {mesh} | {c:.4g} | {m:.4g} | {w:.4g} | "
            "{dom} | {frac:.0%} | {ratio:.2f} | {mem:.1f} |").format(
        arch=r["arch"], shape=r["shape"],
        mesh="x".join(str(x) for x in r["mesh"]),
        c=rf["t_compute_s"], m=rf["t_memory_s"], w=rf["t_wire_s"],
        dom=rf["dominant"], frac=frac, ratio=ratio, mem=mem_gib)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--markdown", action="store_true", default=True)
    args = ap.parse_args()
    recs = load(args.dir)
    print("| arch | shape | mesh | t_compute(s) | t_memory(s) | t_wire(s) | "
          "dominant | roofline-frac | HLO/model flops | mem GiB/dev |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(recs, key=lambda x: (x["arch"], x["shape"],
                                         str(x["mesh"]))):
        print(fmt_row(r))
    ok = [r for r in recs if r.get("ok")]
    print(f"\n{len(ok)}/{len(recs)} cells OK")
    # worst offenders for the perf loop
    def frac(r):
        rf = r["roofline"]
        t = max(rf["t_compute_s"], rf["t_memory_s"], rf["t_wire_s"])
        return rf["t_compute_s"] / t if t else 0
    worst = sorted(ok, key=frac)[:3]
    print("\nworst roofline fractions (hillclimb candidates):")
    for r in worst:
        print(f"  {r['cell']}: frac={frac(r):.1%} "
              f"dominant={r['roofline']['dominant']}")


if __name__ == "__main__":
    main()