"""Fig 11 analogue: data-movement micro-benchmark.

Paper: host<->FPGA DMA, FPGA->GPU P2P, RDMA throughput/latency vs size.
Here: host->device transfer (jax.device_put) and device-resident handoff
(the zero-copy donation path) vs message size."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timeit


def main():
    for size in [1 << 12, 1 << 16, 1 << 20, 1 << 24, 1 << 26]:
        host = np.random.default_rng(0).integers(
            0, 255, size // 4, dtype=np.int32)
        t = timeit(lambda: jax.device_put(host).block_until_ready(), iters=5)
        emit(f"fig11/host_to_device/{size}B", t,
             f"{size / t / 2**30:.2f}GiB_s")
        dev = jax.device_put(host)
        # device-resident handoff: donated elementwise touch (zero-copy path)
        f = jax.jit(lambda x: x + 1, donate_argnums=0)
        t2 = timeit(lambda: f(jax.device_put(host)).block_until_ready(),
                    iters=5)
        emit(f"fig11/donated_step/{size}B", t2,
             f"{size / t2 / 2**30:.2f}GiB_s")
        del dev


if __name__ == "__main__":
    main()