"""Fig 14 analogue: trainer utilization — blocking CPU-style feed vs the
staged prefetching executor (same ETL, same trainer) — plus the Fig-8-style
per-stage occupancy breakdown from the executor's stage stats.

Emits:
  fig14/blocking, fig14/overlapped           (jnp device ETL)
  fig14/cpu_fed_blocking, fig14/cpu_fed_overlapped  (numpy host ETL — the
      paper's headline regime: slow CPU ETL hidden behind the train step)
  fig8/<stage>                                per-stage breakdown
  fig14/utilization_gain                      overlapped - blocking (pp)
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import TrainConfig
from repro.core.pipeline import paper_pipeline
from repro.data import synth
from repro.etl_runtime.runtime import StreamingExecutor
from repro.models import dlrm
from repro.training.train_loop import TrainState, make_train_step

N_BATCHES = 12
BATCH = 4096


def _make_step(cfg, tcfg):
    return jax.jit(make_train_step(lambda p, b: dlrm.loss_fn(p, b, cfg),
                                   tcfg), donate_argnums=0)


def _fresh_pipe(backend):
    pipe = paper_pipeline("II", small_vocab=8192,
                          batch_size=BATCH).compile(backend=backend)
    pipe.fit(synth.dataset_batches("I", rows=8192, batch_size=8192))
    return pipe


def _materialize(batch):
    return {k: np.asarray(v) for k, v in batch.items()}


def run_blocking(pipe, step, state, *, host_etl):
    """ETL inline on the critical path (the paper's CPU-GPU mode)."""
    t0 = time.perf_counter()
    train_s = 0.0
    for raw in synth.dataset_batches("I", rows=N_BATCHES * BATCH,
                                     batch_size=BATCH, seed=2):
        batch = pipe(raw)
        if host_etl:
            batch = _materialize(batch)
        ts = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        train_s += time.perf_counter() - ts
    total = time.perf_counter() - t0
    return train_s / total, total


def run_overlapped(pipe, step, state):
    """Staged prefetching executor: ETL stages overlap the train step."""
    ex = StreamingExecutor(pipe, synth.dataset_batches(
        "I", rows=N_BATCHES * BATCH, batch_size=BATCH, seed=2), credits=2)
    t0 = time.perf_counter()
    train_s = 0.0
    for batch in ex:
        ts = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        train_s += time.perf_counter() - ts
    total = time.perf_counter() - t0
    return train_s / total, total, ex.stats


def main():
    cfg = dlrm.DLRMConfig(vocab_size=8193, d_emb=32, bot_mlp=(128, 64, 32),
                          top_mlp=(128, 64, 1))
    tcfg = TrainConfig(lr=1e-3)
    step = _make_step(cfg, tcfg)

    def fresh_state():
        return TrainState.create(dlrm.init(jax.random.key(0), cfg), tcfg)

    # device (jnp) ETL: async dispatch already hides most of it
    util_block, total_block = run_blocking(_fresh_pipe("jnp"), step,
                                           fresh_state(), host_etl=True)
    emit("fig14/blocking", total_block, f"util={util_block:.2%}")
    util_ov, total_ov, _ = run_overlapped(_fresh_pipe("jnp"), step,
                                          fresh_state())
    emit("fig14/overlapped", total_ov,
         f"util={util_ov:.2%}|speedup={total_block / total_ov:.2f}x")

    # the paper's Fig-1/14 regime: slow host (numpy) ETL on the critical
    # path vs the same producer overlapped — the utilization gap is the
    # headline effect
    cpu_block, cpu_block_total = run_blocking(_fresh_pipe("numpy"), step,
                                              fresh_state(), host_etl=False)
    emit("fig14/cpu_fed_blocking", cpu_block_total,
         f"util={cpu_block:.2%}")
    cpu_ov, cpu_ov_total, stats = run_overlapped(_fresh_pipe("numpy"), step,
                                                 fresh_state())
    emit("fig14/cpu_fed_overlapped", cpu_ov_total,
         f"util={cpu_ov:.2%}|speedup={cpu_block_total / cpu_ov_total:.2f}x")

    # Fig-8-style per-stage breakdown of the overlapped CPU-fed run
    for name, s in stats.stage_breakdown().items():
        emit(f"fig8/{name}", s["busy_s"],
             f"items={s['items']}|wait_in={s['wait_in_s']:.3f}s"
             f"|wait_out={s['wait_out_s']:.3f}s|occ={s['occupancy']:.1%}")
    emit("fig8/overlapped_etl", stats.overlapped_etl_s,
         f"etl_hidden_behind_training={stats.overlapped_etl_s:.3f}s")

    gain_pp = (cpu_ov - cpu_block) * 100
    emit("fig14/utilization_gain", cpu_ov_total,
         f"overlap_gain={gain_pp:.1f}pp")
    assert cpu_ov > cpu_block, (
        f"overlap must beat blocking: {cpu_ov:.2%} vs {cpu_block:.2%}")


if __name__ == "__main__":
    main()
