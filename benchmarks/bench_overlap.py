"""Fig 14 analogue: trainer utilization — blocking CPU-style feed vs the
PipeRec double-buffered overlapped feed (same ETL, same trainer)."""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.configs.base import TrainConfig
from repro.core.pipeline import paper_pipeline
from repro.data import synth
from repro.etl_runtime.runtime import StreamingExecutor
from repro.models import dlrm
from repro.training.train_loop import TrainState, make_train_step

N_BATCHES = 16
BATCH = 4096


def main():
    cfg = dlrm.DLRMConfig(vocab_size=8193, d_emb=32, bot_mlp=(128, 64, 32),
                          top_mlp=(128, 64, 1))
    tcfg = TrainConfig(lr=1e-3)
    step = jax.jit(make_train_step(lambda p, b: dlrm.loss_fn(p, b, cfg),
                                   tcfg), donate_argnums=0)

    def fresh():
        pipe = paper_pipeline("II", small_vocab=8192,
                              batch_size=BATCH).compile(backend="jnp")
        pipe.fit(synth.dataset_batches("I", rows=8192, batch_size=8192))
        state = TrainState.create(dlrm.init(jax.random.key(0), cfg), tcfg)
        return pipe, state

    # blocking: ETL inline on the critical path (the paper's CPU-GPU mode)
    pipe, state = fresh()
    t0 = time.perf_counter()
    train_s = 0.0
    for raw in synth.dataset_batches("I", rows=N_BATCHES * BATCH,
                                     batch_size=BATCH, seed=2):
        batch = {k: np.asarray(v) for k, v in pipe(raw).items()}
        ts = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        train_s += time.perf_counter() - ts
    total_block = time.perf_counter() - t0
    util_block = train_s / total_block
    emit("fig14/blocking", total_block, f"util={util_block:.2%}")

    # overlapped: PipeRec mode (ETL producer thread + credit queue)
    pipe, state = fresh()
    ex = StreamingExecutor(pipe, synth.dataset_batches(
        "I", rows=N_BATCHES * BATCH, batch_size=BATCH, seed=2), credits=2)
    t0 = time.perf_counter()
    train_s = 0.0
    for batch in ex:
        ts = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        train_s += time.perf_counter() - ts
    total_ov = time.perf_counter() - t0
    util_ov = train_s / total_ov
    emit("fig14/overlapped", total_ov,
         f"util={util_ov:.2%}|speedup={total_block / total_ov:.2f}x")

    # paper's Fig-1/14 regime: slow CPU (numpy) ETL on the critical path vs
    # the same slow producer overlapped — the utilization gap is the paper's
    # headline (their CPU ETL is ~13x slower than the train step)
    pipe_np = paper_pipeline("II", small_vocab=8192,
                             batch_size=BATCH).compile(backend="numpy")
    pipe_np.fit(synth.dataset_batches("I", rows=8192, batch_size=8192))
    state = TrainState.create(dlrm.init(jax.random.key(0), cfg), tcfg)
    t0 = time.perf_counter()
    train_s = 0.0
    for raw in synth.dataset_batches("I", rows=8 * BATCH,
                                     batch_size=BATCH, seed=2):
        batch = pipe_np(raw)
        ts = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        train_s += time.perf_counter() - ts
    total_cpu = time.perf_counter() - t0
    emit("fig14/cpu_fed_blocking", total_cpu,
         f"util={train_s / total_cpu:.2%}")


if __name__ == "__main__":
    main()