"""Fig 14 analogue: trainer utilization — blocking CPU-style feed vs the
staged prefetching executor (same ETL, same trainer) — plus the Fig-8-style
per-stage occupancy breakdown from the executor's stage stats.

Ingest runs through the session facade (``EtlJob`` over a ``Source``); the
blocking baseline iterates the same Source inline on the critical path.

Emits:
  fig14/blocking, fig14/overlapped           (jnp device ETL)
  fig14/cpu_fed_blocking, fig14/cpu_fed_overlapped  (numpy host ETL — the
      paper's headline regime: slow CPU ETL hidden behind the train step)
  fig8/<stage>                                per-stage breakdown
  fig14/utilization_gain                      overlapped - blocking (pp)

``--steps N`` overrides the batch count (CI smoke: ``--steps 3`` exercises
the executor path end-to-end without asserting the utilization win, which
needs enough batches to amortize warmup).

``--sweep`` runs the Fig-8 sensitivity grid instead: credits x
stage-cost-ratio cells with pinned (sleep-based) stage costs, emitting
trainer utilization per cell —

  fig8_sweep/credits=C_ratio=R

The deterministic costs isolate the staging-depth effect: utilization
should rise with credits while ETL is the bottleneck (ratio > 1) and
saturate near 100% once ETL hides (ratio <= 1, credits >= 2).
``--sweep-credits`` / ``--sweep-ratios`` override the grid (the nightly CI
smoke runs a single cell).

``--autotune`` (with ``--sweep``) adds one controller-driven cell per
ratio: the same pinned-cost workload starts at credits=1 and lets the
self-tuning ``PipelineController`` pick the staging depth live —

  fig8_sweep/autotuned_ratio=R

the row reports the knobs the controller landed on, for eyeballing
against the exhaustively-swept cells.  ``--json [PATH]`` writes the
machine-readable sweep trajectory (default ``BENCH_10.json`` at the repo
root) with every record stamped with the git SHA and the resolved
interpret mode, so hardware and interpret baselines never get compared.
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax
import numpy as np

from benchmarks.common import emit, git_sha
from repro.configs.base import TrainConfig
from repro.core.pipeline import paper_pipeline
from repro.data.source import Source
from repro.models import dlrm
from repro.session import EtlJob
from repro.training.train_loop import TrainState, make_train_step

BATCH = 4096


def _make_step(cfg, tcfg):
    return jax.jit(make_train_step(lambda p, b: dlrm.loss_fn(p, b, cfg),
                                   tcfg), donate_argnums=0)


def _source(n_batches: int) -> Source:
    return Source.synth("I", rows=n_batches * BATCH, batch_size=BATCH, seed=2)


def _fresh_job(backend: str, n_batches: int) -> EtlJob:
    job = EtlJob(paper_pipeline("II", small_vocab=8192, batch_size=BATCH),
                 _source(n_batches), backend=backend,
                 fit_source=Source.synth("I", rows=8192, batch_size=8192))
    job.fit()
    return job


def _materialize(batch):
    return {k: np.asarray(v) for k, v in batch.items()}


def run_blocking(job, step, state, *, host_etl):
    """ETL inline on the critical path (the paper's CPU-GPU mode)."""
    t0 = time.perf_counter()
    train_s = 0.0
    for raw in job.apply_source():
        batch = job.apply(raw)
        if host_etl:
            batch = _materialize(batch)
        ts = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        train_s += time.perf_counter() - ts
    total = time.perf_counter() - t0
    return train_s / total, total


def run_overlapped(job, step, state):
    """Staged prefetching executor: ETL stages overlap the train step."""
    t0 = time.perf_counter()
    train_s = 0.0
    with job.batches() as ex:
        for batch in ex:
            ts = time.perf_counter()
            state, m = step(state, batch)
            jax.block_until_ready(m["loss"])
            train_s += time.perf_counter() - ts
    total = time.perf_counter() - t0
    return train_s / total, total, job.stats()


def _sweep_cell(job, steps, train_s):
    """Run one pinned-cost cell; return (wall_s, util, stats)."""
    t0 = time.perf_counter()
    train_total = 0.0
    with job.batches() as ex:
        for _ in ex:
            ts = time.perf_counter()
            time.sleep(train_s)
            train_total += time.perf_counter() - ts
    wall = time.perf_counter() - t0
    return wall, job.stats().trainer_utilization(train_total), job.stats()


def run_sweep(credits_list, ratios, steps, *, autotune=False):
    """Credits x stage-cost-ratio sensitivity sweep (Fig-8, ROADMAP item).

    Stage costs are pinned sleeps (deterministic, hardware-independent):
    the transform stage costs ``ratio`` x the train step.  Each cell runs
    the real staged executor through the ``EtlJob`` facade and reports the
    trainer's utilization = train_time / (train_time + starvation).

    With ``autotune``, one extra cell per ratio starts at credits=1 and
    lets the PipelineController choose the staging depth from measured
    windows — the controller-chosen row of the grid.  Returns the
    machine-readable record list (one dict per cell).
    """
    train_s = 0.004
    records = []

    def make_job(credits, ratio, **kw):
        etl_s = train_s * ratio

        def transform(raw, _etl_s=etl_s):
            time.sleep(_etl_s)
            return raw

        src = Source.stream(lambda: iter([{"i": np.arange(8)}] * steps))
        return EtlJob(transform, src, credits=credits, **kw)

    for credits in credits_list:
        for ratio in ratios:
            job = make_job(credits, ratio, name=f"sweep-c{credits}-r{ratio}")
            wall, util, stats = _sweep_cell(job, steps, train_s)
            emit(f"fig8_sweep/credits={credits}_ratio={ratio:g}", wall,
                 f"util={util:.2%}|starved={stats.consumer_wait_s:.3f}s")
            records.append(dict(mode="sweep", credits=credits, ratio=ratio,
                                steps=steps, wall_s=wall, util=util,
                                starved_s=stats.consumer_wait_s))
    if autotune:
        for ratio in ratios:
            job = make_job(1, ratio, autotune=True,
                           max_credits=max(credits_list),
                           name=f"sweep-autotuned-r{ratio}")
            wall, util, stats = _sweep_cell(job, steps, train_s)
            ctl = stats.controller
            chosen = ctl.knob_values() if ctl is not None else {}
            decisions = ctl.decision_counts() if ctl is not None else {}
            emit(f"fig8_sweep/autotuned_ratio={ratio:g}", wall,
                 f"util={util:.2%}|chosen="
                 + ",".join(f"{k}={v}" for k, v in sorted(chosen.items())))
            records.append(dict(mode="autotuned", ratio=ratio, steps=steps,
                                wall_s=wall, util=util,
                                starved_s=stats.consumer_wait_s,
                                chosen=chosen, decisions=decisions))
    return records


def _csv(kind):
    return lambda s: [kind(v) for v in s.split(",") if v]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12,
                    help="batches per run (smoke: 3)")
    ap.add_argument("--sweep", action="store_true",
                    help="run the credits x stage-cost-ratio sweep instead")
    ap.add_argument("--sweep-credits", type=_csv(int), default=[1, 2, 4],
                    help="comma-separated credit depths for --sweep")
    ap.add_argument("--sweep-ratios", type=_csv(float),
                    default=[0.5, 1.0, 2.0],
                    help="comma-separated ETL/train cost ratios for --sweep")
    ap.add_argument("--autotune", action="store_true",
                    help="with --sweep: add a controller-chosen cell per "
                         "ratio (self-tuning PipelineController)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="with --sweep: write the machine-readable records "
                         "(default: BENCH_10.json at the repo root)")
    args = ap.parse_args(argv)
    n = args.steps
    if args.sweep:
        records = run_sweep(args.sweep_credits, args.sweep_ratios, n,
                            autotune=args.autotune)
        if args.json is not None:
            from repro.kernels.ops import default_interpret
            sha, interpret = git_sha(), default_interpret()
            for r in records:
                r["git_sha"] = sha
                r["interpret"] = interpret
            path = pathlib.Path(args.json) if args.json else (
                pathlib.Path(__file__).resolve().parent.parent
                / "BENCH_10.json")
            path.write_text(json.dumps({
                "bench": "overlap_sweep",
                "git_sha": sha,
                "interpret": interpret,
                "records": records,
            }, indent=2) + "\n")
            print(f"wrote {path}", flush=True)
        return

    cfg = dlrm.DLRMConfig(vocab_size=8193, d_emb=32, bot_mlp=(128, 64, 32),
                          top_mlp=(128, 64, 1))
    tcfg = TrainConfig(lr=1e-3)
    step = _make_step(cfg, tcfg)

    def fresh_state():
        return TrainState.create(dlrm.init(jax.random.key(0), cfg), tcfg)

    # device (jnp) ETL: async dispatch already hides most of it
    util_block, total_block = run_blocking(_fresh_job("jnp", n), step,
                                           fresh_state(), host_etl=True)
    emit("fig14/blocking", total_block, f"util={util_block:.2%}")
    util_ov, total_ov, _ = run_overlapped(_fresh_job("jnp", n), step,
                                          fresh_state())
    emit("fig14/overlapped", total_ov,
         f"util={util_ov:.2%}|speedup={total_block / total_ov:.2f}x")

    # the paper's Fig-1/14 regime: slow host (numpy) ETL on the critical
    # path vs the same producer overlapped — the utilization gap is the
    # headline effect
    cpu_block, cpu_block_total = run_blocking(_fresh_job("numpy", n), step,
                                              fresh_state(), host_etl=False)
    emit("fig14/cpu_fed_blocking", cpu_block_total,
         f"util={cpu_block:.2%}")
    cpu_ov, cpu_ov_total, stats = run_overlapped(_fresh_job("numpy", n), step,
                                                 fresh_state())
    emit("fig14/cpu_fed_overlapped", cpu_ov_total,
         f"util={cpu_ov:.2%}|speedup={cpu_block_total / cpu_ov_total:.2f}x")

    # Fig-8-style per-stage breakdown of the overlapped CPU-fed run
    for name, s in stats.stage_breakdown().items():
        emit(f"fig8/{name}", s["busy_s"],
             f"items={s['items']}|wait_in={s['wait_in_s']:.3f}s"
             f"|wait_out={s['wait_out_s']:.3f}s|occ={s['occupancy']:.1%}")
    emit("fig8/overlapped_etl", stats.overlapped_etl_s,
         f"etl_hidden_behind_training={stats.overlapped_etl_s:.3f}s")

    gain_pp = (cpu_ov - cpu_block) * 100
    emit("fig14/utilization_gain", cpu_ov_total,
         f"overlap_gain={gain_pp:.1f}pp")
    if n >= 8:  # smoke runs are too short to assert the win
        assert cpu_ov > cpu_block, (
            f"overlap must beat blocking: {cpu_ov:.2%} vs {cpu_block:.2%}")


if __name__ == "__main__":
    main()
