"""Lookahead embedding cache: cached vs uncached gather across skew × size.

The tentpole measurement for the lookahead prefetch layer
(``etl_runtime/lookahead.py`` + ``kernels.embedding_bag_cached``): on a
synthetic Zipf-skewed index stream, how much of the irregular embedding-table
gather does a small device-resident hot-row cache convert into a dense pass?

Each cell sweeps (Zipf ``alpha`` × cache fraction of the vocab) and times,
per batch:

- ``uncached``  : ``ops.embedding_bag(table, idx, partitions=P)`` — the
  partitioned baseline (P dense passes over the full table).
- ``cached``    : the lookahead-planned path — apply the batch's admit/stage
  plan to the cache tensor, then ``ops.embedding_bag_cached`` with every
  cold row staged (``cold_idx=None``: one dense pass over the small cache,
  the table is never gathered at lookup time).

Host-side planning is timed separately (``plan_ms``) and NOT added to the
cached column: in the real pipeline planning runs inside the executor's
lookahead stage, overlapped with training exactly like the rest of ETL.
Every cell asserts the cached output is bit-identical to the uncached
kernel, and reports the planner's hit rate / admitted / evicted / bytes
saved — the same counters ``etl_runtime.metrics`` exports.

Acceptance target (ISSUE 7): at alpha=1.1 with the cache at 10% of the
vocab, cached >= 2x uncached and hit rate >= 80%.

``--json [PATH]`` writes the machine-readable trajectory (default
``BENCH_7.json`` at the repo root), every record stamped with the git SHA
and interpret flag; ``--cells smoke`` runs the single acceptance cell
(nightly CI).
"""

from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, git_sha, timeit
from repro.etl_runtime.lookahead import (EmbedCacheConfig, EmbedCache,
                                         LookaheadPlanner, PLAN_KEYS)
from repro.kernels import ops

VOCAB = 65536
DIM = 64
BATCH = 256
NNZ = 8
PARTITIONS = 8
WINDOW = 8
ALPHAS = (0.8, 1.1, 1.4)
CACHE_FRACS = (0.05, 0.10)
SMOKE = ((1.1, 0.10),)


def zipf_batches(alpha: float, n_batches: int, seed: int = 0) -> np.ndarray:
    """Bounded Zipf over [0, VOCAB): rank r drawn with p ∝ (r+1)^-alpha,
    ranks shuffled through a fixed permutation so hot rows are scattered
    across the id space like a real hashed vocabulary."""
    rng = np.random.default_rng(seed)
    p = (np.arange(VOCAB, dtype=np.float64) + 1.0) ** -alpha
    p /= p.sum()
    ranks = rng.choice(VOCAB, size=(n_batches, BATCH, NNZ), p=p)
    perm = np.random.default_rng(1234).permutation(VOCAB)
    return perm[ranks].astype(np.int32)


def run_cell(alpha: float, cache_frac: float, n_batches: int) -> dict:
    cache_rows = int(VOCAB * cache_frac)
    # staging region sized so every cold row of a batch fits: the measured
    # cached path is the single-pass staged kernel (cold_idx=None)
    cfg = EmbedCacheConfig(rows=cache_rows, window=WINDOW,
                           stage_max=BATCH * NNZ, min_admit_freq=1,
                           row_bytes=DIM * 4)
    batches = zipf_batches(alpha, n_batches)

    rng = np.random.default_rng(7)
    table = jnp.asarray(rng.standard_normal((VOCAB, DIM)), jnp.float32)

    # plan the whole stream first (in the pipeline this is the lookahead
    # stage's overlapped host work); drain gives shrinking windows at EOS
    planner = LookaheadPlanner(cfg, 1)
    plans = []
    t0 = time.perf_counter()
    for b in batches:
        planner.push(b.reshape(-1, 1))
    while planner.window_depth():
        plans.append(planner.pop_plan()[1])
    plan_s = time.perf_counter() - t0
    st = planner.stats
    assert st.overflow_cold == 0, "staging region must cover all cold rows"

    cache = EmbedCache(cfg, 1, DIM)
    tables = table[None]

    def cached_step(plan):
        payload = cache.advance(tables, plan.as_payload())
        slot = payload["emb_slot"].reshape(BATCH, NNZ)
        return ops.embedding_bag_cached(table, payload["emb_cache"][0],
                                        slot, None)

    def uncached_step(idx):
        return ops.embedding_bag(table, jnp.asarray(idx),
                                 partitions=PARTITIONS)

    # bit-equality on the first batch (property tests sweep this harder)
    want = np.asarray(uncached_step(batches[0]))
    got = np.asarray(cached_step(plans[0]))
    assert np.array_equal(got, want), "cached kernel diverged from uncached"

    # warmup compiles happened above; time one pass over the stream each way
    def run_cached():
        for p in plans:
            out = cached_step(p)
        out.block_until_ready()

    def run_uncached():
        for b in batches:
            out = uncached_step(b)
        out.block_until_ready()

    cached_s = timeit(run_cached, warmup=1, iters=3) / n_batches
    uncached_s = timeit(run_uncached, warmup=1, iters=3) / n_batches
    speedup = uncached_s / cached_s
    cell = f"embed_cache/a{alpha}/c{cache_frac:.0%}"
    emit(f"{cell}/uncached", uncached_s, f"{speedup:.2f}x_speedup")
    emit(f"{cell}/cached", cached_s,
         f"hit={st.hit_rate():.1%}|plan={plan_s / n_batches * 1e3:.2f}ms")
    return dict(alpha=alpha, cache_frac=cache_frac, vocab=VOCAB, dim=DIM,
                batch=BATCH, nnz=NNZ, partitions=PARTITIONS,
                cache_rows=cache_rows, stage_max=cfg.stage_max,
                window=WINDOW, n_batches=n_batches,
                uncached_ms=uncached_s * 1e3, cached_ms=cached_s * 1e3,
                plan_ms=plan_s / n_batches * 1e3, speedup=speedup,
                bit_equal=True, **st.as_dict())


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also write the machine-readable trajectory "
                         "(default: BENCH_7.json at the repo root)")
    ap.add_argument("--cells", default="full", choices=["full", "smoke"],
                    help="smoke = the single acceptance cell (nightly CI)")
    ap.add_argument("--batches", type=int, default=24)
    args = ap.parse_args(argv)

    cells = (SMOKE if args.cells == "smoke"
             else [(a, f) for a in ALPHAS for f in CACHE_FRACS])
    records = [run_cell(a, f, args.batches) for a, f in cells]

    accept = [r for r in records
              if r["alpha"] == 1.1 and r["cache_frac"] <= 0.10]
    for r in accept:
        ok = r["speedup"] >= 2.0 and r["hit_rate"] >= 0.80
        print(f"acceptance a=1.1 c={r['cache_frac']:.0%}: "
              f"speedup={r['speedup']:.2f}x hit={r['hit_rate']:.1%} "
              f"{'PASS' if ok else 'FAIL'}", flush=True)

    if args.json is not None:
        from repro.kernels.ops import default_interpret
        sha, interpret = git_sha(), default_interpret()
        for r in records:
            r["git_sha"] = sha
            r["interpret"] = interpret
        path = pathlib.Path(args.json) if args.json else (
            pathlib.Path(__file__).resolve().parent.parent / "BENCH_7.json")
        path.write_text(json.dumps({
            "bench": "embed_cache",
            "git_sha": sha,
            "interpret": interpret,
            "records": records,
        }, indent=2) + "\n")
        print(f"wrote {path}", flush=True)


if __name__ == "__main__":
    main()
