"""Shared benchmark utilities. All benches print `name,us_per_call,derived`
CSV rows (derived = human-relevant rate or ratio for that row)."""

from __future__ import annotations

import pathlib
import subprocess
import time

import numpy as np


def git_sha(default: str = "unknown") -> str:
    """Short git SHA of this repo, stamped into JSON bench records so each
    trajectory point is attributable to the commit that produced it."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=pathlib.Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10)
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else default
    except (OSError, subprocess.SubprocessError):
        return default


def timeit(fn, *, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (after warmup)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def emit(name: str, seconds: float, derived: str = "") -> None:
    print(f"{name},{seconds * 1e6:.1f},{derived}", flush=True)


def block(tree):
    for v in (tree.values() if isinstance(tree, dict) else tree):
        if hasattr(v, "block_until_ready"):
            v.block_until_ready()
    return tree
