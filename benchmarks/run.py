"""Benchmark harness — one section per paper table/figure.

Usage: ``PYTHONPATH=src python -m benchmarks.run [--only fig13]``
Prints ``name,us_per_call,derived`` CSV rows.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="substring filter on section names")
    args = ap.parse_args()

    from benchmarks import (bench_concurrent, bench_microbench,
                            bench_operators, bench_overlap, bench_pipelines,
                            bench_resources, bench_transfer)
    sections = [
        ("table2_operators", bench_operators.main),
        ("fig12_microbench", bench_microbench.main),
        # empty argv: don't let its --json/--datasets parser see run.py's
        ("fig13_15_16_pipelines", lambda: bench_pipelines.main([])),
        ("fig11_transfer", bench_transfer.main),
        ("fig14_overlap", bench_overlap.main),
        ("fig17_concurrent", bench_concurrent.main),
        ("table3_4_resources", bench_resources.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        try:
            fn()
            print(f"# section {name} done in {time.perf_counter()-t0:.1f}s",
                  flush=True)
        except Exception:
            failures += 1
            print(f"# section {name} FAILED", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()