"""Deterministic virtual-clock simulation harness for the streaming runtime.

Timing-dependent behavior (overlap margins, credit backpressure, the
self-tuning controller's observation windows) is untestable with wall-clock
sleeps: every margin is a race.  This module provides the thread-free
counterpart of ``StreamingExecutor``'s staged pipeline — a blocking-pipeline
recurrence over simulated per-item stage costs on a logical clock — so tests
compute exact makespans, utilizations and starvation patterns in
microseconds, bit-reproducibly.

- ``VirtualClock`` (re-exported from ``repro.etl_runtime.clock``): the seam
  the real runtime accepts via ``clock=``; tests that drive actual executor
  threads inject it so ``StageStats`` timers read logical time.
- ``SimPipeline``: the analytic pipeline model.  Stage ``j`` mirrors a
  runtime stage thread (get → busy → put) feeding a credit queue of bounded
  capacity; the last implicit stage is the consumer.  The recurrence
  captures both starvation (consumer waits on an empty ready queue) and
  backpressure (a stage blocks its put until the downstream queue frees a
  credit), so ``throughput(settings)`` is exact, not sampled.
- ``SimWorkload``: the sweep-grid workload the controller convergence tests
  tune over — knob settings (credits, prefetch depth, row tile, fuse) map
  to deterministic stage costs; ``optimum()`` is the exhaustive sweep the
  acceptance criterion compares against.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Optional, Sequence

from repro.etl_runtime.clock import VirtualClock  # noqa: F401  (re-export)
from repro.etl_runtime.controller import Knob


def _cost_fn(c) -> Callable[[int], float]:
    return c if callable(c) else (lambda i, v=float(c): v)


@dataclasses.dataclass
class SimResult:
    """One simulated run: absolute times plus the derived signals tests
    assert on (all in logical seconds)."""

    makespan: float
    throughput: float            # items delivered per logical second
    consumer_waits: list         # per-delivery starvation wait
    consumer_busy_s: float       # total simulated train time
    stage_busy_s: list           # per-stage total busy time

    @property
    def utilization(self) -> float:
        """Trainer utilization: train time over total wall (logical)."""
        return self.consumer_busy_s / self.makespan if self.makespan else 0.0

    def starved(self, eps: float = 1e-9) -> int:
        return sum(1 for w in self.consumer_waits if w > eps)


class SimPipeline:
    """Blocking-pipeline recurrence over per-item stage costs.

    ``stage_costs``: one cost per ETL stage (float, or ``fn(i) -> float``),
    in pipeline order (e.g. read, transform, place).  ``capacities``: the
    credit-queue capacity downstream of each stage (the runtime sizes all
    of them from one credits budget; pass per-stage values to model the
    prefetch-depth knob separately).  ``consumer_cost``: the train step.

    Per item ``i`` and stage ``j`` (get → busy → put, exactly the runtime's
    stage loop):

        pop[j][i]  = max(put[j-1][i], put[j][i-1])          # get blocks
        busy_done  = pop[j][i] + cost[j](i)
        put[j][i]  = max(busy_done, pop[j+1][i - cap[j]])   # put blocks

    The put term is credit backpressure: the queue between ``j`` and
    ``j+1`` holds ``cap[j]`` items, so item ``i`` cannot be inserted until
    the consumer side popped item ``i - cap[j]``.  The consumer is the
    final stage; its pop-minus-previous-finish gaps are the starvation
    waits the adaptive-credits rule feeds on.
    """

    def __init__(self, stage_costs: Sequence, capacities: Sequence[int],
                 consumer_cost):
        if len(stage_costs) != len(capacities):
            raise ValueError("one capacity per stage (its downstream queue)")
        self.costs = [_cost_fn(c) for c in stage_costs]
        self.caps = [max(1, int(c)) for c in capacities]
        self.consumer = _cost_fn(consumer_cost)

    def run(self, n_items: int) -> SimResult:
        S = len(self.costs)
        # pop[j][i] / put[j][i]; consumer is stage S (pop = delivery start,
        # put = train-step finish)
        pop = [[0.0] * n_items for _ in range(S + 1)]
        put = [[0.0] * n_items for _ in range(S + 1)]
        busy = [0.0] * (S + 1)
        waits = []
        for i in range(n_items):
            # stage order ascending: pop[j] needs put[j-1] of the SAME item
            # (computed just before), the backpressure term needs pop[j+1]
            # of item i - cap[j] (strictly earlier, already computed)
            for j in range(S + 1):
                upstream = put[j - 1][i] if j > 0 else 0.0
                prev = put[j][i - 1] if i > 0 else 0.0
                pop[j][i] = max(upstream, prev)
                cost = (self.consumer(i) if j == S else self.costs[j](i))
                done = pop[j][i] + cost
                if j < S and i - self.caps[j] >= 0:
                    done = max(done, pop[j + 1][i - self.caps[j]])
                put[j][i] = done
                busy[j] += cost
            prev_done = put[S][i - 1] if i > 0 else 0.0
            waits.append(max(0.0, pop[S][i] - prev_done))
        makespan = put[S][n_items - 1] if n_items else 0.0
        return SimResult(makespan=makespan,
                         throughput=n_items / makespan if makespan else 0.0,
                         consumer_waits=waits,
                         consumer_busy_s=busy[S],
                         stage_busy_s=busy[:S])


class SimWorkload:
    """The simulated sweep grid for controller convergence tests.

    Stage model (logical seconds per batch): a read stage whose cost drops
    with prefetch depth, a transform whose cost has an interior row-tile
    optimum (``a/r + b*r``: small tiles pay per-tile overhead, big tiles
    spill) with a fuse multiplier that helps everywhere EXCEPT the largest
    tile (the budget-fallback interaction — fused 512-row tiles fall back
    staged), plus a periodic transform spike every ``spike_every`` batches
    that deeper credits absorb.  The consumer is a constant train step.

    Every cost is a pure function of (settings, batch index): the sweep in
    ``optimum()`` and the controller's probes see identical numbers, so
    "within 10% of the exhaustive optimum" is an exact assertion.
    """

    GRID = {
        "credits": (1, 2, 3, 4, 5, 6, 7, 8),
        "prefetch_depth": (1, 2, 4, 8),
        "row_tile": (64, 128, 256, 512),
        "fuse": (False, True),
    }
    DEFAULTS = {"credits": 2, "prefetch_depth": 1,
                "row_tile": 64, "fuse": False}

    def __init__(self, n_batches: int = 48, *, train_cost: float = 1.0,
                 spike_every: int = 7, spike_mult: float = 6.0):
        self.n_batches = n_batches
        self.train_cost = train_cost
        self.spike_every = spike_every
        self.spike_mult = spike_mult
        self.settings = dict(self.DEFAULTS)

    # -- cost model --------------------------------------------------------

    def _transform_cost(self, s: dict) -> Callable[[int], float]:
        r = s["row_tile"]
        base = 0.35 * (256.0 / r) + 0.0022 * r
        if s["fuse"]:
            base *= 1.05 if r >= 512 else 0.60
        every, mult = self.spike_every, self.spike_mult

        def cost(i: int) -> float:
            return base * (mult if every and (i % every == every - 1)
                           else 1.0)
        return cost

    def pipeline(self, settings: Optional[dict] = None) -> SimPipeline:
        s = dict(self.DEFAULTS, **(settings or self.settings))
        read = 0.25 + 1.2 / (1 + s["prefetch_depth"])
        place = 0.30
        caps = [max(s["credits"], s["prefetch_depth"]),
                s["credits"], s["credits"]]
        return SimPipeline([read, self._transform_cost(s), place],
                           caps, self.train_cost)

    def throughput(self, settings: Optional[dict] = None) -> float:
        return self.pipeline(settings).run(self.n_batches).throughput

    # -- exhaustive sweep (the acceptance baseline) ------------------------

    def optimum(self) -> tuple:
        """(best throughput, best settings) over the full grid."""
        best, best_s = -1.0, None
        names = sorted(self.GRID)
        for combo in itertools.product(*(self.GRID[n] for n in names)):
            s = dict(zip(names, combo))
            t = self.throughput(s)
            if t > best:
                best, best_s = t, s
        return best, best_s

    # -- controller binding ------------------------------------------------

    def make_knobs(self, *, batch_bytes: int = 1 << 20) -> list:
        """Declared knobs whose actuators write ``self.settings`` — the
        simulation counterpart of the executor/EtlJob apply hooks."""

        def setter(name):
            def apply(v, name=name):
                self.settings[name] = v
            return apply

        n_queues = 3
        return [
            Knob("credits", self.GRID["credits"],
                 value=self.settings["credits"], apply=setter("credits"),
                 kind="queue", bytes_per_unit=batch_bytes * n_queues),
            Knob("prefetch_depth", self.GRID["prefetch_depth"],
                 value=self.settings["prefetch_depth"],
                 apply=setter("prefetch_depth"),
                 kind="queue", bytes_per_unit=batch_bytes),
            Knob("row_tile", self.GRID["row_tile"],
                 value=self.settings["row_tile"], apply=setter("row_tile"),
                 kind="compute"),
            Knob("fuse", self.GRID["fuse"],
                 value=self.settings["fuse"], apply=setter("fuse"),
                 kind="compute"),
        ]
