"""Source API: chainable lazily-applied specs, projection pushdown into the
columnar reader, rebatch edge cases, sharding, stream wrapping."""

import queue
import tempfile

import numpy as np
import pytest

from repro.core.schema import Schema
from repro.data import columnar, synth
from repro.data.source import Source, as_source


@pytest.fixture(scope="module")
def dataset_dir():
    with tempfile.TemporaryDirectory() as d:
        columnar.write_dataset(
            d, Schema.criteo_kaggle(),
            synth.dataset_batches("I", rows=2500, batch_size=1000))
        yield d


def _rows(batch: dict) -> int:
    return int(next(iter(batch.values())).shape[0])


# ---------------- chaining & laziness ----------------

def test_specs_are_lazy_and_immutable():
    src = Source.synth("I", rows=2000, batch_size=1000)
    projected = src.columns(["label", "dense_0"])
    assert src.spec.columns is None          # chaining never mutates
    assert projected.spec.columns == ("label", "dense_0")
    # the original still yields every column
    assert len(next(iter(src))) == 40
    assert set(next(iter(projected))) == {"label", "dense_0"}


def test_synth_from_schema_object():
    src = Source.synth(Schema.criteo_kaggle(), rows=300, batch_size=100)
    batches = list(src)
    assert [_rows(b) for b in batches] == [100, 100, 100]
    assert "sparse_25" in batches[0]


def test_shard_partitions_generated_stream():
    src = Source.synth("I", rows=4000, batch_size=1000)
    shard0 = list(src.shard(0, 2))
    shard1 = list(src.shard(1, 2))
    assert len(shard0) == 2 and len(shard1) == 2
    # disjoint: shard batches interleave the base stream
    base = list(src)
    np.testing.assert_array_equal(shard0[0]["label"], base[0]["label"])
    np.testing.assert_array_equal(shard1[0]["label"], base[1]["label"])
    with pytest.raises(ValueError):
        src.shard(2, 2)


# ---------------- rebatch edge cases ----------------

def test_rebatch_splits_and_emits_remainder():
    src = Source.synth("I", rows=2500, batch_size=1000).rebatch(600)
    sizes = [_rows(b) for b in src]
    assert sizes == [600, 600, 600, 600, 100]  # remainder kept by default


def test_rebatch_drop_remainder():
    src = Source.synth("I", rows=2500, batch_size=1000).rebatch(
        600, drop_remainder=True)
    assert [_rows(b) for b in src] == [600] * 4


def test_rebatch_coalesces_across_shard_boundaries(dataset_dir):
    # 3 shard files of 1000/1000/500 rows -> 2 batches of 1250: the second
    # 1250-row batch spans all three shards (coalescing, not just splitting)
    src = Source.columnar(dataset_dir).rebatch(1250)
    sizes = [_rows(b) for b in src]
    assert sizes == [1250, 1250]
    # bit-equality with the unbatched stream: carried rows keep their order
    flat = {k: np.concatenate([b[k] for b in Source.columnar(dataset_dir)])
            for k in next(iter(Source.columnar(dataset_dir)))}
    rb = list(Source.columnar(dataset_dir).rebatch(1250))
    np.testing.assert_array_equal(
        np.concatenate([b["dense_3"] for b in rb]), flat["dense_3"])


def test_rebatch_coalesces_small_batches():
    src = Source.synth("I", rows=900, batch_size=100).rebatch(400)
    assert [_rows(b) for b in src] == [400, 400, 100]


# ---------------- projection pushdown (columnar) ----------------

class _SpyNpz:
    """np.load stand-in that records which column keys are materialized."""

    accessed: list = []

    def __init__(self, real):
        self._real = real

    @property
    def files(self):
        return self._real.files

    def __getitem__(self, key):
        _SpyNpz.accessed.append(key)
        return self._real[key]

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return self._real.__exit__(*exc)


def test_columnar_projection_never_materializes_others(dataset_dir,
                                                       monkeypatch):
    real_load = np.load
    monkeypatch.setattr(columnar.np, "load",
                        lambda *a, **k: _SpyNpz(real_load(*a, **k)))
    _SpyNpz.accessed = []
    got = list(Source.columnar(dataset_dir).columns(["label", "dense_2"]))
    assert len(got) == 3 and set(got[0]) == {"label", "dense_2"}
    assert set(_SpyNpz.accessed) == {"label", "dense_2"}  # nothing else read


def test_columnar_shard_selects_disjoint_files(dataset_dir):
    all_rows = sum(_rows(b) for b in Source.columnar(dataset_dir))
    s0 = sum(_rows(b) for b in Source.columnar(dataset_dir).shard(0, 2))
    s1 = sum(_rows(b) for b in Source.columnar(dataset_dir).shard(1, 2))
    assert all_rows == 2500 and s0 + s1 == all_rows
    assert {len(list(Source.columnar(dataset_dir).shard(i, 3)))
            for i in range(3)} == {1}


def test_columnar_loads_schema(dataset_dir):
    src = Source.columnar(dataset_dir)
    assert src.schema["sparse_0"].hex_width == 8


# ---------------- stream / queue / coercion ----------------

def test_stream_callable_is_reiterable():
    calls = []

    def feed():
        calls.append(1)
        return iter([{"x": np.ones(2)}])

    src = Source.stream(feed)
    assert len(list(src)) == 1 and len(list(src)) == 1
    assert len(calls) == 2  # fresh iterator per pass


def test_stream_queue_drains_until_sentinel():
    q = queue.Queue()
    for i in range(3):
        q.put({"x": np.full(2, i)})
    q.put(None)
    got = list(Source.stream(q))
    assert [int(b["x"][0]) for b in got] == [0, 1, 2]


def test_as_source_identity_and_wrap():
    src = Source.synth("I", rows=100, batch_size=100)
    assert as_source(src) is src
    wrapped = as_source([{"x": np.ones(1)}])
    assert isinstance(wrapped, Source) and len(list(wrapped)) == 1


# ---------------- length_key / arrival specs ----------------

def test_length_key_and_arrival_ride_the_spec():
    fn = lambda b: 1.0
    src = Source.synth("I", rows=100, batch_size=100).length_key(fn)
    assert src.spec.length_key is fn
    a = src.arrival([1.0, 2.0])
    assert a.spec.arrival == [1.0, 2.0]
    lookup = a.spec.arrival_fn()
    assert lookup(0) == 1.0 and lookup(5) is None
    by_fn = src.arrival(lambda i: 10.0 * i).spec.arrival_fn()
    assert by_fn(3) == 30.0


def test_stream_queue_close_wakes_blocked_reader_immediately():
    """Regression: close() used to leave a reader parked on an empty queue
    sleeping out the rest of its poll interval (up to poll_s) before it
    noticed; the wake sentinel must end it promptly."""
    import threading
    import time

    q = queue.Queue()
    src = Source.stream(q, poll_s=5.0)   # long poll: the old latency bound
    done = threading.Event()
    got = []

    def run():
        got.extend(iter(src))
        done.set()

    t = threading.Thread(target=run)
    t.start()
    time.sleep(0.1)                      # reader is now blocked on get()
    t0 = time.monotonic()
    src.close()
    assert done.wait(timeout=2.0)
    assert time.monotonic() - t0 < 1.0   # woke well inside poll_s
    assert got == []
    t.join()


def test_stream_queue_close_with_full_queue_still_ends():
    """The wake sentinel cannot be enqueued into a full queue (a full queue
    has no reader blocked on an empty get); close must not raise and the
    reader must still end on its close token without a wake."""
    q = queue.Queue(maxsize=2)
    q.put({"x": np.zeros(1)})
    q.put({"x": np.ones(1)})
    src = Source.stream(q, poll_s=0.05)
    it = iter(src)
    first = next(it)                     # reader now parked at yield
    assert int(first["x"][0]) == 0
    q.put({"x": np.full(1, 2.0)})        # full again: put_nowait(_WAKE) drops
    src.close()                          # must not raise queue.Full
    assert list(it) == []                # token observed; real items unread
