"""Lookahead embedding prefetch: planner invariants, executor stage wiring,
device cache lifecycle, gradient exactness, and the drop/cache metrics."""

import jax
import jax.numpy as jnp
import numpy as np
import threading

from repro.etl_runtime.lookahead import (CacheStats, EmbedCache,
                                         EmbedCacheConfig, LookaheadPlanner,
                                         PLAN_KEYS, cached_embedding_lookup)
from repro.etl_runtime.runtime import (CreditQueue, RuntimeStats, StageStats,
                                       StreamingExecutor)
from repro.kernels import ref

RNG = np.random.default_rng(11)
V, T, B, D, ROWS = 300, 3, 48, 8, 40
CFG = EmbedCacheConfig(rows=ROWS, window=4, row_bytes=4 * D)


def _skewed_batches(n, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        b = (rng.zipf(1.3, size=(B, T)).clip(max=V) - 1).astype(np.int64)
        b[rng.random(b.shape) < 0.05] = -1  # padding lanes
        out.append(b)
    return out


def _drain_plans(planner, batches):
    """Push every batch, pop every plan (EOS drains the partial window)."""
    plans = []
    for b in batches:
        planner.push(b)
        if planner.window_depth() >= planner.cfg.window:
            plans.append(planner.pop_plan())
    while planner.window_depth():
        plans.append(planner.pop_plan())
    return plans


def test_planner_remap_reconstructs_rows():
    """slot/cold/admit plans are a total, consistent remap: replaying the
    admit plans against a slot->row mirror, every lookup resolves to its
    original row (resident slot, staged slot, or cold fall-through)."""
    planner = LookaheadPlanner(CFG, T)
    batches = _skewed_batches(10)
    mirror = [np.full(ROWS, -1, np.int64) for _ in range(T)]
    n_plans = 0
    for idx, plan in _drain_plans(planner, batches):
        n_plans += 1
        for t in range(T):
            for s, r in zip(plan.admit_slots[t], plan.admit_rows[t]):
                if s >= 0:
                    mirror[t][s] = r
            for bi in range(B):
                row = idx[bi, t]
                slot, cold = plan.slot[bi, t], plan.cold[bi, t]
                if row < 0:
                    assert slot == -1 and cold == -1
                elif slot >= 0:
                    if slot < ROWS:
                        assert mirror[t][slot] == row
                    else:  # staged region
                        assert plan.stage_rows[t][slot - ROWS] == row
                else:
                    assert cold == row
    assert n_plans == len(batches)  # EOS drained the window, nothing lost
    st = planner.stats
    assert st.lookups == st.hits + st.misses
    assert st.hits > 0 and st.admitted > 0
    assert st.gather_bytes_saved() > 0


def test_planner_window_frequency_drives_hit_rate():
    """A heavily skewed stream with a cache sized to the hot set gets a high
    hit rate; a uniform stream with a tiny cache does not."""
    hot = LookaheadPlanner(EmbedCacheConfig(rows=64, window=4,
                                            min_admit_freq=1), 1)
    rng = np.random.default_rng(3)
    skew = [(rng.zipf(1.5, size=(256, 1)).clip(max=V) - 1) for _ in range(12)]
    _drain_plans(hot, skew)
    assert hot.stats.hit_rate() > 0.6

    cold = LookaheadPlanner(EmbedCacheConfig(rows=4, window=4), 1)
    uni = [rng.integers(0, V, size=(256, 1)) for _ in range(12)]
    _drain_plans(cold, uni)
    assert cold.stats.hit_rate() < hot.stats.hit_rate()


def test_planner_refresh_readmits_referenced_residents():
    """refresh=True: every referenced resident row appears in the batch's
    admit plan (so cached training reads fresh rows after param updates)."""
    cfg = EmbedCacheConfig(rows=ROWS, window=2, refresh=True)
    planner = LookaheadPlanner(cfg, T)
    for idx, plan in _drain_plans(planner, _skewed_batches(6, seed=5)):
        for t in range(T):
            adm = set(plan.admit_rows[t][plan.admit_slots[t] >= 0].tolist())
            for bi in range(B):
                if idx[bi, t] >= 0 and 0 <= plan.slot[bi, t] < ROWS:
                    assert idx[bi, t] in adm


def test_executor_lookahead_stage_annotates_batches():
    batches = _skewed_batches(9, seed=7)

    def source():
        for b in batches:
            yield {"sparse": b.astype(np.int32), "tag": len(b)}

    ex = StreamingExecutor(lambda x: x, source(), lookahead=CFG)
    seen = 0
    for payload in ex:
        assert all(k in payload for k in PLAN_KEYS)
        assert payload["emb_slot"].shape == (B, T)
        assert payload["tag"] == B  # original keys ride along
        seen += 1
    assert seen == len(batches)  # EOS drains the lookahead window
    assert "lookahead" in ex.stats.stages
    assert ex.stats.stages["lookahead"].items == len(batches)
    assert isinstance(ex.stats.cache, CacheStats)
    assert ex.stats.cache.lookups > 0


def test_executor_lookahead_column_subset():
    """cfg.tables restricts planning to the named columns (per-table
    on/off): plan arrays have the subset width."""
    cfg = EmbedCacheConfig(rows=16, window=2, tables=(0, 2))
    batches = _skewed_batches(4, seed=9)
    ex = StreamingExecutor(lambda x: x,
                           ({"sparse": b.astype(np.int32)} for b in batches),
                           lookahead=cfg)
    for payload in ex:
        assert payload["emb_slot"].shape == (B, 2)


def test_embed_cache_advance_and_cached_lookup_bit_exact():
    """EmbedCache.advance + the cached kernel reproduce the plain stacked
    lookup bit-for-bit across a planned stream."""
    batches = _skewed_batches(8, seed=13)
    tables = jnp.asarray(RNG.standard_normal((T, V, D)), jnp.float32)
    planner = LookaheadPlanner(CFG, T)
    cache = EmbedCache(CFG, T, D)
    for idx, plan in _drain_plans(planner, batches):
        batch = cache.advance(tables, plan.as_payload())
        orig = jnp.asarray(idx.astype(np.int32))
        out = cached_embedding_lookup(tables, batch["emb_cache"],
                                      batch["emb_slot"], batch["emb_cold"],
                                      orig, partitions=2)
        want = jnp.stack([ref.embedding_bag(tables[t], orig[:, t:t + 1])
                          for t in range(T)], axis=1)
        assert jnp.array_equal(out, want)


def test_embed_cache_advance_passthrough_without_plan():
    cache = EmbedCache(CFG, T, D)
    batch = {"sparse": np.zeros((B, T), np.int32)}
    assert cache.advance(jnp.zeros((T, V, D)), batch) is batch


def test_cached_lookup_gradient_matches_plain():
    """Backward of the cached lookup == plain scatter-add gradient (the
    cache receives zero cotangent; all sensitivity goes to the tables)."""
    idx = _skewed_batches(1, seed=17)[0]
    tables = jnp.asarray(RNG.standard_normal((T, V, D)), jnp.float32)
    planner = LookaheadPlanner(CFG, T)
    cache = EmbedCache(CFG, T, D)
    planner.push(idx)
    _, plan = planner.pop_plan()
    batch = cache.advance(tables, plan.as_payload())
    orig = jnp.asarray(idx.astype(np.int32))

    def loss_cached(tb):
        return cached_embedding_lookup(
            tb, batch["emb_cache"], batch["emb_slot"], batch["emb_cold"],
            orig).sum()

    def loss_plain(tb):
        valid = orig >= 0
        rows = jax.vmap(lambda t, i: t[i], in_axes=(0, 1), out_axes=1)(
            tb, jnp.where(valid, orig, 0))
        return jnp.where(valid[..., None], rows, 0).sum()

    g_cached = jax.grad(loss_cached)(tables)
    g_plain = jax.grad(loss_plain)(tables)
    assert jnp.allclose(g_cached, g_plain)


# ---------------------------------------------------------------------------
# drop_oldest visibility (satellite: shed batches in the stage breakdown)
# ---------------------------------------------------------------------------

def test_credit_queue_counts_drop_oldest():
    q = CreditQueue(2, threading.Event(), "t")
    assert q.put(1) == 0 and q.put(2) == 0
    assert q.put(3, drop_oldest=True) == 1
    assert q.dropped == 1
    assert q.get() == 2  # oldest (1) was shed


def test_stage_drop_oldest_and_cache_in_prometheus_export():
    from repro.etl_runtime import metrics as metrics_lib

    stats = RuntimeStats()
    stats.stages["place"] = StageStats("place", items=5, drop_oldest=3)
    stats.cache = CacheStats(lookups=10, hits=8, misses=2, admitted=4,
                             row_bytes=64)
    text = metrics_lib.stats_to_prometheus(stats)
    assert 'repro_etl_stage_drop_oldest_total{stage="place"} 3' in text
    assert "repro_etl_embed_cache_hits_total 8" in text
    assert "repro_etl_embed_cache_hit_rate 0.8" in text
    assert "repro_etl_embed_cache_gather_bytes_saved_total 384" in text
