"""Compiled (interpret=False) vs interpret-mode kernels: capability
resolution, trace legality, bit-exact parity, and the mosaic-illegal
planner fallback.

Three tiers, gated by what this host can actually do:

- everywhere: ``default_interpret`` capability resolution, trace smokes
  (every kernel entry point traces with ``interpret=False`` — Pallas
  traces the kernel body and index maps at bind time, so shape/layout
  bugs in the compiled path surface even on CPU), the scatter-vs-serial
  fit-build equality, the planner's ``mosaic-illegal`` fallback, and
  traced-kernel-count parity between modes.
- compiled target present (TPU/Mosaic or GPU/Triton): the full
  bit-equality sweep — every entry point, edge rows included (negative /
  OOV / padding) — plus a compile-only ``.lower().compile()`` smoke.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as O
from repro.kernels import lanes, ops, ref
from repro.kernels.backend import compiled_backend, default_interpret
from repro.kernels.dataflow import (GroupOutput, StreamInput, TableInput,
                                    TileStep, make_fit_dataflow)

RNG = np.random.default_rng(11)
HEXMAP = np.frombuffer(b"0123456789abcdef", np.uint8)

needs_compiled = pytest.mark.skipif(
    compiled_backend() is None,
    reason="no compiled Pallas target on this backend "
           f"({jax.default_backend()}): parity needs real execution")


# ---------------------------------------------------------------------------
# kernel entry-point cases: name -> (callable, args) builder
#
# Every case covers edge rows: -1 sentinels, out-of-range (OOV) ids, and
# row counts that leave padding in the last tile.
# ---------------------------------------------------------------------------

def _vocab_table(cap: int, seed: int = 3):
    vals = np.random.default_rng(seed).integers(0, cap, size=500).astype(np.int32)
    vg = O.VocabGen(cap)
    table = vg.finalize(vg.update(vg.init_state(), vals, 0))
    return table, O.VocabGen.n_unique(table)


def case_fused_stage(interpret):
    x = (RNG.normal(size=(101, 13)) * 10).astype(np.float32)
    clamp, log = O.Clamp(0.0), O.Logarithm()
    chain = lambda v: log.jnp_expr(clamp.jnp_expr(v))
    fn = ops.fused_stage(chain, in_dtype=np.float32, out_dtype=np.float32,
                         interpret=interpret)
    return fn, (jnp.asarray(x),)


def case_fused_stage_hex(interpret):
    digits = RNG.integers(0, 16, size=(8, 67, 3))
    raw = HEXMAP[digits]
    mod = O.Modulus(4096)
    chain = lambda v: mod.jnp_expr(ref.hex2int_digit_major(v))
    fn = ops.fused_stage(chain, in_dtype=np.uint8, out_dtype=np.int32,
                         hex_width=8, interpret=interpret)
    return fn, (jnp.asarray(raw),)


def case_packer(interpret):
    widths = [13, 26, 5]
    blocks = [jnp.asarray((RNG.normal(size=(77, w)) * 3).astype(np.float32))
              for w in widths]
    fn = ops.packer(widths, [np.float32] * 3, np.float32, pad_cols_to=128,
                    interpret=interpret)
    return fn, tuple(blocks)


def case_output_dataflow(interpret):
    cap = 64
    table, n_uniq = _vocab_table(cap)
    resolved = np.where(table >= 0, table, n_uniq).astype(np.int32)
    dense = (RNG.normal(size=(93, 5)) * 10).astype(np.float32)
    ids = RNG.integers(-1, cap + 3, size=(93, 3)).astype(np.int32)  # OOV rows
    ids_b = np.clip(ids, 0, cap - 1)
    clamp, log = O.Clamp(0.0), O.Logarithm()
    dense_chain = lambda v: log.jnp_expr(clamp.jnp_expr(v))
    fn = ops.output_dataflow(
        inputs=[StreamInput("d", 5, np.dtype(np.float32)),
                StreamInput("i", 3, np.dtype(np.int32))],
        tables=[TableInput("v0", cap)],
        steps=[TileStep("map", "dlog", ("d",), fn=dense_chain),
               TileStep("lookup", "rank", ("i",), table=0),
               TileStep("map", "oh", ("i",),
                        fn=lambda x: lanes.onehot_lanes(x % 4, 4))],
        terminals=[("dlog", 5), ("rank", 3), ("oh", 12)],
        out_dtype=np.float32, pad_cols_to=32, interpret=interpret)
    return fn, (jnp.asarray(dense), jnp.asarray(ids_b),
                jnp.asarray(resolved).reshape(1, -1))


def case_group_dataflow(interpret):
    cap = 64
    table, n_uniq = _vocab_table(cap)
    resolved = np.where(table >= 0, table, n_uniq).astype(np.int32)
    dense = (RNG.normal(size=(57, 5)) * 10).astype(np.float32)
    ids = RNG.integers(0, cap, size=(57, 3)).astype(np.int32)
    clamp, log = O.Clamp(0.0), O.Logarithm()
    dense_chain = lambda v: log.jnp_expr(clamp.jnp_expr(v))
    fn = ops.group_dataflow(
        inputs=[StreamInput("d", 5, np.dtype(np.float32)),
                StreamInput("i", 3, np.dtype(np.int32))],
        tables=[TableInput("v0", cap)],
        steps=[TileStep("map", "dlog", ("d",), fn=dense_chain),
               TileStep("lookup", "rank", ("i",), table=0)],
        outputs=[GroupOutput("a", (("dlog", 5),), np.dtype(np.float32), 16),
                 GroupOutput("b", (("rank", 3),), np.dtype(np.int32), 8)],
        interpret=interpret)
    return fn, (jnp.asarray(dense), jnp.asarray(ids),
                jnp.asarray(resolved).reshape(1, -1))


def case_fit_dataflow(interpret):
    cap = 96
    vals = RNG.integers(0, cap, size=(203, 3)).astype(np.int32)
    vals.reshape(-1)[::11] = -1          # missing ids drop
    vals.reshape(-1)[1] = cap + 7        # overflow ids drop
    fn = ops.fit_dataflow([StreamInput("v", 3, np.dtype(np.int32))],
                          [], "v", cap, partitions=3, interpret=interpret)
    return fn, (jnp.asarray(vals),)


def case_vocab_build(interpret):
    vals = RNG.integers(0, 96, size=777).astype(np.int32)
    fn = lambda v: ops.vocab_build_chunk(v, capacity=96, partitions=3,
                                         interpret=interpret)
    return fn, (jnp.asarray(vals),)


def case_vocab_lookup(interpret):
    cap = 96
    table, n_uniq = _vocab_table(cap)
    x = RNG.integers(0, cap, size=(61, 5)).astype(np.int32)
    fn = lambda a, t: ops.vocab_lookup(a, t, n_uniq, partitions=3,
                                       interpret=interpret)
    return fn, (jnp.asarray(x), jnp.asarray(table))


def case_embedding_bag(interpret):
    tbl = RNG.normal(size=(67, 19)).astype(np.float32)
    idx = RNG.integers(-1, 67, size=(45, 7)).astype(np.int32)  # -1 padding
    fn = lambda t, i: ops.embedding_bag(t, i, partitions=3,
                                        interpret=interpret)
    return fn, (jnp.asarray(tbl), jnp.asarray(idx))


def _cached_bag_inputs():
    vocab, dim, cache_rows = 67, 19, 11
    tbl = RNG.normal(size=(vocab, dim)).astype(np.float32)
    idx = RNG.integers(-1, vocab, size=(45, 7)).astype(np.int32)
    hot = np.random.default_rng(5).choice(vocab, size=cache_rows, replace=False)
    slotmap = {int(v): s for s, v in enumerate(hot)}
    cache = tbl[hot]
    slot = np.vectorize(lambda v: slotmap.get(int(v), -1))(idx).astype(np.int32)
    cold = np.where((idx >= 0) & (slot < 0), idx, -1).astype(np.int32)
    return tbl, cache, slot, cold


def case_embedding_bag_cached(interpret):
    tbl, cache, slot, cold = _cached_bag_inputs()
    fn = lambda t, c, s, o: ops.embedding_bag_cached(
        t, c, s, o, partitions=3, interpret=interpret)
    return fn, (jnp.asarray(tbl), jnp.asarray(cache),
                jnp.asarray(slot), jnp.asarray(cold))


def case_embedding_bag_cache_only(interpret):
    tbl, cache, slot, _ = _cached_bag_inputs()
    fn = lambda t, c, s: ops.embedding_bag_cached(t, c, s, None,
                                                  interpret=interpret)
    return fn, (jnp.asarray(tbl), jnp.asarray(cache), jnp.asarray(slot))


CASES = [
    case_fused_stage, case_fused_stage_hex, case_packer,
    case_output_dataflow, case_group_dataflow, case_fit_dataflow,
    case_vocab_build, case_vocab_lookup, case_embedding_bag,
    case_embedding_bag_cached, case_embedding_bag_cache_only,
]
CASE_IDS = [c.__name__.removeprefix("case_") for c in CASES]


def _as_arrays(out):
    if isinstance(out, (tuple, list)):
        return [np.asarray(a) for a in out]
    if isinstance(out, dict):
        return [np.asarray(out[k]) for k in sorted(out)]
    return [np.asarray(out)]


# ---------------------------------------------------------------------------
# everywhere: capability, trace smokes, cross-form equality
# ---------------------------------------------------------------------------

def test_default_interpret_matches_backend_capability():
    """interpret defaults OFF exactly when a compiled Pallas target exists."""
    target = compiled_backend()
    if jax.default_backend() == "tpu":
        assert target == "mosaic"
    elif jax.default_backend() == "gpu":
        assert target == "triton"
    else:
        assert target is None
    assert default_interpret() is (target is None)


@pytest.mark.parametrize("case", CASES, ids=CASE_IDS)
def test_compiled_trace_smoke(case):
    """Every entry point traces with interpret=False on ANY host: Pallas
    binds the kernel jaxpr and validates block shapes at trace time, so a
    Mosaic-shape regression in the kernel body fails here, without TPUs."""
    fn, args = case(interpret=False)
    out = jax.eval_shape(fn, *args)
    assert jax.tree_util.tree_leaves(out)


def test_fit_build_forms_bit_identical():
    """The compiled fit build (serialized scalar stores) == the interpret
    build (whole-tile masked scatter), bit for bit: min/add accumulation
    is order-independent.  Runs both forms under interpret mode so the
    cross-form proof holds on CPU."""
    cap = 96
    vals = RNG.integers(-2, cap + 2, size=(203, 3)).astype(np.int32)
    for partitions in (1, 3):
        fns = {form: make_fit_dataflow(
            [StreamInput("v", 3, np.dtype(np.int32))], [], "v", cap,
            partitions=partitions, interpret=True, build_form=form)
            for form in ("scatter", "serial")}
        a = _as_arrays(fns["scatter"](jnp.asarray(vals)))
        b = _as_arrays(fns["serial"](jnp.asarray(vals)))
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)


# ---------------------------------------------------------------------------
# everywhere: planner fallback + traced-count parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def _paper_modes():
    from repro.core.pipeline import paper_pipeline
    mk = lambda interp: paper_pipeline("II", small_vocab=512).compile(
        backend="pallas", interpret=interp)
    return mk(True), mk(False)


def test_compiled_mode_keeps_fusion_and_call_count(_paper_modes):
    """When every slice stays legal under the compiled budget, both modes
    lower the SAME plan: same paths, same traced pallas_call count."""
    from repro.data import synth
    pi, pc = _paper_modes
    assert pi.plan.compiled_mode is False and pc.plan.compiled_mode is True
    paths = lambda p: {k: v["path"] for k, v in p.lowering_report().items()}
    assert paths(pi) == paths(pc)
    raw = next(synth.dataset_batches("II", rows=200, batch_size=200, seed=9))
    assert pi.traced_pallas_call_count(raw) == pc.traced_pallas_call_count(raw)


def test_mosaic_illegal_fallback_never_crashes():
    """A slice legal under the logical budget but over the compiled one
    (lane-pad + banked-gather scratch) falls back staged with reason_kind
    "mosaic-illegal" — and only in compiled mode."""
    from repro.core.pipeline import paper_pipeline
    mk = lambda interp: paper_pipeline("II", small_vocab=1 << 20).compile(
        backend="pallas", interpret=interp)
    pi, pc = mk(True), mk(False)
    assert pi.lowering_report()["sparse"]["path"] == "grouped"
    rep = pc.lowering_report()["sparse"]
    assert rep["path"] == "staged"
    assert rep["reason_kind"] == "mosaic-illegal"
    # interpret-legal slices stay fused in compiled mode
    assert pc.lowering_report()["dense"]["path"] == "grouped"


def test_bench_refuses_cross_interpret_comparison():
    """The perf-trajectory compare hard-refuses to diff runs measured in
    different interpret modes (a lowering delta, not a regression)."""
    from benchmarks.bench_pipelines import compare_to_baseline
    rec = [dict(dataset="I", pipeline="I", variant="fused_vs_staged",
                speedup=8.0)]
    a = {"interpret": True, "records": rec}
    b = {"interpret": False, "records": rec}
    with pytest.raises(SystemExit, match="cross-interpret-mode"):
        compare_to_baseline(a, b)
    # same-mode: no regression at equal speedups, regression when degraded
    assert compare_to_baseline(a, dict(a)) == []
    worse = {"interpret": True,
             "records": [dict(rec[0], speedup=2.0)]}
    assert compare_to_baseline(worse, a)


# ---------------------------------------------------------------------------
# compiled target present: bit-exact parity + compile smoke
# ---------------------------------------------------------------------------

@needs_compiled
@pytest.mark.parametrize("case", CASES, ids=CASE_IDS)
def test_compiled_bit_identical_to_interpret(case):
    fn_i, args_i = case(interpret=True)
    fn_c, args_c = case(interpret=False)
    a = _as_arrays(fn_i(*args_i))
    b = _as_arrays(fn_c(*args_c))
    assert len(a) == len(b)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


@needs_compiled
@pytest.mark.parametrize("case", CASES, ids=CASE_IDS)
def test_compiled_lowering_compiles(case):
    """compile-only: the full backend lowering (Mosaic/Triton) accepts
    every kernel — no execution, so it stays cheap on hardware."""
    fn, args = case(interpret=False)
    jax.jit(fn).lower(*args).compile()
