"""End-to-end paper system: streaming ETL -> packer -> DLRM training.

This is the paper's full loop (Fig 3/8): raw Criteo-like logs are fit +
transformed by the compiled pipeline, streamed through the double-buffered
runtime, and consumed by the DLRM trainer; loss must decrease.
"""

import jax
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core.pipeline import paper_pipeline
from repro.data import synth
from repro.etl_runtime.runtime import StreamingExecutor
from repro.models import dlrm
from repro.training.train_loop import TrainState, make_train_step

CFG = dlrm.DLRMConfig(vocab_size=2049, d_emb=16, bot_mlp=(64, 32, 16),
                      top_mlp=(64, 32, 1))


def _loss(params, batch):
    return dlrm.loss_fn(params, batch, CFG)


@pytest.mark.slow
def test_dlrm_trains_on_etl_stream():
    pipe = paper_pipeline("II", small_vocab=2048,
                          batch_size=512).compile(backend="jnp")
    pipe.fit(synth.dataset_batches("I", rows=4000, batch_size=1000, seed=1))
    assert max(pipe.state.n_unique.values()) > 100  # vocab actually learned

    tcfg = TrainConfig(lr=3e-3)
    params = dlrm.init(jax.random.key(0), CFG)
    state = TrainState.create(params, tcfg)
    step = jax.jit(make_train_step(_loss, tcfg), donate_argnums=0)

    ex = StreamingExecutor(pipe, synth.dataset_batches(
        "I", rows=20 * 512, batch_size=512, seed=2), credits=2)
    losses = []
    for batch in ex:
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert len(losses) == 20
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


def test_dlrm_prediction_in_unit_interval():
    params = dlrm.init(jax.random.key(0), CFG)
    pipe = paper_pipeline("II", small_vocab=2048).compile(backend="jnp")
    pipe.fit(synth.dataset_batches("I", rows=2000, batch_size=1000))
    batch = pipe(next(synth.dataset_batches("I", rows=256, batch_size=256)))
    pred = np.asarray(dlrm.predict(params, batch, CFG))
    assert pred.shape == (256,)
    assert (pred >= 0).all() and (pred <= 1).all()


def test_dlrm_embedding_indices_within_table():
    """VocabMap output (incl. OOV) always fits the embedding table."""
    pipe = paper_pipeline("II", small_vocab=2048).compile(backend="jnp")
    pipe.fit(synth.dataset_batches("I", rows=3000, batch_size=1000))
    batch = pipe(next(synth.dataset_batches("I", rows=512, batch_size=512,
                                            seed=9)))
    sparse = np.asarray(batch["sparse"])[:, :26]
    n_uniq = max(pipe.state.n_unique.values())
    assert sparse.max() <= n_uniq  # OOV == n_unique
    assert sparse.max() < CFG.vocab_size


def _cache_cfg(**kw):
    from repro.etl_runtime.lookahead import EmbedCacheConfig
    kw.setdefault("rows", 96)
    kw.setdefault("window", 3)
    kw.setdefault("tables", tuple(range(CFG.n_sparse)))
    return EmbedCacheConfig(**kw)


def test_dlrm_cached_forward_matches_plain():
    """With a lookahead plan + cache attached, the DLRM forward routes
    through the cached kernel and reproduces the plain path bit-for-bit."""
    from repro.etl_runtime.lookahead import EmbedCache, LookaheadPlanner

    pipe = paper_pipeline("II", small_vocab=2048).compile(backend="jnp")
    pipe.fit(synth.dataset_batches("I", rows=2000, batch_size=1000))
    batch = pipe(next(synth.dataset_batches("I", rows=128, batch_size=128,
                                            seed=4)))
    params = dlrm.init(jax.random.key(1), CFG)
    plain = np.asarray(dlrm.forward(params, batch, CFG))

    cfg = _cache_cfg()
    planner = LookaheadPlanner(cfg, CFG.n_sparse)
    planner.push(np.asarray(batch["sparse"])[:, :CFG.n_sparse])
    _, plan = planner.pop_plan()
    cache = EmbedCache(cfg, CFG.n_sparse, CFG.d_emb)
    cached_batch = cache.advance(params["tables"],
                                 {**batch, **plan.as_payload()})
    assert "emb_cache" in cached_batch
    got = np.asarray(dlrm.forward(params, cached_batch, CFG))
    np.testing.assert_array_equal(got, plain)


@pytest.mark.slow
def test_dlrm_cached_training_matches_uncached():
    """Full wiring: executor lookahead stage -> train_loop(embed_cache=...)
    -> cached forward/backward.  With refresh=True the cached run's losses
    match an uncached run on the same stream (exact gradients + fresh rows)."""
    from repro.etl_runtime.lookahead import EmbedCache
    from repro.training.train_loop import LoopConfig, train_loop

    pipe = paper_pipeline("II", small_vocab=2048,
                          batch_size=256).compile(backend="jnp")
    pipe.fit(synth.dataset_batches("I", rows=3000, batch_size=1000, seed=1))
    tcfg = TrainConfig(lr=3e-3)
    step = jax.jit(make_train_step(_loss, tcfg))
    steps = 6

    def run(cache_cfg):
        state = TrainState.create(dlrm.init(jax.random.key(0), CFG), tcfg)
        src = synth.dataset_batches("I", rows=steps * 256, batch_size=256,
                                    seed=2)
        ex = StreamingExecutor(pipe, src, lookahead=cache_cfg)
        losses = []

        def wrapped(state, batch):
            state, m = step(state, batch)
            losses.append(float(m["loss"]))
            return state, m

        cache = (EmbedCache(cache_cfg, CFG.n_sparse, CFG.d_emb)
                 if cache_cfg else None)
        train_loop(state, wrapped, ex, LoopConfig(total_steps=steps,
                                                  log_every=0),
                   async_ckpt=False, embed_cache=cache)
        return losses, ex.stats

    plain_losses, _ = run(None)
    cached_losses, stats = run(_cache_cfg(refresh=True, min_admit_freq=1))
    assert len(cached_losses) == steps
    np.testing.assert_allclose(cached_losses, plain_losses, rtol=1e-6)
    assert stats.cache.hits > 0
    assert stats.cache.hit_rate() > 0.2
