"""Training substrate: optimizers, accumulation, compression, checkpointing,
fault tolerance, end-to-end loss decrease."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg, TrainConfig
from repro.configs.registry import get_reduced
from repro.models.api import build_model, random_batch
from repro.training import checkpoint as ck
from repro.training import fault
from repro.training.grad import (ef_init, microbatched_value_and_grad,
                                 quantize_int8, dequantize_int8,
                                 split_microbatches)
from repro.training.optimizer import clip_by_global_norm, global_norm
from repro.training.train_loop import (LoopConfig, TrainState, make_train_step,
                                       train_loop)

CFG = get_reduced("llama3_2_3b")
MODEL = build_model(CFG)
BATCH = random_batch(CFG, ShapeCfg("t", 32, 8, "train"))


def test_loss_decreases_adamw():
    tcfg = TrainConfig(lr=1e-3)
    state = TrainState.create(MODEL.init(jax.random.key(0)), tcfg)
    step = jax.jit(make_train_step(MODEL.loss, tcfg), donate_argnums=0)
    first = last = None
    for _ in range(25):
        state, m = step(state, BATCH)
        last = float(m["loss"])
        first = first if first is not None else last
    assert last < first * 0.7, (first, last)


def test_loss_decreases_adafactor():
    tcfg = TrainConfig(optimizer="adafactor", lr=1e-3)
    state = TrainState.create(MODEL.init(jax.random.key(0)), tcfg)
    step = jax.jit(make_train_step(MODEL.loss, tcfg), donate_argnums=0)
    first = last = None
    for _ in range(25):
        state, m = step(state, BATCH)
        last = float(m["loss"])
        first = first if first is not None else last
    assert last < first, (first, last)


def test_microbatched_grads_match_full_batch():
    """Accumulated grads == single-shot grads (same loss surface)."""
    params = MODEL.init(jax.random.key(0))
    vg1 = jax.jit(microbatched_value_and_grad(MODEL.loss, 1))
    vg4 = jax.jit(microbatched_value_and_grad(MODEL.loss, 4))
    l1, g1 = vg1(params, BATCH)
    l4, g4 = vg4(params, BATCH)
    np.testing.assert_allclose(float(l1), float(l4), rtol=2e-3)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.05, atol=5e-3)


def test_split_microbatches_shapes():
    mb = split_microbatches({"x": np.zeros((8, 3))}, 4)
    assert mb["x"].shape == (4, 2, 3)
    with pytest.raises(AssertionError):
        split_microbatches({"x": np.zeros((7, 3))}, 4)


def test_grad_clip():
    tree = {"a": jnp.full((10,), 100.0)}
    clipped, n = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(n) > 100


def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.5 + 1e-6  # half-ulp of the int8 grid


def test_compressed_psum_error_feedback_converges():
    """EF residual carries quantization error: mean of many steps unbiased."""
    from repro.training.grad import compressed_psum_mean
    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single-device shard_map still exercises the code path
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    mesh = Mesh(np.array(devs[:1]), ("d",))
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(32,)) * 0.1,
                          jnp.float32)}
    ef = ef_init(g)
    total = np.zeros(32)
    fn = shard_map(lambda gg, ee: compressed_psum_mean(gg, ee, "d"),
                   mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()))
    acc_err = []
    for i in range(50):
        out, ef = fn(g, ef)
        total += np.asarray(out["w"])
        acc_err.append(np.abs(total / (i + 1) - np.asarray(g["w"])).max())
    assert acc_err[-1] < acc_err[0]  # EF drives the running mean to truth


def test_checkpoint_roundtrip_and_atomicity():
    tcfg = TrainConfig()
    state = TrainState.create(MODEL.init(jax.random.key(0)), tcfg)
    with tempfile.TemporaryDirectory() as d:
        ck.save(state, d, 7)
        assert ck.latest_step(d) == 7
        zeros = jax.tree_util.tree_map(
            lambda x: np.zeros(x.shape, x.dtype), state)
        restored = ck.restore(d, zeros)
        for a, b in zip(jax.tree_util.tree_leaves(state),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # uncommitted dirs are invisible
        os.makedirs(os.path.join(d, "step_00000009"))
        assert ck.latest_step(d) == 7
        # prune keeps newest
        ck.save(state, d, 8)
        ck.save(state, d, 9)
        ck.prune(d, keep=1)
        assert ck.latest_step(d) == 9
        with pytest.raises(FileNotFoundError):
            ck.restore(d, zeros, step=7)


def test_checkpoint_structure_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        ck.save({"a": np.ones(3)}, d, 1)
        with pytest.raises(ValueError):
            ck.restore(d, {"a": np.ones(3), "b": np.ones(2)})


def test_async_checkpointer():
    with tempfile.TemporaryDirectory() as d:
        acp = ck.AsyncCheckpointer()
        acp.save_async({"w": jnp.ones((4, 4))}, d, 3)
        acp.wait()
        assert ck.latest_step(d) == 3


def test_watchdog_fires():
    wd = fault.Watchdog(0.05)
    wd.arm()
    import time
    time.sleep(0.3)
    with pytest.raises(fault.WatchdogTimeout):
        wd.check()
    wd.close()


def test_run_with_restarts():
    attempts = []

    def make_fn():
        def fn():
            attempts.append(1)
            if len(attempts) < 3:
                raise RuntimeError("injected failure")
        return fn

    stats = fault.run_with_restarts(make_fn, max_restarts=5)
    assert stats.restarts == 2 and len(attempts) == 3


def test_restart_resumes_from_checkpoint():
    """Kill training mid-run; restart continues from the last commit."""
    tcfg = TrainConfig(lr=1e-3)
    with tempfile.TemporaryDirectory() as d:
        step_fn = jax.jit(make_train_step(MODEL.loss, tcfg), donate_argnums=0)
        state = TrainState.create(MODEL.init(jax.random.key(0)), tcfg)

        def batches(n):
            for _ in range(n):
                yield BATCH

        # run 10 steps with ckpt every 5, then simulate crash + restore
        state = train_loop(state, step_fn, batches(10),
                           LoopConfig(total_steps=10, ckpt_dir=d,
                                      ckpt_every=5, log_every=0),
                           async_ckpt=False)
        assert ck.latest_step(d) == 10
        zeros = jax.tree_util.tree_map(
            lambda x: np.zeros(x.shape, x.dtype), state)
        restored = ck.restore(d, zeros)
        assert int(restored.step) == 10
        restored = train_loop(restored, step_fn, batches(5),
                              LoopConfig(total_steps=15, ckpt_dir=d,
                                         ckpt_every=5, log_every=0),
                              async_ckpt=False)
        assert int(restored.step) == 15


# ---------------- checkpoint rollover (online service posture) ----------------

def _tiny_state(v=1.0):
    return {"w": np.full((3, 3), v, np.float32)}


def test_prune_interleaved_with_async_saves_keeps_exact():
    """The online rollover pattern — save_async then prune each tick —
    converges to exactly ``keep`` committed checkpoints, newest kept."""
    with tempfile.TemporaryDirectory() as d:
        acp = ck.AsyncCheckpointer()
        for step in range(3, 31, 3):
            acp.save_async(_tiny_state(step), d, step)
            ck.prune(d, keep=2)
        acp.wait()
        ck.prune(d, keep=2)   # the last save commits after its prune
        committed = sorted(
            int(p.split("_")[1]) for p in os.listdir(d)
            if p.startswith("step_")
            and os.path.exists(os.path.join(d, p, "COMMITTED")))
        assert committed == [27, 30]
        assert ck.latest_step(d) == 30
        restored = ck.restore(d, _tiny_state(0.0))
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      _tiny_state(30)["w"])


def test_prune_keep_one_edge():
    with tempfile.TemporaryDirectory() as d:
        for step in (1, 2, 3):
            ck.save(_tiny_state(step), d, step)
        ck.prune(d, keep=1)
        assert ck.latest_step(d) == 3
        assert [p for p in os.listdir(d) if p.startswith("step_")] == \
            ["step_00000003"]


def test_prune_uncommitted_garbage_cannot_displace_committed():
    """Crash-between-save-and-commit edge: an uncommitted ``step_*`` dir
    (newer step number than every committed one) must not count toward the
    keep window — pruning with keep=1 must keep the committed checkpoint
    and delete the garbage, and restore must land on the committed one."""
    with tempfile.TemporaryDirectory() as d:
        ck.save(_tiny_state(7), d, 7)
        # simulate a crash mid-save: step dir exists, no COMMITTED marker
        crash = os.path.join(d, "step_00000009")
        os.makedirs(crash)
        with open(os.path.join(crash, "manifest.json"), "w") as fh:
            fh.write("{}")
        ck.prune(d, keep=1)
        assert not os.path.isdir(crash)          # garbage swept
        assert ck.latest_step(d) == 7            # committed one survived
        restored = ck.restore(d, _tiny_state(0.0))
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      _tiny_state(7)["w"])
