"""Self-tuning PipelineController: convergence, determinism, pressure,
hysteresis, knob-application equivalence — all on the deterministic
simulation harness (tests/simclock.py), plus live-executor integration."""

import numpy as np
import pytest

from proptest import given, strategies as st
from simclock import SimPipeline, SimWorkload, VirtualClock

from repro.core.pipeline import paper_pipeline
from repro.data import synth
from repro.etl_runtime.controller import Knob, PipelineController
from repro.etl_runtime.runtime import StreamingExecutor


# ---------------- simulation harness sanity ----------------

def test_simpipeline_consumer_bound_is_analytic():
    """One ETL stage cheaper than the consumer: after the initial fill the
    consumer never waits, so the makespan is exactly fill + N * step."""
    r = SimPipeline([0.5], [2], 1.0).run(8)
    assert r.makespan == pytest.approx(0.5 + 8 * 1.0)
    assert r.starved() == 1                      # only the first delivery
    assert r.consumer_waits[0] == pytest.approx(0.5)
    assert all(w == 0.0 for w in r.consumer_waits[1:])
    assert r.stage_busy_s[0] == pytest.approx(8 * 0.5)


def test_simpipeline_credits_absorb_spikes():
    """Periodic ETL spikes starve a shallow queue but not a deep one —
    the signal the credits knob exists to exploit."""
    def spiky(i):
        return 3.0 if i % 4 == 3 else 0.2

    shallow = SimPipeline([spiky], [1], 1.0).run(32)
    deep = SimPipeline([spiky], [4], 1.0).run(32)
    assert deep.throughput > shallow.throughput
    assert deep.starved() < shallow.starved()


# ---------------- hill-climber convergence (acceptance) ----------------

@pytest.mark.parametrize("seed", [0, 1, 5])
def test_converges_within_10pct_of_sweep_optimum(seed):
    """<= 30 observation windows land within 10% of the exhaustive-sweep
    optimum, from a deliberately bad default, under any fixed seed."""
    w = SimWorkload()
    best, _ = w.optimum()
    untuned = w.throughput()
    ctl = PipelineController(w.make_knobs(), mode="throughput",
                             seed=seed, tolerance=0.005)
    for _ in range(30):
        ctl.observe_window(w.throughput())
    ctl.restore_best()
    final = w.throughput()
    assert ctl.window <= 30
    assert final >= 0.90 * best
    assert final >= untuned            # never worse than where it started
    # every decision stayed inside the declared candidate domain
    domains = {k.name: set(k.candidates) for k in ctl.knobs}
    for _, knob, _, value in ctl.decision_log():
        assert value in domains[knob]


def test_convergence_is_deterministic_under_fixed_seed():
    """Same seed, same workload -> bit-identical decision history."""
    runs = []
    for _ in range(2):
        w = SimWorkload()
        ctl = PipelineController(w.make_knobs(), mode="throughput",
                                 seed=3, tolerance=0.005)
        for _ in range(30):
            ctl.observe_window(w.throughput())
        runs.append((ctl.decision_log(), ctl.knob_values(), dict(w.settings)))
    assert runs[0] == runs[1]


def test_throughput_drift_reopens_a_converged_search():
    """>10% regime change un-retires the knobs (the climber probes again)."""
    w = SimWorkload()
    ctl = PipelineController(w.make_knobs(), mode="throughput",
                             seed=0, tolerance=0.005)
    quiet = 0
    for _ in range(80):                       # run to full convergence
        quiet = quiet + 1 if not ctl.observe_window(w.throughput()) else 0
        if quiet >= 3:
            break
    assert quiet >= 3, "climber never converged"
    w.train_cost = 3.0                        # regime change: >10% drop
    probed = []
    for _ in range(3):
        probed += [d for d in ctl.observe_window(w.throughput())
                   if d.action == "probe"]
    assert probed, "drift did not reopen the search"


# ---------------- property: tuned never below untuned ----------------

@given(st.lists(st.floats(0.05, 1.5), min_size=1, max_size=3),
       st.floats(0.2, 1.2), st.integers(0, 999))
def test_tuning_never_decreases_steady_state_throughput(costs, train, seed):
    """Random stage-cost vectors: after restore_best() the tuned pipeline's
    simulated throughput is >= the untuned default, and every knob value
    the controller ever applied is inside its declared bounds."""
    settings = {"credits": 2, "prefetch_depth": 1}

    def tput():
        spiky = [(lambda i, c=c: c * (5.0 if i % 5 == 4 else 1.0))
                 for c in costs]
        caps = ([max(settings["credits"], settings["prefetch_depth"])]
                + [settings["credits"]] * (len(costs) - 1))
        return SimPipeline(spiky, caps, train).run(24).throughput

    def setter(name):
        return lambda v: settings.__setitem__(name, v)

    knobs = [Knob("credits", (1, 2, 3, 4, 6, 8), value=2,
                  apply=setter("credits"), kind="queue",
                  bytes_per_unit=1 << 20),
             Knob("prefetch_depth", (1, 2, 4, 8), value=1,
                  apply=setter("prefetch_depth"), kind="queue",
                  bytes_per_unit=1 << 20)]
    untuned = tput()
    ctl = PipelineController(knobs, mode="throughput", seed=seed,
                             tolerance=0.005)
    for _ in range(24):
        ctl.observe_window(tput())
        for k in knobs:
            assert k.value in k.candidates
    ctl.restore_best()
    assert tput() >= untuned * (1 - 1e-9)
    domains = {k.name: set(k.candidates) for k in knobs}
    for _, knob, _, value in ctl.decision_log():
        assert value in domains[knob]


# ---------------- memory-pressure guard ----------------

def test_pressure_shrinks_queue_knobs_first_largest_first():
    """The guard preempts the optimizer and halves queued bytes via the
    queue knobs (largest estimated footprint first); compute knobs hold."""
    w = SimWorkload()
    w.settings.update(credits=8, prefetch_depth=8, row_tile=256, fuse=True)
    pressure = {"level": 0.0}
    ctl = PipelineController(
        w.make_knobs(), mode="throughput", seed=0, tolerance=0.005,
        memory_pressure=lambda: pressure["level"])
    ctl.observe_window(w.throughput())        # settle + first probe
    before = ctl.total_queued_bytes()
    assert before > 0
    pressure["level"] = 1.0
    windows = 0
    while ctl.total_queued_bytes() > before / 2:
        decisions = ctl.observe_window(w.throughput())
        windows += 1
        assert windows <= 10, "guard failed to halve queued bytes"
        assert all(d.action in ("pressure-shrink", "revert")
                   for d in decisions)
    # queue knobs shrank; compute knobs untouched while queues move
    assert w.settings["credits"] < 8 and w.settings["prefetch_depth"] < 8
    assert w.settings["row_tile"] == 256 and w.settings["fuse"] is True
    # largest-footprint-first: credits (3 queues/batch) shrinks before
    # prefetch_depth (1 batch) on the first guarded window
    first = [d for d in ctl.decisions if d.action == "pressure-shrink"]
    assert first[0].knob == "credits"
    # pressure clears -> the optimizer resumes probing
    pressure["level"] = 0.0
    resumed = []
    for _ in range(2):
        resumed += ctl.observe_window(w.throughput())
    assert any(d.action == "probe" for d in resumed)


def test_pressure_shrinks_compute_knobs_only_at_queue_floor():
    w = SimWorkload()
    w.settings.update(credits=1, prefetch_depth=1, row_tile=512, fuse=False)
    ctl = PipelineController(w.make_knobs(), mode="throughput",
                             memory_pressure=lambda: 1.0)
    ctl.observe_window(w.throughput())
    shrunk = [d.knob for d in ctl.decisions if d.action == "pressure-shrink"]
    assert "row_tile" in shrunk                # queues at floor -> compute
    assert w.settings["row_tile"] == 256


def test_pressure_on_live_executor_no_deadlock_no_drops():
    """A sustained pressure event on the real executor shrinks the staging
    footprint >= 2x and every batch still arrives exactly once."""
    N = 12

    def src():
        for i in range(N):
            yield {"x": np.full((4, 4), i, np.int32)}

    ctl = PipelineController([], mode="throughput", window_deliveries=2,
                             memory_pressure=lambda: 1.0)
    ex = StreamingExecutor(lambda b: b, src(), credits=4, max_credits=8,
                           autotune=ctl)
    before = ctl.total_queued_bytes()
    got = [int(b["x"][0, 0]) for b in ex]
    assert got == list(range(N))               # in order, none dropped
    assert ex.stats.dropped_stale == 0
    assert ex.current_credits == 1             # shrunk to the floor
    assert ctl.total_queued_bytes() <= before / 2
    assert ex.join(timeout=2.0)


# ---------------- occupancy-mode hysteresis (oscillation damper) ----------

def _alternating_signals(ctl, windows=12):
    """Feed grow/shrink-inducing windows alternately; return resize log."""
    for i in range(windows):
        if i % 2 == 0:
            ctl.observe_window(1.0, starved=ctl.window_deliveries,
                               always_full=False)
        else:
            ctl.observe_window(1.0, starved=0, always_full=True)
    return [d for d in ctl.decisions if d.action in ("grow", "shrink")]


def _occupancy_controller(hysteresis):
    store = {"credits": 4}
    knob = Knob("credits", tuple(range(1, 9)), value=4,
                apply=lambda v: store.__setitem__("credits", v),
                kind="queue", bytes_per_unit=1 << 20)
    return PipelineController([knob], mode="occupancy",
                              window_deliveries=4, hysteresis=hysteresis)


def test_hysteresis_damps_adaptive_credit_oscillation():
    """Alternating starve/full signals ping-pong an undamped controller
    every window; hysteresis suppresses the reversals."""
    undamped = _occupancy_controller(hysteresis=0)
    resizes0 = _alternating_signals(undamped)
    assert undamped.suppressed_flips == 0
    # undamped: every window reverses direction with a 1-window gap
    flips0 = sum(1 for a, b in zip(resizes0, resizes0[1:])
                 if a.action != b.action)
    assert flips0 >= 8

    damped = _occupancy_controller(hysteresis=2)
    resizes2 = _alternating_signals(damped)
    assert damped.suppressed_flips >= 3
    assert len(resizes2) < len(resizes0)
    # no reversal ever lands within the damper window
    for a, b in zip(resizes2, resizes2[1:]):
        if a.action != b.action:
            assert b.window - a.window > 2


# ---------------- knob-application equivalence ----------------

def _fit_batches():
    return synth.dataset_batches("I", rows=3000, batch_size=1000, seed=7)


def _assert_bit_identical(want, got, msg):
    assert set(want) == set(got)
    for k in want:
        np.testing.assert_array_equal(np.asarray(want[k]),
                                      np.asarray(got[k]),
                                      err_msg=f"{msg}/{k}")


def test_with_knobs_matches_fresh_compile_bit_exact():
    """with_knobs(row_tile/fuse) re-plans in place; outputs must be
    bit-identical to compiling the same pipeline fresh at those settings."""
    raw = next(synth.dataset_batches("I", rows=600, batch_size=600, seed=9))
    p = paper_pipeline("II", small_vocab=2048)
    cp = p.compile(backend="pallas")
    cp.fit(_fit_batches())
    base_tile = cp.plan.row_tile

    swapped = cp.with_knobs(row_tile=128, fuse={"sparse"})
    assert swapped.plan.row_tile == 128
    assert swapped.fuse_spec() == frozenset({"sparse"})
    fresh = p.compile(backend="pallas", row_tile=128, fuse={"sparse"})
    fresh.fit(_fit_batches())
    _assert_bit_identical(fresh(raw), swapped(raw), "row_tile=128")

    # toggling back restores the original program's outputs exactly
    back = swapped.with_knobs(row_tile=base_tile, fuse="auto")
    assert back.plan.row_tile == base_tile and back.fuse_spec() == "auto"
    _assert_bit_identical(cp(raw), back(raw), "round-trip")


def test_row_tile_swap_mid_run_bit_identical():
    """Flipping row_tile mid-stream (the controller's actuator path) must
    not perturb a single delivered byte: every batch — whichever compile
    processed it — equals the fresh-compile reference."""
    batches = list(synth.dataset_batches("I", rows=4000, batch_size=1000,
                                         seed=3))
    p = paper_pipeline("II", small_vocab=2048)
    cp = p.compile(backend="pallas")
    cp.fit(_fit_batches())
    fresh = p.compile(backend="pallas", row_tile=128)
    fresh.fit(_fit_batches())

    ex = StreamingExecutor(cp, iter(batches), credits=2)
    it = iter(ex)
    got = [next(it), next(it)]
    ex.swap_pipeline(cp.with_knobs(row_tile=128))
    got.extend(it)
    assert ex.pipeline.plan.row_tile == 128
    assert len(got) == len(batches)
    for i, (raw, out) in enumerate(zip(batches, got)):
        _assert_bit_identical(fresh(raw), out, f"batch{i}")


# ---------------- virtual-clock seam through the live executor ----------

def test_virtual_clock_drives_stage_timers():
    """StageStats timing flows through the injected clock: logical
    advances in the transform land EXACTLY in its busy counter — no
    wall-clock in the accounting path."""
    clock = VirtualClock()

    def pipe(b):
        clock.advance(0.25)
        return b

    def src(n=4):
        for i in range(n):
            yield {"x": np.full((2, 2), i, np.int32)}

    ex = StreamingExecutor(pipe, src(), credits=2, clock=clock)
    assert sum(1 for _ in ex) == 4
    assert ex.stats.stages["transform"].busy_s == 1.0   # 4 * 0.25, exact
    assert ex.stats.stages["place"].busy_s == 0.0       # nobody advanced
    # all waits are logical too, so they are bounded by the total advance
    assert 0.0 <= ex.stats.consumer_wait_s <= 1.0
    assert ex.join(timeout=2.0)


def test_on_delivery_windows_use_injected_clock():
    """Window throughput is measured on the controller's clock: feeding
    logical timestamps yields exact batches/sec, deterministically."""
    clock = VirtualClock()
    store = {"credits": 2}
    knob = Knob("credits", (1, 2, 3, 4), value=2,
                apply=lambda v: store.__setitem__("credits", v),
                kind="queue", bytes_per_unit=1 << 20)
    ctl = PipelineController([knob], mode="occupancy", clock=clock,
                             window_deliveries=4, hysteresis=0)
    decisions = []
    for _ in range(4):
        clock.advance(0.5)                   # 2 deliveries / logical second
        decisions += ctl.on_delivery(wait_s=0.2, ready_full=False)
    # every delivery starved -> the window closed with one grow decision
    assert [d.action for d in decisions] == ["grow"]
    assert store["credits"] == 3
