"""Distribution: sharding rules, hlo_cost analyzer, multi-device subprocess.

The 8-device tests run in a subprocess so the 1-device default of the rest of
the suite is untouched (jax locks device count at first init).
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import hlo_cost
from repro.distributed.sharding import param_specs, batch_specs, cache_specs

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ---------------- hlo_cost analyzer ----------------

def test_analyzer_matches_xla_on_straightline():
    c = jax.jit(lambda a, b: a @ b).lower(
        jax.ShapeDtypeStruct((256, 512), jnp.float32),
        jax.ShapeDtypeStruct((512, 1024), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    xla = hlo_cost.xla_cost_analysis(c)
    assert r["flops"] == xla["flops"]
    assert abs(r["bytes_accessed"] - xla["bytes accessed"]) / xla["bytes accessed"] < 0.1


def test_analyzer_multiplies_loop_trip_counts():
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ c, None), x, None, length=10)
        return y
    c = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
    r = hlo_cost.analyze(c.as_text())
    assert r["flops"] >= 10 * 2 * 128 ** 3  # XLA's own counts body ONCE
    assert hlo_cost.xla_cost_analysis(c)["flops"] < r["flops"]


# ---------------- sharding rules ----------------

def _mk_mesh():
    return jax.sharding.Mesh(
        np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))


def test_param_specs_stacked_layers():
    mesh = _mk_mesh()
    tree = {"blocks": {"attn": {"wq": jax.ShapeDtypeStruct((4, 64, 64),
                                                           jnp.float32)}}}
    spec = param_specs(tree, mesh)
    s = spec["blocks"]["attn"]["wq"]
    assert len(s) == 3  # stacked leading dim handled


def test_batch_specs_rows():
    mesh = _mk_mesh()
    spec = batch_specs({"tokens": jax.ShapeDtypeStruct((8, 16), jnp.int32)},
                       mesh)
    assert len(spec["tokens"]) == 2


def test_cache_specs_layouts():
    mesh = _mk_mesh()
    tree = {"blocks": {"k": jax.ShapeDtypeStruct((2, 4, 32, 8, 16),
                                                 jnp.bfloat16),
                       "pos": jax.ShapeDtypeStruct((32,), jnp.int32)},
            "ssm": jax.ShapeDtypeStruct((2, 4, 8, 16, 32), jnp.float32)}
    spec = cache_specs(tree, mesh)
    assert len(spec["blocks"]["k"]) == 5
    assert all(x is None for x in spec["blocks"]["pos"])


# ---------------- multi-device subprocess ----------------

@pytest.mark.slow
def test_sharded_train_step_runs_on_8_devices():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.mesh import make_host_mesh
        from repro.distributed import sharding as shd
        from repro.configs.registry import get_reduced
        from repro.configs.base import ShapeCfg, TrainConfig
        from repro.models.api import build_model, random_batch, input_specs
        from repro.training.train_loop import (TrainState, make_train_step,
                                               jit_train_step)
        assert len(jax.devices()) == 8
        mesh = make_host_mesh(model_axis=2)  # 4 x 2
        shd.set_active_mesh(mesh)
        cfg = get_reduced("llama3_2_3b")
        model = build_model(cfg)
        tcfg = TrainConfig(lr=1e-3, microbatch=2, fsdp=True)
        state_shapes = jax.eval_shape(
            lambda: TrainState.create(model.init(jax.random.key(0)), tcfg))
        shape = ShapeCfg("t", 32, 8, "train")
        step_fn, spec = jit_train_step(
            make_train_step(model.loss, tcfg), mesh, state_shapes,
            input_specs(cfg, shape))
        with mesh:
            state = TrainState.create(model.init(jax.random.key(0)), tcfg)
            batch = random_batch(cfg, shape)
            l0 = None
            for i in range(8):
                state, m = step_fn(state, batch)
                if l0 is None: l0 = float(m["loss"])
            assert float(m["loss"]) < l0
        print("OK8", l0, float(m["loss"]))
    """)
    assert "OK8" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard_1_to_8_devices():
    """Checkpoint written on 1 device restores onto an 8-device mesh."""
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        # write on the CURRENT (1-device) process
        from repro.configs.base import TrainConfig
        from repro.configs.registry import get_reduced
        from repro.models.api import build_model
        from repro.training import checkpoint as ck
        from repro.training.train_loop import TrainState
        cfg = get_reduced("llama3_2_3b")
        model = build_model(cfg)
        state = TrainState.create(model.init(jax.random.key(3)),
                                  TrainConfig())
        ck.save(state, d, 42)
        out = run_subprocess(f"""
            import jax, numpy as np
            from jax.sharding import NamedSharding
            from repro.launch.mesh import make_host_mesh
            from repro.distributed import sharding as shd
            from repro.configs.base import TrainConfig
            from repro.configs.registry import get_reduced
            from repro.models.api import build_model
            from repro.training import checkpoint as ck
            from repro.training.train_loop import TrainState
            mesh = make_host_mesh(model_axis=2)
            cfg = get_reduced("llama3_2_3b")
            model = build_model(cfg)
            tcfg = TrainConfig()
            shapes = jax.eval_shape(
                lambda: TrainState.create(model.init(jax.random.key(0)), tcfg))
            pspec = shd.param_specs(shapes.params, mesh)
            ospec = shd.param_specs(shapes.opt, mesh)
            from jax.sharding import PartitionSpec as P
            spec = TrainState(params=pspec, opt=ospec, step=P())
            sh = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), spec,
                is_leaf=lambda x: isinstance(x, P))
            zeros = jax.tree_util.tree_map(
                lambda s: np.zeros(s.shape, s.dtype), shapes)
            st = ck.restore({d!r}, zeros, shardings=sh)
            assert int(st.step) == 0
            ndev = len(set(
                dev for leaf in jax.tree_util.tree_leaves(st.params)
                for dev in leaf.sharding.device_set))
            assert ndev == 8, ndev
            print("ELASTIC_OK", ck.latest_step({d!r}))
        """)
        assert "ELASTIC_OK 42" in out


@pytest.mark.slow
def test_dryrun_cell_on_8_devices():
    """A miniature of the production dry-run on an 8-device host mesh."""
    out = run_subprocess("""
        import jax
        from repro.configs.base import ShapeCfg
        from repro.distributed import sharding as shd, hlo_cost
        from repro.launch.cells import plan_cell
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        shd.set_active_mesh(mesh)
        shape = ShapeCfg("train_tiny", 256, 16, "train")
        plan = plan_cell("mamba2_370m", shape, mesh)
        with mesh:
            lowered = plan.jitted.lower(*plan.abstract_args)
            compiled = lowered.compile()
        r = hlo_cost.analyze(compiled.as_text())
        assert r["flops"] > 0 and r["n_collectives"] > 0
        print("CELL_OK", int(r["n_collectives"]))
    """)
    assert "CELL_OK" in out
