"""Relational optimizer: CSE, dead-stage pushdown, multi-output grouping.

The acceptance scenario lives here: a plan with three outputs sharing a
decode prefix, all fitting one VMEM budget, must lower to FEWER kernels
than outputs, execute shared prefixes exactly once per batch, and stay
bit-identical across the grouped / per-output-fused / staged rungs of the
fallback ladder.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import operators as O
from repro.core.optimizer import optimize_plan
from repro.core.pipeline import Pipeline, Vocab, paper_pipeline
from repro.core.planner import (FusedStage, Planner, VocabLookupStage)
from repro.core.schema import Schema
from repro.data import synth


def _shared_prefix_pipeline(n_outputs=3, pad_cols_to=1):
    """n outputs, each rebuilding the SAME dense decode chain and the SAME
    sparse decode + bound + vocab chain from scratch (fresh source nodes per
    output — the worst-case duplication the optimizer must recover)."""
    p = Pipeline(Schema.criteo_kaggle())
    for i in range(n_outputs):
        d = (p.dense("dense_*") | O.FillMissing(0.0) | O.Clamp(0.0, 50.0)
             | O.Logarithm())
        s = (p.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(1000)
             | Vocab(1000))
        p.output(f"out{i}", [d, s], dtype=np.float32,
                 pad_cols_to=pad_cols_to)
    return p


def _plan(p, **kw):
    planner = Planner(p.graph, vmem_budget=kw.pop("vmem_budget", 4 << 20),
                      lanes=8, vector_width=128)
    return planner.plan(p._outputs)


def _fit_batches():
    return synth.dataset_batches("I", rows=2000, batch_size=1000, seed=7)


@pytest.fixture(scope="module")
def raw_batch():
    return next(synth.dataset_batches("I", rows=600, batch_size=600, seed=9))


# ---------------- CSE --------------------------------------------------------


def test_cse_merges_duplicate_prefixes():
    plan = _plan(_shared_prefix_pipeline(3))
    opt = optimize_plan(plan)
    # 3x(dense chain + sparse chain + lookup) -> 1x each
    assert len(plan.stages) == 9 and len(opt.stages) == 3
    assert len(plan.vocab_fits) == 3 and len(opt.vocab_fits) == 1
    rep = opt.optimize_report()
    assert rep["optimized"] is True
    assert rep["cse"]["merged_stages"] == 6  # 2 duplicate copies x 3 stages
    assert rep["cse"]["merged_vocabs"] == 2
    # every output's pack terminals now point at the shared buffers
    bufs = {tuple(po.buffers) for po in opt.pack}
    assert len(bufs) == 1
    # the input plan is untouched
    assert len(plan.stages) == 9 and plan.opt_info == {}


def test_cse_keeps_distinct_parameters_apart():
    """Same shape, different operator parameters -> NOT merged."""
    p = Pipeline(Schema.criteo_kaggle())
    d1 = p.dense("dense_*") | O.FillMissing(0.0) | O.Clamp(0.0, 50.0)
    d2 = p.dense("dense_*") | O.FillMissing(0.0) | O.Clamp(0.0, 99.0)
    p.output("a", [d1], dtype=np.float32)
    p.output("b", [d2], dtype=np.float32)
    opt = optimize_plan(_plan(p))
    assert opt.optimize_report()["cse"]["merged_stages"] == 0
    assert len(opt.stages) == 2
    # sources DO merge (same columns), stages don't
    assert opt.optimize_report()["cse"]["merged_sources"] == 1


def test_cse_dedupes_vocab_fit_pairs():
    """Identical value stream + capacity + min_count -> one VocabFit; a
    different min_count keeps its own fit."""
    p = Pipeline(Schema.criteo_kaggle())
    for name, mc in (("a", 1), ("b", 1), ("c", 2)):
        s = (p.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(512)
             | Vocab(512, min_count=mc))
        p.output(name, [s], dtype=np.int32)
    opt = optimize_plan(_plan(p))
    assert len(opt.vocab_fits) == 2  # min_count=1 pair merged, mc=2 kept
    assert opt.optimize_report()["cse"]["merged_vocabs"] == 1


# ---------------- pushdown (dead-stage elimination) --------------------------


def test_pushdown_drops_orphan_stage():
    """A stage outside the closure of outputs + fits is dropped before the
    legality passes see it (plan surgery / CSE can orphan producers)."""
    plan = _plan(_shared_prefix_pipeline(1))
    # structurally distinct ops, so CSE cannot fold it onto a live stage
    dead = dataclasses.replace(
        next(s for s in plan.stages if isinstance(s, FusedStage)),
        stage_id="s_dead", out_buf="orphan", ops=[O.Clamp(0.0, 123.0)])
    surgically = dataclasses.replace(plan, stages=plan.stages + [dead])
    surgically.buffers = dict(plan.buffers)
    surgically.buffers["orphan"] = dataclasses.replace(
        plan.buffers[dead.in_buf], name="orphan")
    opt = optimize_plan(surgically)
    assert "s_dead" not in [s.stage_id for s in opt.stages]
    assert "orphan" not in opt.buffers
    assert opt.optimize_report()["pushdown"]["dead_stages"] == 1
    # live stages and programs are unaffected
    assert all(dp.legal for dp in opt.dataflows)


def test_pushdown_recomputes_fit_closure():
    plan = _plan(_shared_prefix_pipeline(3))
    opt = optimize_plan(plan)
    # after CSE the fit closure references only surviving stage ids
    live = {s.stage_id for s in opt.stages}
    assert set(opt.fit_stage_ids) <= live
    assert len(opt.fit_stage_ids) < len(plan.fit_stage_ids)


# ---------------- grouping ---------------------------------------------------


def test_grouping_respects_budget():
    """Outputs that fit per-output but not merged stay solo-fused."""
    # pad each output to 512 f32 lanes: one packed tile is 512 KiB, so any
    # two outputs merged blow a 2 MiB dataflow budget while each fits alone
    p = _shared_prefix_pipeline(3, pad_cols_to=512)
    planner = Planner(p.graph, vmem_budget=1 << 20, lanes=8, vector_width=128)
    opt = optimize_plan(planner.plan(p._outputs))
    assert all(dp.legal for dp in opt.dataflows)
    assert opt.groups == []
    rep = opt.optimize_report()
    assert all("per-output fused" in v for v in rep["grouping"].values())


def test_grouping_reports_fallback_members():
    """Illegal outputs are excluded from groups with a classified reason."""
    p = paper_pipeline("III", large_vocab=2 ** 21)  # HBM table
    c = p.compile(backend="pallas", interpret=True)
    rep = c.optimize_report()
    assert rep["groups"] == [["dense", "label"]]
    assert "hbm-table" in rep["grouping"]["sparse"]


# ---------------- the acceptance scenario ------------------------------------


def test_grouped_lowering_acceptance(raw_batch):
    """≥3 outputs sharing a decode prefix, one VMEM budget: fewer kernels
    than outputs, shared prefix executes once per batch, and the grouped /
    per-output-fused / staged paths agree bit-for-bit."""
    variants = {
        "grouped": dict(fuse="auto", optimize="auto"),
        "solo": dict(fuse="auto", optimize="off"),
        "staged": dict(fuse="off", optimize="auto"),
    }
    outs, compiled = {}, {}
    for key, kw in variants.items():
        c = _shared_prefix_pipeline(3).compile(backend="pallas",
                                               interpret=True, **kw)
        c.fit(_fit_batches())
        outs[key] = {k: np.asarray(v) for k, v in c(raw_batch).items()}
        compiled[key] = c

    g = compiled["grouped"]
    n_out = len(g.plan.pack)
    assert n_out == 3
    # grouped lowering engaged: strictly fewer kernels than outputs
    assert g.traced_pallas_call_count(raw_batch) == 1 < n_out
    assert {v["path"] for v in g.lowering_report().values()} == {"grouped"}
    # shared prefix stages execute exactly once per batch under grouping...
    assert set(g.stage_execution_counts().values()) == {1}
    # ...whereas the unoptimized plan re-executes each duplicated copy
    solo = compiled["solo"]
    assert solo.traced_pallas_call_count(raw_batch) == n_out
    counts = solo.stage_execution_counts()
    assert len(counts) == 9 and set(counts.values()) == {1}  # 3 copies x 1

    # bit-identical across the whole fallback ladder
    for key in ("solo", "staged"):
        for name in outs["grouped"]:
            np.testing.assert_array_equal(outs["grouped"][name],
                                          outs[key][name],
                                          err_msg=f"{key}/{name}")
    # and pinned to the numpy oracle under the repo's float convention
    ref = _shared_prefix_pipeline(3).compile(backend="numpy")
    ref.fit(_fit_batches())
    for name, want in ref(raw_batch).items():
        got = outs["grouped"][name]
        if np.issubdtype(got.dtype, np.integer):
            np.testing.assert_array_equal(want, got)
        else:
            np.testing.assert_allclose(want, got, rtol=1e-5)


def test_grouping_solo_fused_counts_shared_stage_per_kernel(raw_batch):
    """With CSE on but grouping budget-blocked, the shared stage re-executes
    once per solo kernel — the counter the acceptance test relies on really
    distinguishes the lowerings."""
    p = _shared_prefix_pipeline(3, pad_cols_to=512)
    c = p.compile(backend="pallas", interpret=True, vmem_budget=1 << 20)
    assert {v["path"] for v in c.lowering_report().values()} == {"fused"}
    counts = c.stage_execution_counts()
    assert len(counts) == 3  # CSE still merged the duplicates
    assert set(counts.values()) == {3}  # each shared stage runs per kernel


# ---------------- fallback reasons (lowering_report taxonomy) ----------------


def test_budget_fallback_reason_kind():
    # vocab-free so the undersized budget can only trip the working-set
    # check (a vocab would re-place its table to HBM first)
    p = Pipeline(Schema.criteo_kaggle())
    p.output("out0", [p.dense("dense_*") | O.FillMissing(0.0)],
             dtype=np.float32)
    c = p.compile(backend="pallas", interpret=True, vmem_budget=1 << 10)
    rep = c.lowering_report()["out0"]
    assert rep["path"] == "staged" and rep["reason_kind"] == "budget"
    assert "working set" in rep["reason"] or "budget" in rep["reason"]


def test_hbm_fit_fallback_reason_kind():
    c = paper_pipeline("III", large_vocab=2 ** 21).compile(
        backend="pallas", interpret=True)
    (rep,) = c.fit_lowering_report().values()
    assert rep["path"] == "staged" and rep["reason_kind"] == "hbm-table"


def test_optimize_off_reports_unoptimized():
    c = _shared_prefix_pipeline(2).compile(backend="jnp", optimize="off")
    rep = c.optimize_report()
    assert rep["optimized"] is False
    assert rep["cse"]["merged_stages"] == 0 and rep["groups"] == []
    # the unoptimized plan still lowers every output legally
    assert len(c.plan.stages) == 6


def test_lookup_not_merged_across_different_vocab_params():
    p = Pipeline(Schema.criteo_kaggle())
    for name, cap in (("a", 512), ("b", 1024)):
        s = (p.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(512)
             | Vocab(cap))
        p.output(name, [s], dtype=np.int32)
    opt = optimize_plan(_plan(p))
    lookups = [s for s in opt.stages if isinstance(s, VocabLookupStage)]
    assert len(lookups) == 2 and len(opt.vocab_fits) == 2
    assert opt.optimize_report()["cse"]["merged_vocabs"] == 0
