"""ETL streaming runtime: staged prefetching executor, credit backpressure,
freshness, stop semantics, multi-tenancy, columnar storage."""

import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import paper_pipeline
from repro.core.schema import Schema
from repro.core.semantics import (BatchingPolicy, FreshnessPolicy,
                                  OrderingPolicy, PipelineSemantics)
from repro.data import columnar, synth
from repro.etl_runtime.multitenant import PipelineManager
from repro.data.source import Source
from repro.etl_runtime.runtime import (CreditQueue, SourcePrefetcher,
                                       StreamingExecutor, _STOPPED)


def _pipe(backend="jnp"):
    p = paper_pipeline("I", modulus=1024).compile(backend=backend)
    return p


# ---------------- stage machinery units ----------------

def test_credit_queue_backpressure_bounds_depth():
    """A put beyond capacity blocks until a get frees a credit."""
    stop = threading.Event()
    q = CreditQueue(2, stop)
    assert q.put("a") == 0 and q.put("b") == 0
    assert len(q) == 2
    done = threading.Event()

    def blocked_put():
        q.put("c")
        done.set()

    t = threading.Thread(target=blocked_put, daemon=True)
    t.start()
    time.sleep(0.2)
    assert not done.is_set()          # producer is credit-blocked
    assert len(q) == 2                # depth never exceeds capacity
    assert q.get() == "a"             # FIFO; frees one credit
    assert done.wait(1.0)             # blocked put completes
    assert len(q) == 2


def test_credit_queue_drops_oldest_first():
    stop = threading.Event()
    q = CreditQueue(2, stop)
    q.put("old"), q.put("mid")
    assert q.put("new", drop_oldest=True) == 1  # sheds exactly one
    assert len(q) == 2
    assert q.get() == "mid" and q.get() == "new"  # "old" was the casualty


def test_credit_queue_drop_oldest_drains_after_shrink():
    """A shrunk capacity drains the backlog on the next freshness put."""
    stop = threading.Event()
    q = CreditQueue(4, stop)
    for i in range(4):
        q.put(i)
    q.set_capacity(2)
    assert q.put(9, drop_oldest=True) == 3  # sheds down to the new bound
    assert len(q) == 2
    assert q.get() == 3 and q.get() == 9


def test_credit_queue_put_is_stop_aware():
    """A full queue can never deadlock shutdown (the seed sentinel bug)."""
    stop = threading.Event()
    q = CreditQueue(1, stop)
    q.put("x")
    t0 = time.perf_counter()
    stop.set()
    q.wake()
    assert q.put("y") is _STOPPED          # returns instead of hanging
    assert q.get() is _STOPPED
    assert time.perf_counter() - t0 < 1.0


def test_source_prefetcher_delivers_all_in_order():
    """The standalone read stage yields every batch in order and records
    read-stage occupancy (EtlJob.fit's ingest overlap path)."""
    batches = [{"i": np.full(4, k)} for k in range(7)]
    pf = SourcePrefetcher(Source.stream(lambda: iter(batches)), credits=2)
    got = list(pf)
    assert [int(b["i"][0]) for b in got] == list(range(7))
    assert pf.stats.items == 7
    pf.close()


def test_source_prefetcher_overlaps_reader_with_consumer():
    """While the consumer works on chunk k, the reader prefetches ahead —
    total wall time is max(read, consume), not the sum."""
    read_s, consume_s, n = 0.02, 0.02, 6

    def gen():
        for k in range(n):
            time.sleep(read_s)
            yield {"i": np.full(2, k)}

    pf = SourcePrefetcher(Source.stream(gen), credits=2)
    t0 = time.perf_counter()
    for _ in pf:
        time.sleep(consume_s)
    wall = time.perf_counter() - t0
    serial = n * (read_s + consume_s)
    assert wall < serial * 0.8, (wall, serial)  # reads hid behind consumes
    pf.close()


def test_source_prefetcher_error_reraises_at_consumer():
    def gen():
        yield {"i": np.zeros(2)}
        raise OSError("disk gone")

    pf = SourcePrefetcher(Source.stream(gen), credits=2)
    it = iter(pf)
    next(it)
    with pytest.raises(RuntimeError, match="fit read stage failed"):
        list(it)


def test_source_prefetcher_close_unblocks_full_queue():
    """close() is prompt even when the reader is parked on a full queue."""
    many = ({"i": np.zeros(2)} for _ in range(10_000))
    pf = SourcePrefetcher(Source.stream(lambda: many), credits=1)
    it = iter(pf)
    next(it)  # start the reader; it will fill the queue and block
    time.sleep(0.05)
    t0 = time.perf_counter()
    pf.close()
    assert time.perf_counter() - t0 < 2.0
    assert not pf._thread.is_alive()


def test_executor_backpressure_bounds_inflight():
    """With no consumer, delivered-batch count is bounded by credits."""
    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=8000, batch_size=1000), credits=2)
    ex.start()
    time.sleep(1.0)  # producer runs ahead while we don't consume
    assert ex.stats.produced <= 2  # ready queue holds at most `credits`
    assert all(d <= 2 for d in ex.queue_depths().values())
    for _ in ex:
        pass


# ---------------- executor behaviour ----------------

def test_executor_delivers_all_batches():
    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=5000, batch_size=1000), credits=2)
    n = 0
    for batch in ex:
        assert np.asarray(batch["dense"]).shape[0] == 1000
        n += 1
    assert n == 5 and ex.stats.produced == 5 and ex.stats.consumed == 5
    bd = ex.stats.stage_breakdown()
    assert set(bd) == {"read", "transform", "place", "deliver"}
    assert all(bd[s]["items"] == 5 for s in ("read", "transform", "place",
                                             "deliver"))
    assert bd["transform"]["busy_s"] > 0


@pytest.mark.slow
def test_freshness_drops_stale_batches():
    sem = PipelineSemantics(batching=BatchingPolicy(100),
                            freshness=FreshnessPolicy(max_staleness_batches=1))
    pipe = _pipe()
    ex = StreamingExecutor(pipe, synth.dataset_batches(
        "I", rows=6000, batch_size=1000), credits=1, semantics=sem)
    ex.start()
    time.sleep(1.5)  # consumer absent: stale batches must be dropped
    got = list(ex)
    assert ex.stats.dropped_stale >= 1
    assert len(got) + ex.stats.dropped_stale == ex.stats.produced


def test_stop_returns_promptly_mid_stream():
    """Regression (seed bug): stop() must not hang on full queues."""
    def endless():
        while True:
            yield next(synth.dataset_batches("I", rows=500, batch_size=500))

    ex = StreamingExecutor(_pipe(), endless(), credits=1)
    it = iter(ex)
    next(it)                     # pipeline is mid-stream, queues filling
    time.sleep(0.3)              # let every queue reach capacity
    t0 = time.perf_counter()
    ex.stop()
    assert time.perf_counter() - t0 < 0.5   # stop() itself is non-blocking
    assert ex.join(timeout=2.0)             # all stage threads exit promptly
    assert list(it) == []                   # consumer unblocks too


def test_stage_error_surfaces_to_consumer():
    """A raising stage fn stops the pipeline and re-raises, never hangs."""
    def bad_pipe(b):
        raise ValueError("malformed batch")

    ex = StreamingExecutor(bad_pipe, synth.dataset_batches(
        "I", rows=2000, batch_size=1000), credits=2)
    with pytest.raises(RuntimeError, match="stage failed") as ei:
        list(ex)
    assert isinstance(ei.value.__cause__, ValueError)
    assert ex.join(timeout=2.0)


def test_stop_without_consumer_is_prompt():
    """Seed deadlock shape: producer blocked on a full queue at stop time."""
    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=8000, batch_size=1000), credits=1)
    ex.start()
    time.sleep(0.5)              # no consumer: queues are full, stages blocked
    ex.stop()
    assert ex.join(timeout=2.0)


def test_overlap_improves_utilization():
    """Overlap hides a pinned ETL cost behind the train step (paper Fig 14).

    Formerly a pinned-sleep wall-clock test (0.03s ETL vs 0.05s train,
    zero-margin races on a loaded CI host); now the same per-batch costs
    run through the blocking-pipeline recurrence in tests/simclock.py, so
    both utilizations are EXACT and the test runs in microseconds:
    blocking = STEP/(STEP+ETL) vs overlapped = N*STEP/(fill + N*STEP).
    """
    from simclock import SimPipeline
    ETL_S, STEP_S, N = 0.03, 0.05, 64

    overlap = SimPipeline([ETL_S], [2], STEP_S).run(N)
    # ETL cheaper than the step: after the one-batch fill the trainer
    # never waits again, so the makespan is analytic to the last bit
    assert overlap.makespan == pytest.approx(ETL_S + N * STEP_S)
    assert overlap.starved() == 1            # only the very first delivery
    assert overlap.stage_busy_s[0] == pytest.approx(N * ETL_S)

    util_overlap = overlap.utilization
    util_block = STEP_S / (STEP_S + ETL_S)   # ETL inline between steps
    assert util_overlap == pytest.approx(
        N * STEP_S / (ETL_S + N * STEP_S))
    assert util_overlap - util_block >= 0.05  # >= 5pp, with margin to spare


@pytest.mark.slow
def test_straggler_skip():
    """A source that stalls beyond the timeout is skipped, not fatal."""
    def slow_source():
        yield next(synth.dataset_batches("I", rows=100, batch_size=100))
        time.sleep(0.8)  # straggler
        yield next(synth.dataset_batches("I", rows=100, batch_size=100, seed=1))

    ex = StreamingExecutor(_pipe(), slow_source(), credits=2,
                           read_timeout_s=0.2)
    got = list(ex)
    assert len(got) == 2  # both batches eventually arrive
    assert ex.stats.skipped_straggler >= 1  # but the stall was detected


# ---------------- ordering: bucket_by_length reorder window ----------------

def test_bucket_by_length_sorts_within_window():
    """The order stage emits ascending length inside each bounded window."""
    lens = [5, 1, 3, 2, 6, 4]

    def src():
        for n in lens:
            yield {"tokens": np.arange(1, n + 1, dtype=np.int32).reshape(1, n)}

    sem = PipelineSemantics(
        batching=BatchingPolicy(1),
        ordering=OrderingPolicy("bucket_by_length", reorder_window=3))
    ex = StreamingExecutor(lambda b: b, src(), semantics=sem, credits=2)
    got = [int(b["tokens"].shape[1]) for b in ex]
    # windows [5,1,3] and [2,6,4], each sorted ascending; windows stay FIFO
    assert got == [1, 3, 5, 2, 4, 6]
    bd = ex.stats.stage_breakdown()
    assert "order" in bd and bd["order"]["items"] == len(lens)
    assert ex.queue_depths().get("sorted") == 0


def test_fifo_ordering_has_no_order_stage():
    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=2000, batch_size=1000), credits=2)
    assert all(len(list(ex)) == 2 for _ in [0])
    assert "order" not in ex.stats.stages and "sorted" not in ex.queue_depths()


# ---------------- adaptive credits (occupancy-sized staging) ----------------

def test_adaptive_credits_grow_when_trainer_starves():
    """A starving consumer grows the staging budget up to max_credits."""
    def src(n=20):
        for i in range(n):
            yield {"x": np.full((4, 4), i, np.int32)}

    def slow_pipe(b):
        time.sleep(0.02)  # ETL slower than the (instant) consumer
        return b

    ex = StreamingExecutor(slow_pipe, src(), credits=2,
                           adaptive_credits=True, max_credits=4)
    assert sum(1 for _ in ex) == 20
    assert ex.current_credits == 4
    assert ex.stats.credit_grows == 2 and ex.stats.credit_shrinks == 0


def test_adaptive_credits_shrink_when_ready_sits_full():
    """Fast ETL + slow consumer reclaims a previously grown budget."""
    def src(n=24):
        for i in range(n):
            yield {"x": np.full((4, 4), i, np.int32)}

    ex = StreamingExecutor(lambda b: b, src(), credits=2,
                           adaptive_credits=True, max_credits=4)
    # simulate a prior grow phase, then consume slowly so the ready queue
    # refills to capacity before every pop
    ex.current_credits = 4
    for q in (ex._packed_q, ex._ready_q):
        q.set_capacity(4)
    for _ in ex:
        time.sleep(0.05)  # ETL (instant) keeps the queue full; no starvation
    assert ex.stats.credit_shrinks >= 1
    assert ex.current_credits < 4
    assert ex.current_credits >= ex.credits  # never below the floor


def test_adaptive_credits_disabled_keeps_budget_fixed():
    def src(n=10):
        for i in range(n):
            yield {"x": np.full((4, 4), i, np.int32)}

    ex = StreamingExecutor(lambda b: b, src(), credits=2)
    list(ex)
    assert ex.current_credits == 2
    assert ex.stats.credit_grows == 0 and ex.stats.credit_shrinks == 0


# ---------------- Prometheus-style metrics exposition ----------------

def test_stage_stats_prometheus_text(tmp_path):
    from repro.etl_runtime import metrics as metrics_lib

    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=3000, batch_size=1000), credits=2)
    assert len(list(ex)) == 3
    text = metrics_lib.stats_to_prometheus(ex.stats, labels={"tenant": "t0"})
    assert '# TYPE repro_etl_stage_items_total counter' in text
    assert 'repro_etl_stage_items_total{stage="transform",tenant="t0"} 3' in text
    assert 'repro_etl_produced_total{tenant="t0"} 3' in text
    assert 'repro_etl_stage_busy_seconds_total{stage="read"' in text
    # every emitted sample line parses as  name{labels} float
    for line in text.strip().splitlines():
        if line.startswith("#"):
            continue
        name_part, value = line.rsplit(" ", 1)
        float(value)
        assert name_part.startswith("repro_etl_")
    p = tmp_path / "metrics.prom"
    metrics_lib.write_metrics_file(str(p), text)
    assert p.read_text() == text


# ---------------- multi-tenant (weighted-credit policy) ----------------

def test_multitenant_concurrent_pipelines():
    mgr = PipelineManager()
    for i in range(3):
        mgr.add(f"t{i}", _pipe(),
                lambda i=i: synth.dataset_batches("I", rows=3000,
                                                  batch_size=1000, seed=i))
    res = mgr.run(n_batches=3)
    assert len(res) == 3
    assert all(r.batches == 3 for r in res.values())
    assert all(r.rows_per_s > 0 for r in res.values())
    # every tenant ran through the staged machinery
    assert all(r.stage_breakdown["transform"]["items"] >= 3
               for r in res.values())


def test_multitenant_weights_split_credit_budget():
    """Weights govern the staging-credit split across concurrent tenants."""
    mgr = PipelineManager(total_credits=6)
    mgr.add("heavy", _pipe(),
            lambda: synth.dataset_batches("I", rows=2000, batch_size=1000,
                                          seed=0), weight=2.0)
    mgr.add("light", _pipe(),
            lambda: synth.dataset_batches("I", rows=2000, batch_size=1000,
                                          seed=1), weight=1.0)
    assert mgr.credit_allocation() == {"heavy": 4, "light": 2}
    res = mgr.run(n_batches=2)
    assert res["heavy"].credits == 4 and res["light"].credits == 2
    assert res["heavy"].weight == 2.0
    assert all(r.batches == 2 for r in res.values())  # both made progress


def test_multitenant_swap_is_o1():
    mgr = PipelineManager()
    mgr.add("a", _pipe(), lambda: iter([]))
    new_pipe = _pipe()
    t0 = time.perf_counter()
    mgr.swap("a", new_pipe, lambda: iter([]))
    assert time.perf_counter() - t0 < 0.1  # partial-reconfiguration analogue
    with pytest.raises(KeyError):
        mgr.swap("missing", new_pipe, lambda: iter([]))


# ---------------- columnar storage ----------------

def test_columnar_roundtrip_and_selective_columns():
    schema = Schema.criteo_kaggle()
    batches = list(synth.dataset_batches("I", rows=2500, batch_size=1000))
    with tempfile.TemporaryDirectory() as d:
        man = columnar.write_dataset(d, schema, iter(batches))
        assert man["rows"] == 2500 and len(man["shards"]) == 3
        assert columnar.load_schema(d)["dense_0"].kind == "dense"
        # full roundtrip
        back = list(columnar.iter_shards(d))
        for a, b in zip(batches, back):
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])
        # selective column access
        only = next(columnar.iter_shards(d, columns=["label", "dense_0"]))
        assert set(only) == {"label", "dense_0"}
        # re-batching
        rb = list(columnar.iter_batches(d, 600))
        assert all(next(iter(b.values())).shape[0] == 600 for b in rb)
        assert len(rb) == 4  # 2500 // 600, remainder dropped
