"""ETL streaming runtime: overlap, backpressure, freshness, multi-tenancy,
columnar storage."""

import os
import tempfile
import time

import numpy as np
import pytest

from repro.core.pipeline import paper_pipeline
from repro.core.schema import Schema
from repro.core.semantics import (BatchingPolicy, FreshnessPolicy,
                                  OrderingPolicy, PipelineSemantics)
from repro.data import columnar, synth
from repro.etl_runtime.multitenant import PipelineManager
from repro.etl_runtime.runtime import StreamingExecutor


def _pipe(backend="jnp"):
    p = paper_pipeline("I", modulus=1024).compile(backend=backend)
    return p


def test_executor_delivers_all_batches():
    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=5000, batch_size=1000), credits=2)
    n = 0
    for batch in ex:
        assert np.asarray(batch["dense"]).shape[0] == 1000
        n += 1
    assert n == 5 and ex.stats.produced == 5 and ex.stats.consumed == 5


def test_backpressure_bounds_queue():
    """Slow consumer: the producer must block on credits (bounded memory)."""
    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=8000, batch_size=1000), credits=2)
    ex.start()
    time.sleep(1.0)  # producer runs ahead while we don't consume
    # it can have produced at most credits + 1 in-flight batches
    assert ex.stats.produced <= 4
    for _ in ex:
        pass


def test_freshness_drops_stale_batches():
    sem = PipelineSemantics(batching=BatchingPolicy(100),
                            freshness=FreshnessPolicy(max_staleness_batches=1))
    pipe = _pipe()
    ex = StreamingExecutor(pipe, synth.dataset_batches(
        "I", rows=6000, batch_size=1000), credits=1, semantics=sem)
    ex.start()
    time.sleep(1.5)  # consumer absent: stale batches must be dropped
    got = list(ex)
    assert ex.stats.dropped_stale >= 1
    assert len(got) + ex.stats.dropped_stale == ex.stats.produced


def test_overlap_improves_utilization():
    """Trainer utilization with overlap >= without (the paper's Fig 14)."""
    def consume(executor, step_s):
        t0 = time.perf_counter()
        train = 0.0
        for b in executor:
            ts = time.perf_counter()
            time.sleep(step_s)
            train += time.perf_counter() - ts
        return train / (time.perf_counter() - t0)

    # overlapped: ETL runs in the producer thread while we "train"
    ex = StreamingExecutor(_pipe(), synth.dataset_batches(
        "I", rows=6000, batch_size=1000), credits=2)
    util_overlap = consume(ex, 0.05)
    # blocking: ETL inline between steps
    pipe = _pipe()
    t0 = time.perf_counter()
    train = 0.0
    for raw in synth.dataset_batches("I", rows=6000, batch_size=1000):
        _ = {k: np.asarray(v) for k, v in pipe(raw).items()}
        ts = time.perf_counter()
        time.sleep(0.05)
        train += time.perf_counter() - ts
    util_block = train / (time.perf_counter() - t0)
    assert util_overlap > util_block


def test_multitenant_concurrent_pipelines():
    mgr = PipelineManager()
    for i in range(3):
        mgr.add(f"t{i}", _pipe(),
                lambda i=i: synth.dataset_batches("I", rows=3000,
                                                  batch_size=1000, seed=i))
    res = mgr.run(n_batches=3)
    assert len(res) == 3
    assert all(r.batches == 3 for r in res.values())
    assert all(r.rows_per_s > 0 for r in res.values())


def test_multitenant_swap_is_o1():
    mgr = PipelineManager()
    mgr.add("a", _pipe(), lambda: iter([]))
    new_pipe = _pipe()
    t0 = time.perf_counter()
    mgr.swap("a", new_pipe, lambda: iter([]))
    assert time.perf_counter() - t0 < 0.1  # partial-reconfiguration analogue
    with pytest.raises(KeyError):
        mgr.swap("missing", new_pipe, lambda: iter([]))


def test_columnar_roundtrip_and_selective_columns():
    schema = Schema.criteo_kaggle()
    batches = list(synth.dataset_batches("I", rows=2500, batch_size=1000))
    with tempfile.TemporaryDirectory() as d:
        man = columnar.write_dataset(d, schema, iter(batches))
        assert man["rows"] == 2500 and len(man["shards"]) == 3
        assert columnar.load_schema(d)["dense_0"].kind == "dense"
        # full roundtrip
        back = list(columnar.iter_shards(d))
        for a, b in zip(batches, back):
            for k in a:
                np.testing.assert_array_equal(a[k], b[k])
        # selective column access
        only = next(columnar.iter_shards(d, columns=["label", "dense_0"]))
        assert set(only) == {"label", "dense_0"}
        # re-batching
        rb = list(columnar.iter_batches(d, 600))
        assert all(next(iter(b.values())).shape[0] == 600 for b in rb)
        assert len(rb) == 4  # 2500 // 600, remainder dropped


def test_straggler_skip():
    """A source that stalls beyond the timeout is skipped, not fatal."""
    def slow_source():
        yield next(synth.dataset_batches("I", rows=100, batch_size=100))
        time.sleep(0.8)  # straggler
        yield next(synth.dataset_batches("I", rows=100, batch_size=100, seed=1))

    ex = StreamingExecutor(_pipe(), slow_source(), credits=2,
                           read_timeout_s=0.2)
    got = list(ex)
    assert len(got) == 2  # both batches eventually arrive
    assert ex.stats.skipped_straggler >= 1  # but the stall was detected
