"""Per-operator semantics: numpy oracle == jnp expression, edge cases."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as O

RNG = np.random.default_rng(0)


def check_op(op, x, **kw):
    want = op.numpy(x)
    got = np.asarray(op.jnp_expr(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, rtol=1e-6, **kw)


def test_clamp_basic():
    x = np.array([-5.0, -0.0, 0.5, 99.0], np.float32)
    check_op(O.Clamp(0.0), x)
    assert O.Clamp(0.0).numpy(x).min() == 0.0


def test_clamp_hi():
    x = RNG.normal(size=(100,)).astype(np.float32) * 10
    op = O.Clamp(0.0, 5.0)
    assert op.numpy(x).max() <= 5.0
    check_op(op, x)


def test_logarithm():
    x = np.array([0.0, 999.0, 1e-9], np.float32)
    out = O.Logarithm().numpy(x)
    np.testing.assert_allclose(out[1], np.log(1000.0), rtol=1e-6)
    check_op(O.Logarithm(), x)


def test_fill_missing_float():
    x = np.array([3.2, np.nan, -1.0], np.float32)
    out = O.FillMissing(0.0).numpy(x)
    np.testing.assert_allclose(out, np.array([3.2, 0.0, -1.0], np.float32),
                               rtol=1e-6)
    check_op(O.FillMissing(0.0), x)


def test_fill_missing_int():
    x = np.array([7, O.INT_MISSING, -3], np.int32)
    out = O.FillMissing(5).numpy(x)
    np.testing.assert_array_equal(out, [7, 5, -3])
    check_op(O.FillMissing(5), x)


def test_bucketize_paper_example():
    # paper: x=37, bins=[10,20,40] -> bin 3  (wait: 37 >= 10, >= 20, < 40 -> 2)
    op = O.Bucketize([10, 20, 40])
    assert op.numpy(np.array([37.0], np.float32))[0] == 2
    assert op.numpy(np.array([45.0], np.float32))[0] == 3
    assert op.numpy(np.array([5.0], np.float32))[0] == 0
    check_op(op, RNG.normal(size=(64,)).astype(np.float32) * 30)


def test_bucketize_unsorted_raises():
    with pytest.raises(ValueError):
        O.Bucketize([10, 5])


def test_onehot_paper_example():
    # bin=3, K=5 -> [0,0,0,1,0]
    op = O.OneHot(5)
    out = op.numpy(np.array([[3]], np.int64))
    np.testing.assert_array_equal(out[0], [0, 0, 0, 1, 0])
    x = RNG.integers(0, 5, size=(16, 2)).astype(np.int32)
    check_op(op, x)


def test_onehot_out_of_range_all_zero():
    out = O.OneHot(4).numpy(np.array([[7]], np.int64))
    assert out.sum() == 0


def test_hex2int_paper_example():
    # "0x1a3f" -> 6719 (without the 0x prefix, width 4)
    op = O.Hex2Int(4)
    x = np.frombuffer(b"1a3f", np.uint8).reshape(1, 1, 4)
    assert op.numpy(x)[0, 0] == 0x1A3F == 6719
    got = np.asarray(op.jnp_expr(jnp.asarray(x)))
    assert got[0, 0] == 6719


def test_hex2int_case_and_overflow():
    op = O.Hex2Int(8)
    for s, want in [(b"ffffffff", -1), (b"FFFFFFFF", -1),
                    (b"80000000", -(2 ** 31)), (b"7fffffff", 2 ** 31 - 1)]:
        x = np.frombuffer(s, np.uint8).reshape(1, 1, 8)
        assert op.numpy(x)[0, 0] == want, s
        assert np.asarray(op.jnp_expr(jnp.asarray(x)))[0, 0] == want, s


def test_hex2int_missing_sentinel():
    x = np.zeros((1, 1, 8), np.uint8)  # all-zero string = missing
    assert O.Hex2Int(8).numpy(x)[0, 0] == O.INT_MISSING


def test_modulus_paper_example():
    op = O.Modulus(5)
    assert op.numpy(np.array([-7], np.int32))[0] == 3
    x = RNG.integers(-(2 ** 31), 2 ** 31 - 1, size=(1000,)).astype(np.int32)
    out = op.numpy(x)
    assert out.min() >= 0 and out.max() < 5
    check_op(op, x)


def test_sigrid_hash_range_and_determinism():
    op = O.SigridHash(1000)
    x = RNG.integers(-(2 ** 31), 2 ** 31 - 1, size=(5000,)).astype(np.int32)
    out = op.numpy(x)
    assert out.min() >= 0 and out.max() < 1000
    np.testing.assert_array_equal(out, op.numpy(x))  # deterministic
    check_op(op, x)
    # distribution sanity: all buckets of a small mod get hit
    small = O.SigridHash(8).numpy(x)
    assert len(np.unique(small)) == 8


def test_cartesian_binary():
    op = O.Cartesian(m=997)
    a = RNG.integers(0, 1000, size=(500,)).astype(np.int32)
    b = RNG.integers(0, 1000, size=(500,)).astype(np.int32)
    out = op.numpy2(a, b)
    assert out.min() >= 0 and out.max() < 997
    got = np.asarray(op.jnp_expr2(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_array_equal(got, out)
    # asymmetric: cross(a,b) != cross(b,a) in general
    assert not np.array_equal(out, op.numpy2(b, a))


def test_vocab_gen_first_appearance_order():
    vg = O.VocabGen(capacity=16)
    st = vg.init_state()
    st = vg.update(st, np.array([5, 3, 5, 7, 3, 0], np.int32), 0)
    table = vg.finalize(st)
    # 5 seen first -> 0; 3 -> 1; 7 -> 2; 0 -> 3
    assert table[5] == 0 and table[3] == 1 and table[7] == 2 and table[0] == 3
    assert O.VocabGen.n_unique(table) == 4
    assert (table == -1).sum() == 12


def test_vocab_gen_rejects_out_of_range():
    vg = O.VocabGen(capacity=4)
    with pytest.raises(ValueError):
        vg.update(vg.init_state(), np.array([9], np.int32), 0)


def test_vocab_map_oov():
    vg = O.VocabGen(capacity=8)
    st = vg.update(vg.init_state(), np.array([1, 2], np.int32), 0)
    table = vg.finalize(st)
    vm = O.VocabMap(8)
    out = vm.numpy_apply(np.array([[1, 2, 5]], np.int32), table)
    np.testing.assert_array_equal(out, [[0, 1, 2]])  # 5 unseen -> OOV == 2


def test_vocab_gen_frequency_filter():
    """min_count drops rare values (paper §3.2.2 frequency-based filtering):
    they vanish from the table and map to OOV at apply time."""
    vg = O.VocabGen(capacity=16, min_count=2)
    st = vg.init_state()
    st = vg.update(st, np.array([5, 3, 5, 7, 3, 5], np.int32), 0)
    table = vg.finalize(st)
    # 5 (x3) and 3 (x2) survive in first-appearance order; 7 (x1) filtered
    assert table[5] == 0 and table[3] == 1 and table[7] == -1
    assert O.VocabGen.n_unique(table) == 2
    out = O.VocabMap(16).numpy_apply(np.array([[5, 3, 7]], np.int32), table)
    np.testing.assert_array_equal(out, [[0, 1, 2]])  # 7 -> OOV (== n_unique)


def test_vocab_gen_min_count_one_keeps_all():
    vg1 = O.VocabGen(capacity=8, min_count=1)
    vg0 = O.VocabGen(capacity=8)
    x = np.array([1, 2, 2, 4], np.int32)
    t1 = vg1.finalize(vg1.update(vg1.init_state(), x, 0))
    t0 = vg0.finalize(vg0.update(vg0.init_state(), x, 0))
    np.testing.assert_array_equal(t1, t0)
