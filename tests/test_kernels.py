"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret=True)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import operators as O
from repro.kernels import ops, ref

RNG = np.random.default_rng(7)
HEXMAP = np.frombuffer(b"0123456789abcdef", np.uint8)


@pytest.mark.parametrize("rows,cols", [(8, 13), (100, 26), (257, 5), (1024, 128)])
@pytest.mark.parametrize("dtype", [np.float32])
def test_fused_dense_sweep(rows, cols, dtype):
    x = (RNG.normal(size=(rows, cols)) * 10).astype(dtype)
    clamp, log = O.Clamp(0.0), O.Logarithm()
    chain = lambda v: log.jnp_expr(clamp.jnp_expr(v))
    fn = ops.fused_stage(chain, in_dtype=dtype, out_dtype=dtype,
                         interpret=True)
    got = np.asarray(fn(jnp.asarray(x)))
    want = np.asarray(ref.fused_chain(jnp.asarray(x), chain))
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("rows,cols,width", [(64, 26, 8), (100, 3, 4), (8, 1, 8)])
def test_fused_hex_sweep(rows, cols, width):
    digits = RNG.integers(0, 16, size=(width, rows, cols))
    raw = HEXMAP[digits]
    mod = O.Modulus(4096)
    chain = lambda v: mod.jnp_expr(ref.hex2int_digit_major(v))
    fn = ops.fused_stage(chain, in_dtype=np.uint8, out_dtype=np.int32,
                         hex_width=width, interpret=True)
    got = np.asarray(fn(jnp.asarray(raw)))
    # oracle: trailing-hex layout numpy
    want = mod.numpy(O.Hex2Int(width).numpy(np.moveaxis(raw, 0, -1)))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("cap,parts", [(64, 1), (64, 4), (256, 8), (512, 2)])
@pytest.mark.parametrize("n", [1, 100, 5000])
def test_vocab_build_sweep(cap, parts, n):
    vals = RNG.integers(0, cap, size=(n,)).astype(np.int32)
    got = np.asarray(ops.vocab_build_chunk(jnp.asarray(vals), capacity=cap,
                                           partitions=parts, interpret=True))
    want = np.asarray(ref.vocab_build_chunk(jnp.asarray(vals), cap))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("rows,width,cap", [(8, 3, 64), (100, 7, 128),
                                            (257, 1, 32)])
@pytest.mark.parametrize("partitions", [1, 4])
def test_fit_dataflow_matches_staged_build(rows, width, cap, partitions):
    """Fused fit kernel == staged build kernel + counts oracle, including
    out-of-range values: negatives and >= capacity drop on both paths
    (regression: JAX scatter index normalization must not wrap -1 to the
    last table slot).  Partitioned accumulators agree with partitions=1."""
    from repro.kernels.dataflow import StreamInput, make_fit_dataflow

    vals = RNG.integers(0, cap, size=(rows, width)).astype(np.int32)
    vals.reshape(-1)[:: max(1, vals.size // 7)] = -1       # missing ids
    if vals.size > 3:
        vals.reshape(-1)[1] = cap + 5                      # overflow id
    fn = make_fit_dataflow([StreamInput("v", width, np.dtype(np.int32))],
                           [], "v", cap, partitions=partitions,
                           interpret=True)
    got_fp, got_cnt = (np.asarray(a) for a in fn(jnp.asarray(vals)))
    flat = vals.reshape(-1)
    want_fp = np.full(cap, 2 ** 31 - 1, np.int32)
    want_cnt = np.zeros(cap, np.int32)
    for i, v in enumerate(flat):
        if 0 <= v < cap:
            want_fp[v] = min(want_fp[v], i)
            want_cnt[v] += 1
    np.testing.assert_array_equal(got_fp, want_fp)
    np.testing.assert_array_equal(got_cnt, want_cnt)
    # the staged Pallas build drops out-of-range values too: bit-equal
    staged = np.asarray(ops.vocab_build_chunk(
        jnp.asarray(flat), capacity=cap, partitions=1, interpret=True))
    np.testing.assert_array_equal(got_fp, staged)


@pytest.mark.parametrize("rows,cols,cap,parts", [(8, 3, 64, 4), (100, 26, 128, 1),
                                                 (33, 7, 256, 8)])
def test_vocab_lookup_sweep(rows, cols, cap, parts):
    vals = RNG.integers(0, cap, size=(500,)).astype(np.int32)
    vg = O.VocabGen(cap)
    table = vg.finalize(vg.update(vg.init_state(), vals, 0))
    n = O.VocabGen.n_unique(table)
    x = RNG.integers(0, cap, size=(rows, cols)).astype(np.int32)
    got = np.asarray(ops.vocab_lookup(jnp.asarray(x), jnp.asarray(table), n,
                                      partitions=parts, interpret=True))
    want = np.asarray(ref.vocab_lookup(jnp.asarray(x), jnp.asarray(table), n))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("widths,out_dtype", [
    ([13, 26], np.float32), ([1], np.float32), ([5, 7, 11], np.int32)])
@pytest.mark.parametrize("rows", [8, 100])
def test_packer_sweep(widths, out_dtype, rows):
    blocks = [(RNG.normal(size=(rows, w)) * 3).astype(np.float32)
              for w in widths]
    pk = ops.packer(widths, [np.float32] * len(widths), out_dtype,
                    pad_cols_to=128, interpret=True)
    got = np.asarray(pk(*[jnp.asarray(b) for b in blocks]))
    want = np.asarray(ref.pack_blocks([jnp.asarray(b) for b in blocks],
                                      out_dtype, 128))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert got.shape[1] % 128 == 0


@pytest.mark.parametrize("rows", [8, 100, 257])
@pytest.mark.parametrize("pad_to", [8, 32])
def test_output_dataflow_sweep(rows, pad_to):
    """One streaming kernel = chain + hex decode + lookup + pack epilogue."""
    from repro.kernels.dataflow import StreamInput, TableInput, TileStep

    dense = (RNG.normal(size=(rows, 5)) * 10).astype(np.float32)
    digits = RNG.integers(0, 16, size=(4, rows, 3))
    hexraw = HEXMAP[digits]
    cap = 64
    vals = RNG.integers(0, cap, size=(500,)).astype(np.int32)
    vg = O.VocabGen(cap)
    table = vg.finalize(vg.update(vg.init_state(), vals, 0))
    n_uniq = O.VocabGen.n_unique(table)

    clamp, log, mod = O.Clamp(0.0), O.Logarithm(), O.Modulus(cap)
    dense_chain = lambda v: log.jnp_expr(clamp.jnp_expr(v))
    hex_chain = lambda v: mod.jnp_expr(ref.hex2int_digit_major(v))

    fn = ops.output_dataflow(
        inputs=[StreamInput("d", 5, np.dtype(np.float32)),
                StreamInput("h", 3, np.dtype(np.uint8), hex_width=4)],
        tables=[TableInput("v0", cap)],
        steps=[TileStep("map", "dlog", ("d",), fn=dense_chain),
               TileStep("map", "hid", ("h",), fn=hex_chain),
               TileStep("lookup", "hrank", ("hid",), table=0)],
        terminals=[("dlog", 5), ("hrank", 3)],
        out_dtype=np.float32, pad_cols_to=pad_to, interpret=True)
    # the compiler folds OOV into the table before the call
    resolved = np.where(table >= 0, table, n_uniq).astype(np.int32)
    got = np.asarray(fn(jnp.asarray(dense), jnp.asarray(hexraw),
                        jnp.asarray(resolved).reshape(1, -1)))

    want_d = np.asarray(dense_chain(jnp.asarray(dense)))
    want_ids = mod.numpy(O.Hex2Int(4).numpy(np.moveaxis(hexraw, 0, -1)))
    want_r = np.asarray(ref.vocab_lookup(jnp.asarray(want_ids),
                                         jnp.asarray(table), n_uniq))
    want = np.asarray(ref.pack_blocks(
        [jnp.asarray(want_d), jnp.asarray(want_r)], np.float32, pad_to))
    assert got.shape == (rows, -(-8 // pad_to) * pad_to)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("vocab,dim,batch,nnz,parts", [
    (64, 16, 33, 5, 4), (128, 32, 8, 1, 1), (256, 8, 100, 7, 8)])
def test_embedding_bag_sweep(vocab, dim, batch, nnz, parts):
    tbl = RNG.normal(size=(vocab, dim)).astype(np.float32)
    idx = RNG.integers(0, vocab, size=(batch, nnz)).astype(np.int32)
    got = np.asarray(ops.embedding_bag(jnp.asarray(tbl), jnp.asarray(idx),
                                       partitions=parts, interpret=True))
    want = np.asarray(ref.embedding_bag(jnp.asarray(tbl), jnp.asarray(idx)))
    # partition accumulation reorders the f32 sums
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("vocab,parts", [
    (67, 4),    # vocab does not divide partitions: last partition padded
    (100, 8),   # 100 // 8 leaves a ragged tail
    (33, 1)])
def test_embedding_bag_padded_partition(vocab, parts):
    """Arbitrary vocab sizes work with partitions > 1 (the wrapper pads the
    last partition; padded rows are unreachable)."""
    tbl = RNG.normal(size=(vocab, 12)).astype(np.float32)
    idx = RNG.integers(0, vocab, size=(50, 4)).astype(np.int32)
    got = np.asarray(ops.embedding_bag(jnp.asarray(tbl), jnp.asarray(idx),
                                       partitions=parts, interpret=True))
    want = np.asarray(ref.embedding_bag(jnp.asarray(tbl), jnp.asarray(idx)))
    # gather-then-pool structure: identical rows, identical sum order
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("batch,nnz,block_batch", [
    (33, 5, 8),    # batch not a multiple of block_batch
    (7, 1, 128),   # nnz=1, tiny batch below the block
    (129, 3, 128)])  # one full block + a remainder row
def test_embedding_bag_sentinels_and_ragged_batch(batch, nnz, block_batch):
    """-1 sentinel indices contribute zero (incl. fully-empty bags) and
    batch padding never leaks into the output."""
    from repro.kernels import embedding_bag as bag
    vocab = 90
    tbl = RNG.normal(size=(vocab, 16)).astype(np.float32)
    idx = RNG.integers(0, vocab, size=(batch, nnz)).astype(np.int32)
    idx[RNG.random(idx.shape) < 0.3] = -1
    idx[0, :] = -1  # an entirely-empty bag pools to the zero vector
    got = np.asarray(bag.embedding_bag(jnp.asarray(tbl), jnp.asarray(idx),
                                       partitions=3, block_batch=block_batch,
                                       interpret=True))
    want = np.asarray(ref.embedding_bag(jnp.asarray(tbl), jnp.asarray(idx)))
    assert got.shape == (batch, 16)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got[0], 0.0)


@pytest.mark.parametrize("parts", [1, 4])
@pytest.mark.parametrize("staged", [False, True])
def test_embedding_bag_cached_bit_equal_to_uncached(parts, staged):
    """Two-level cached kernel == uncached kernel, bit for bit, when the
    cache rows mirror the table rows the remap assigned (the lookahead
    stage's invariant).  ``staged`` covers the single-pass fast path."""
    vocab, dim, batch, nnz, cache_rows = 150, 8, 40, 6, 32
    tbl = RNG.normal(size=(vocab, dim)).astype(np.float32)
    idx = RNG.integers(0, vocab, size=(batch, nnz)).astype(np.int32)
    idx[RNG.random(idx.shape) < 0.1] = -1

    if staged:
        # stage EVERY distinct row into an ext cache: cold_idx=None
        uniq = np.unique(idx[idx >= 0])
        cache = tbl[uniq]
        slot_of = np.full(vocab, -1, np.int64)
        slot_of[uniq] = np.arange(len(uniq))
        slot = np.where(idx >= 0, slot_of[idx.clip(min=0)], -1).astype(np.int32)
        cold = None
    else:
        hot = RNG.choice(vocab, size=cache_rows, replace=False)
        cache = tbl[hot]
        slot_of = np.full(vocab, -1, np.int64)
        slot_of[hot] = np.arange(cache_rows)
        slot = np.where(idx >= 0, slot_of[idx.clip(min=0)], -1).astype(np.int32)
        cold = np.where(slot < 0, idx, -1).astype(np.int32)

    got = np.asarray(ops.embedding_bag_cached(
        jnp.asarray(tbl), jnp.asarray(cache), jnp.asarray(slot),
        None if cold is None else jnp.asarray(cold),
        partitions=parts, interpret=True))
    want = np.asarray(ops.embedding_bag(jnp.asarray(tbl), jnp.asarray(idx),
                                        partitions=parts, interpret=True))
    np.testing.assert_array_equal(got, want)
    want_ref = np.asarray(ref.embedding_bag_cached(
        jnp.asarray(tbl), jnp.asarray(cache), jnp.asarray(slot),
        None if cold is None else jnp.asarray(cold)))
    np.testing.assert_array_equal(got, want_ref)


def test_flash_attention_matches_dense():
    from repro.models import layers as L
    B, S, H, D = 2, 128, 2, 16
    q, k, v = (jnp.asarray(RNG.normal(size=(B, S, H, D)).astype(np.float32))
               for _ in range(3))
    qp = kp = jnp.arange(S)
    for causal, window in [(True, 0), (True, 32), (False, 0)]:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(D)
        s = s + L._mask_from_positions(qp, kp, causal, window)
        want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
        got = L.flash_attention(q, k, v, qp, kp, causal=causal, window=window,
                                q_chunk=32, k_chunk=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)
