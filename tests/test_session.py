"""EtlJob session facade: lifecycle, projection pushdown, host-side
length keys, weighted round-robin transform service, adaptive raw-queue
resize, bit-equality of the facade path with the direct path."""

import tempfile
import time

import numpy as np
import pytest

from repro.core.operators import Clamp, FillMissing, Logarithm
from repro.core.pipeline import Pipeline, paper_pipeline
from repro.core.schema import Schema
from repro.core.semantics import (BatchingPolicy, FreshnessPolicy,
                                  OrderingPolicy, PipelineSemantics)
from repro.data import columnar, synth
from repro.data.source import Source
from repro.etl_runtime.multitenant import (PipelineManager, TransformService,
                                           WeightedRoundRobin)
from repro.etl_runtime.runtime import StreamingExecutor
from repro.session import EtlJob


@pytest.fixture(scope="module")
def dataset_dir():
    with tempfile.TemporaryDirectory() as d:
        columnar.write_dataset(
            d, Schema.criteo_kaggle(),
            synth.dataset_batches("I", rows=2000, batch_size=500))
        yield d


# ---------------- lifecycle ----------------

def test_job_compile_fit_batches_stats():
    job = EtlJob(paper_pipeline("II", small_vocab=512, batch_size=500),
                 Source.synth("I", rows=2000, batch_size=500, seed=2),
                 backend="jnp",
                 fit_source=Source.synth("I", rows=1000, batch_size=500))
    job.fit()
    assert max(job.state.n_unique.values()) > 0
    with job.batches() as batches:
        n = sum(1 for _ in batches)
    assert n == 4
    s = job.stats()
    assert s is not None and s.consumed == 4
    assert s.stage_breakdown()["transform"]["items"] == 4


def test_job_semantics_flow_from_template():
    """Pipeline-template semantics reach the executor without re-wiring."""
    p = Pipeline(Schema.lm_events(8), batch_size=4,
                 ordering=OrderingPolicy("bucket_by_length",
                                         reorder_window=2))
    t = p.tokens("tokens_raw")
    p.output("tokens", [t], dtype=np.int32)
    job = EtlJob(p, Source.lm_events(8, rows=16, batch_size=4),
                 backend="jnp")
    with job.batches() as ex:
        list(ex)
    assert "order" in job.stats().stages  # order stage came from the template


def test_job_semantics_override():
    job = EtlJob(paper_pipeline("I", modulus=256, batch_size=100),
                 Source.synth("I", rows=200, batch_size=100),
                 backend="jnp",
                 freshness=FreshnessPolicy(max_staleness_batches=1))
    assert job.semantics.freshness.online
    assert job.semantics.ordering.kind == "fifo"  # untouched policy kept


def test_job_metrics_file_written_on_close(tmp_path):
    path = str(tmp_path / "etl.prom")
    job = EtlJob(paper_pipeline("I", modulus=256, batch_size=100),
                 Source.synth("I", rows=300, batch_size=100),
                 backend="jnp", metrics_file=path,
                 metrics_labels={"tenant": "t0"})
    with job.batches() as ex:
        assert len(list(ex)) == 3
    text = (tmp_path / "etl.prom").read_text()
    assert 'repro_etl_consumed_total{tenant="t0"} 3' in text


def test_job_rebatch_to_batching_policy():
    """rebatch=True decouples source batch geometry from BatchingPolicy."""
    job = EtlJob(paper_pipeline("I", modulus=256, batch_size=500),
                 Source.synth("I", rows=2000, batch_size=800, seed=1),
                 backend="jnp", rebatch=True)
    with job.batches() as ex:
        sizes = [int(np.asarray(b["dense"]).shape[0]) for b in ex]
    assert sizes == [500, 500, 500, 500]  # policy drops the remainder


def test_job_rejects_non_pipeline():
    with pytest.raises(TypeError):
        EtlJob(42, Source.synth("I", rows=100, batch_size=100))


# ---------------- projection pushdown (acceptance criterion) ----------------

def _dense_only_pipeline() -> Pipeline:
    p = Pipeline(Schema.criteo_kaggle(), batch_size=500)
    d = p.dense("dense_*") | FillMissing(0.0) | Clamp(0.0) | Logarithm()
    p.output("dense", [d], dtype=np.float32, pad_cols_to=16)
    return p


def test_pushdown_projects_source_to_referenced_columns(dataset_dir):
    job = EtlJob(_dense_only_pipeline(), Source.columnar(dataset_dir),
                 backend="jnp")
    eff = job.apply_source()
    assert eff.spec.columns == tuple(f"dense_{i}" for i in range(13))
    raw = next(iter(eff))
    assert set(raw) == set(eff.spec.columns)  # no label / sparse columns
    out = job.apply(raw)
    assert out["dense"].shape == (500, 16)


def test_pushdown_skipped_when_host_length_key_present():
    """A host length key may read columns the pipeline never references;
    auto projection must not strip them out from under the key fn."""
    job = EtlJob(_dense_only_pipeline(),
                 Source.synth("I", rows=1000, batch_size=500).length_key(
                     lambda raw: float(raw["sparse_0"][0, 0])),
                 backend="jnp",
                 ordering=OrderingPolicy("bucket_by_length",
                                         reorder_window=2))
    assert job.apply_source().spec.columns is None  # projection skipped
    with job.batches() as ex:
        assert len(list(ex)) == 2  # key fn saw sparse_0; no KeyError


def test_pushdown_respects_explicit_projection(dataset_dir):
    explicit = Source.columnar(dataset_dir).columns(
        [f"dense_{i}" for i in range(13)] + ["label"])
    job = EtlJob(_dense_only_pipeline(), explicit, backend="jnp")
    assert job.apply_source().spec.columns == explicit.spec.columns


def test_fit_projection_is_vocab_closure_only():
    job = EtlJob(paper_pipeline("II", small_vocab=512, batch_size=500),
                 backend="jnp")
    cols = job.compiled.plan.fit_referenced_columns()
    assert cols == [f"sparse_{i}" for i in range(26)]
    assert len(job.compiled.plan.referenced_columns()) == 40


def test_facade_output_bit_equal_to_direct_path(dataset_dir):
    """Acceptance: the fused pallas apply through EtlJob + projected
    columnar Source is bit-equal to the direct pre-refactor call path on
    the Criteo-shaped dataset."""
    direct = paper_pipeline("I", modulus=512,
                            batch_size=500).compile(backend="pallas")
    job = EtlJob(paper_pipeline("I", modulus=512, batch_size=500),
                 Source.columnar(dataset_dir), backend="pallas")
    assert any(r["path"] in ("fused", "grouped")
               for r in job.lowering_report().values())
    raw_full = next(columnar.iter_batches(dataset_dir, 500))
    via_direct = direct(raw_full)
    via_job = job.apply(next(iter(job.apply_source().rebatch(500))))
    for k in via_direct:
        np.testing.assert_array_equal(np.asarray(via_direct[k]),
                                      np.asarray(via_job[k]))


def test_job_fit_prefetch_bit_equal_to_inline_and_overlaps():
    """fit() through the read-stage prefetcher produces the same state as
    the inline iteration and records read-stage occupancy."""
    def build():
        return EtlJob(paper_pipeline("II", small_vocab=512, batch_size=500),
                      backend="pallas",
                      fit_source=Source.synth("I", rows=1500,
                                              batch_size=500, seed=7))
    pre = build()
    pre.fit()
    assert pre.fit_read_stats is not None and pre.fit_read_stats.items == 3
    inline = build()
    inline.fit(prefetch=False)
    assert inline.fit_read_stats is None
    for a, b in zip(pre.state.tables.values(), inline.state.tables.values()):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert list(pre.state.n_unique.values()) == \
        list(inline.state.n_unique.values())


def test_job_fit_lowering_report_and_reader_error():
    job = EtlJob(paper_pipeline("II", small_vocab=512, batch_size=500),
                 backend="pallas")
    assert all(v["path"] == "fused"
               for v in job.fit_lowering_report().values())

    def bad_feed():
        yield next(synth.dataset_batches("I", rows=100, batch_size=100))
        raise OSError("fit shard lost")

    with pytest.raises(RuntimeError, match="fit read stage failed"):
        job.fit(Source.stream(bad_feed))


# ---------------- host-side length keys (ROADMAP follow-on) ----------------

def _varlen_source():
    lens = [5, 1, 3, 2, 6, 4]

    def feed():
        for n in lens:
            yield {"tokens": np.arange(1, n + 1,
                                       dtype=np.int32).reshape(1, n)}

    return Source.stream(feed), lens


def test_host_length_key_orders_without_touching_payload():
    """Regression: with a Source-provided host key, the order stage never
    syncs (or even inspects) the transform stage's output payloads."""
    src, _ = _varlen_source()
    src = src.length_key(lambda raw: float(raw["tokens"].shape[1]))

    class _Opaque:
        """Stands in for a device future: any inspection is an error."""

        def __init__(self, inner):
            self.inner = inner

        def block_until_ready(self):
            raise AssertionError("order stage synced a device future")

        def __array__(self, *a, **k):
            raise AssertionError("order stage materialized the payload")

    def _fallback(batch):
        raise AssertionError("fallback length key was consulted")

    sem = PipelineSemantics(
        batching=BatchingPolicy(1),
        ordering=OrderingPolicy("bucket_by_length", reorder_window=3))
    ex = StreamingExecutor(lambda b: {"tokens": _Opaque(b["tokens"])}, src,
                           semantics=sem, credits=2, length_key=_fallback)
    got = [int(b["tokens"].inner.shape[1]) for b in ex]
    # windows [5,1,3] and [2,6,4], each ascending by the host key
    assert got == [1, 3, 5, 2, 4, 6]


def test_fallback_length_key_still_used_without_host_key():
    src, _ = _varlen_source()
    sem = PipelineSemantics(
        batching=BatchingPolicy(1),
        ordering=OrderingPolicy("bucket_by_length", reorder_window=3))
    ex = StreamingExecutor(lambda b: b, src, semantics=sem, credits=2)
    assert [int(b["tokens"].shape[1]) for b in ex] == [1, 3, 5, 2, 4, 6]


# ---------------- arrival timestamps (freshness experiments) --------------

def test_arrival_timestamps_recorded_for_delivered_batches():
    src, lens = _varlen_source()
    src = src.arrival([float(10 * (i + 1)) for i in range(len(lens))])
    ex = StreamingExecutor(lambda b: b, src, credits=2)
    assert len(list(ex)) == len(lens)
    assert list(ex.stats.delivered_arrivals) == [10.0, 20.0, 30.0, 40.0,
                                                 50.0, 60.0]


def test_queue_stream_stop_does_not_leak_read_thread():
    """A dead producer (no None sentinel) must not leak the read thread:
    stop() closes the Source and every stage joins promptly."""
    import queue as queue_lib

    q = queue_lib.Queue()
    q.put({"x": np.ones((2, 2), np.int32)})
    ex = StreamingExecutor(lambda b: b, Source.stream(q, poll_s=0.05),
                           credits=2)
    it = iter(ex)
    next(it)          # one batch delivered; producer now silent
    ex.stop()
    assert ex.join(timeout=2.0)


def test_queue_stream_reiterates_after_close():
    """close() ends only the active iteration: a later run of the same
    queue Source still drains freshly queued data (multitenant managers
    re-run their tenants)."""
    import queue as queue_lib

    q = queue_lib.Queue()
    src = Source.stream(q, poll_s=0.05)
    q.put({"x": np.ones((2, 2), np.int32)})
    ex = StreamingExecutor(lambda b: b, src, credits=2)
    next(iter(ex))
    ex.stop()
    assert ex.join(timeout=2.0)
    # second run over the same Source after new data arrives
    q.put({"x": np.full((2, 2), 7, np.int32)})
    q.put(None)
    ex2 = StreamingExecutor(lambda b: b, src, credits=2)
    got = list(ex2)
    assert len(got) == 1 and int(got[0]["x"][0, 0]) == 7


# ---------------- weighted round-robin service ----------------

def test_wrr_schedule_is_deterministic_and_proportional():
    wrr = WeightedRoundRobin({"a": 3, "b": 1})
    picks = [wrr.pick() for _ in range(8)]
    assert picks == ["a", "a", "b", "a"] * 2  # smooth WRR, 3:1
    assert picks.count("a") == 6 and picks.count("b") == 2


def test_wrr_eligibility_excludes_idle_tenants():
    wrr = WeightedRoundRobin({"a": 1, "b": 1, "c": 1})
    assert [wrr.pick({"b"}) for _ in range(3)] == ["b"] * 3
    with pytest.raises(ValueError):
        wrr.pick(set())
    with pytest.raises(ValueError):
        WeightedRoundRobin({"a": 0})


def test_transform_service_grants_follow_weights():
    svc = TransformService({"hot": 2, "cold": 1})
    hot, cold = svc.gate("hot"), svc.gate("cold")
    # single-threaded: each acquire arbitrates among current requesters
    order = []
    for _ in range(6):
        assert hot.acquire()
        order.append("hot")
        hot.release()
    assert order == ["hot"] * 6  # cold never waiting -> hot never starved
    assert list(svc.grants) == order
    with pytest.raises(KeyError):
        svc.gate("unknown")


def test_multitenant_service_weighted_run_completes():
    def _pipe():
        return paper_pipeline("I", modulus=256,
                              batch_size=500).compile(backend="jnp")

    mgr = PipelineManager(total_credits=4, service_weighted=True)
    mgr.add("a", _pipe(), Source.synth("I", rows=1500, batch_size=500,
                                       seed=0), weight=2.0)
    mgr.add("b", _pipe(), Source.synth("I", rows=1500, batch_size=500,
                                       seed=1), weight=1.0)
    res = mgr.run(n_batches=3)
    assert all(r.batches == 3 for r in res.values())
    assert all(r.stage_breakdown["transform"]["items"] >= 3
               for r in res.values())


# ---------------- adaptive credits: raw queue resize ----------------

def test_adaptive_credits_resize_raw_queue_too():
    def src(n=20):
        for i in range(n):
            yield {"x": np.full((4, 4), i, np.int32)}

    def slow_pipe(b):
        time.sleep(0.02)  # ETL slower than the (instant) consumer
        return b

    ex = StreamingExecutor(slow_pipe, src(), credits=2,
                           adaptive_credits=True, max_credits=4)
    assert sum(1 for _ in ex) == 20
    assert ex.stats.credit_grows == 2
    assert ex.stats.raw_resizes == 2           # counted per budget change
    assert ex._raw_q.capacity == ex.current_credits == 4  # raw queue follows


def test_fixed_credits_never_resize_raw_queue():
    def src(n=6):
        for i in range(n):
            yield {"x": np.full((2, 2), i, np.int32)}

    ex = StreamingExecutor(lambda b: b, src(), credits=2)
    list(ex)
    assert ex.stats.raw_resizes == 0 and ex._raw_q.capacity == 2
