"""End-to-end behaviour tests for the paper's system (PipeRec-JAX).

The full loop: raw event logs -> compiled streaming ETL (fit + apply) ->
format-aware packer -> double-buffered runtime -> trainer, with checkpoint /
restart in the middle — the paper's Fig 3 running as one program.
"""

import tempfile

import jax
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.configs.registry import get_reduced
from repro.core.pipeline import lm_token_pipeline, paper_pipeline
from repro.data import synth
from repro.etl_runtime.runtime import StreamingExecutor
from repro.models import dlrm
from repro.models.api import build_model
from repro.training import checkpoint as ck
from repro.training.train_loop import (LoopConfig, TrainState, make_train_step,
                                       train_loop)


@pytest.mark.slow
def test_full_recsys_system_with_restart():
    """ETL-fed DLRM training that crashes, restarts, and finishes."""
    cfg = dlrm.DLRMConfig(vocab_size=1025, d_emb=8, bot_mlp=(32, 8),
                          top_mlp=(32, 1))
    tcfg = TrainConfig(lr=3e-3)
    pipe = paper_pipeline("II", small_vocab=1024,
                          batch_size=256).compile(backend="jnp")
    pipe.fit(synth.dataset_batches("I", rows=2000, batch_size=1000))
    step = jax.jit(make_train_step(
        lambda p, b: dlrm.loss_fn(p, b, cfg), tcfg), donate_argnums=0)

    with tempfile.TemporaryDirectory() as d:
        state = TrainState.create(dlrm.init(jax.random.key(0), cfg), tcfg)

        def stream(rows):
            ex = StreamingExecutor(pipe, synth.dataset_batches(
                "I", rows=rows, batch_size=256, seed=3), credits=2)
            return ex

        # phase 1: 8 steps, checkpoint every 4
        state = train_loop(state, step, stream(8 * 256),
                           LoopConfig(total_steps=8, ckpt_dir=d,
                                      ckpt_every=4, log_every=0),
                           async_ckpt=False)
        assert ck.latest_step(d) == 8
        # "crash": drop the live state; restore from the last commit
        zeros = jax.tree_util.tree_map(
            lambda x: np.zeros(x.shape, x.dtype), state)
        restored = ck.restore(d, zeros)
        assert int(restored.step) == 8
        # phase 2: continue to 16
        final = train_loop(restored, step, stream(8 * 256),
                           LoopConfig(total_steps=16, ckpt_dir=d,
                                      ckpt_every=8, log_every=0),
                           async_ckpt=False)
        assert int(final.step) == 16


@pytest.mark.slow
def test_full_lm_system():
    """The same engine feeding an assigned-architecture LM trainer."""
    cfg = get_reduced("llama3_2_3b")
    model = build_model(cfg)
    tcfg = TrainConfig(lr=1e-3, microbatch=2)
    pipe = lm_token_pipeline(seq_len=64, vocab_size=cfg.vocab_size,
                             batch_size=8).compile(backend="jnp")
    step = jax.jit(make_train_step(model.loss, tcfg), donate_argnums=0)
    state = TrainState.create(model.init(jax.random.key(0)), tcfg)
    ex = StreamingExecutor(pipe, synth.lm_event_batches(
        64, rows=12 * 8, batch_size=8), credits=2)
    losses = []
    for batch in ex:
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert len(losses) == 12
    assert losses[-1] < losses[0]
    assert ex.stats.consumed == 12


def test_pallas_backend_system():
    """The FPGA-analogue backend (explicit Pallas kernels) drives training."""
    cfg = dlrm.DLRMConfig(vocab_size=513, d_emb=8, bot_mlp=(16, 8),
                          top_mlp=(16, 1))
    pipe = paper_pipeline("II", small_vocab=512,
                          batch_size=128).compile(backend="pallas")
    pipe.fit(synth.dataset_batches("I", rows=1000, batch_size=500))
    tcfg = TrainConfig(lr=1e-3)
    step = jax.jit(make_train_step(
        lambda p, b: dlrm.loss_fn(p, b, cfg), tcfg), donate_argnums=0)
    state = TrainState.create(dlrm.init(jax.random.key(1), cfg), tcfg)
    for raw in synth.dataset_batches("I", rows=4 * 128, batch_size=128):
        state, m = step(state, pipe(raw))
        assert np.isfinite(float(m["loss"]))
