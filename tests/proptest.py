"""Minimal vendored property-test harness (ROADMAP follow-on).

``hypothesis`` used to be an optional test dependency and the property suite
skipped without it.  This module vendors the subset the suite needs so the
properties always run; ``tests/test_property.py`` still prefers hypothesis as
a fast path when it happens to be installed.

API (mirrors the hypothesis subset the suite uses)::

    from proptest import given, strategies as st

    @given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
    def test_prop(vals):
        ...

Design:

- **Seeded**: each test draws from a ``numpy`` Generator seeded from the
  test's name, so runs are deterministic and failures reproduce.
- **Sized**: early examples are small (size grows with the example index),
  so trivial counterexamples surface before large ones.
- **Shrinking**: on failure the harness greedily minimizes the example —
  each strategy proposes simpler candidates (toward 0 / shorter lists /
  fewer rows) and the first candidate that still fails becomes the new
  example, until a fixpoint — then re-raises the original exception with
  the minimal falsifying example prepended to its message.
"""

from __future__ import annotations

import functools
import inspect
import zlib
from typing import Optional, Sequence

import numpy as np

DEFAULT_MAX_EXAMPLES = 25
SHRINK_BUDGET = 400  # candidate evaluations per failing test


class Strategy:
    """Base strategy: ``generate(rng, size)`` draws one value; ``shrink(v)``
    yields strictly-simpler candidates, simplest first."""

    def generate(self, rng: np.random.Generator, size: int):
        raise NotImplementedError

    def shrink(self, value):
        return iter(())


class _Integers(Strategy):
    def __init__(self, lo: int, hi: int):
        if lo > hi:
            raise ValueError(f"empty integer range [{lo}, {hi}]")
        self.lo, self.hi = int(lo), int(hi)
        # shrink target: 0 when in range, else the boundary nearest 0
        self.target = min(max(0, self.lo), self.hi)

    def generate(self, rng, size):
        # bias early examples toward the target and the boundaries —
        # off-by-one bugs live there
        if size <= 2 or rng.random() < 0.25:
            return int(rng.choice([self.lo, self.hi, self.target]))
        span = min(self.hi - self.lo, max(1, 2 ** min(size, 62)))
        lo = max(self.lo, self.target - span)
        hi = min(self.hi, self.target + span)
        return int(rng.integers(lo, hi + 1))

    def shrink(self, v):
        if v == self.target:
            return
        yield self.target
        mid = self.target + (v - self.target) // 2
        if mid not in (v, self.target):
            yield mid
        step = v - 1 if v > self.target else v + 1
        if step != mid:
            yield step


class _Floats(Strategy):
    def __init__(self, lo: float, hi: float, allow_nan: bool = False):
        if lo > hi:
            raise ValueError(f"empty float range [{lo}, {hi}]")
        self.lo, self.hi = float(lo), float(hi)
        self.allow_nan = allow_nan
        self.target = min(max(0.0, self.lo), self.hi)

    def generate(self, rng, size):
        if self.allow_nan and rng.random() < 0.05:
            return float("nan")
        if size <= 2 or rng.random() < 0.25:
            return float(rng.choice([self.lo, self.hi, self.target]))
        return float(rng.uniform(self.lo, self.hi))

    def shrink(self, v):
        if v != v:  # nan shrinks to the target (a finite reproducer)
            yield self.target
            return
        if v == self.target:
            return
        yield self.target
        mid = self.target + (v - self.target) / 2
        if mid not in (v, self.target):
            yield mid
        as_int = float(int(v))
        if self.lo <= as_int <= self.hi and as_int != v:
            yield as_int


class _Lists(Strategy):
    def __init__(self, elements: Strategy, min_size: int = 0,
                 max_size: int = 32):
        if min_size > max_size:
            raise ValueError("min_size > max_size")
        self.elements = elements
        self.min_size, self.max_size = int(min_size), int(max_size)

    def generate(self, rng, size):
        hi = min(self.max_size, max(self.min_size, size * 4))
        n = int(rng.integers(self.min_size, hi + 1))
        return [self.elements.generate(rng, size) for _ in range(n)]

    def shrink(self, v):
        n = len(v)
        # structural shrinks first: drop whole spans, then halves, then
        # single elements; finally shrink elements pointwise
        if n > self.min_size:
            keep = max(self.min_size, n // 2)
            yield list(v[:keep])
            yield list(v[n - keep:])
            for i in range(n):
                if n - 1 >= self.min_size:
                    yield v[:i] + v[i + 1:]
        for i, x in enumerate(v):
            for cand in self.elements.shrink(x):
                yield v[:i] + [cand] + v[i + 1:]


class _Arrays(Strategy):
    """ndarray of ``dtype`` with shape drawn per-dim from ``shape``
    (ints or integer Strategies); ``elements`` bounds the values."""

    def __init__(self, dtype, shape, elements: Optional[Strategy] = None):
        self.dtype = np.dtype(dtype)
        self.shape = tuple(shape) if isinstance(shape, (tuple, list)) \
            else (shape,)
        if elements is None:
            elements = (_Floats(-1e6, 1e6)
                        if self.dtype.kind == "f" else _Integers(0, 2 ** 15))
        self.elements = elements

    def _dims(self, rng, size):
        return tuple(d.generate(rng, size) if isinstance(d, Strategy) else int(d)
                     for d in self.shape)

    def generate(self, rng, size):
        dims = self._dims(rng, size)
        flat = [self.elements.generate(rng, size)
                for _ in range(int(np.prod(dims)) if dims else 1)]
        return np.asarray(flat, self.dtype).reshape(dims)

    def shrink(self, v):
        # shrink the leading dim (rows), then values toward the target
        if v.ndim and v.shape[0] > 1:
            yield v[:max(1, v.shape[0] // 2)].copy()
            yield v[:-1].copy()
        flat = v.reshape(-1)
        for i in range(flat.size):
            for cand in self.elements.shrink(flat[i].item()):
                out = flat.copy()
                out[i] = cand
                yield out.reshape(v.shape)


class _ColumnDicts(Strategy):
    """Raw columnar batch: ``{name: 1-D array}`` sharing one row count —
    the shape every Source / pipeline ingest path consumes."""

    def __init__(self, columns: dict, rows: Strategy):
        # columns: name -> dtype or (dtype, element Strategy)
        self.columns = {
            name: (np.dtype(spec[0]), spec[1]) if isinstance(spec, tuple)
            else (np.dtype(spec), None)
            for name, spec in columns.items()}
        self.rows = rows

    def generate(self, rng, size):
        n = self.rows.generate(rng, size)
        out = {}
        for name, (dtype, elems) in self.columns.items():
            arr = _Arrays(dtype, (n,), elems)
            out[name] = arr.generate(rng, size)
        return out

    def shrink(self, v):
        n = len(next(iter(v.values())))
        for keep in (max(1, n // 2), n - 1):
            if 0 < keep < n:
                yield {k: a[:keep].copy() for k, a in v.items()}


class strategies:
    """Namespace mirroring ``hypothesis.strategies`` for the used subset."""

    @staticmethod
    def integers(min_value: int, max_value: int) -> Strategy:
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value: float, max_value: float, *,
               allow_nan: bool = False) -> Strategy:
        return _Floats(min_value, max_value, allow_nan)

    @staticmethod
    def lists(elements: Strategy, *, min_size: int = 0,
              max_size: int = 32) -> Strategy:
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def arrays(dtype, shape, *, elements: Optional[Strategy] = None) -> Strategy:
        return _Arrays(dtype, shape, elements)

    @staticmethod
    def column_dicts(columns: dict, *,
                     rows: Optional[Strategy] = None) -> Strategy:
        return _ColumnDicts(columns, rows or _Integers(1, 64))


def _shrink_example(fails, strategies_seq: Sequence[Strategy], example: list):
    """Greedy fixpoint minimization under a candidate-evaluation budget."""
    budget = SHRINK_BUDGET
    improved = True
    while improved and budget > 0:
        improved = False
        for i, strat in enumerate(strategies_seq):
            for cand in strat.shrink(example[i]):
                budget -= 1
                trial = list(example)
                trial[i] = cand
                if fails(trial):
                    example = trial
                    improved = True
                    break
                if budget <= 0:
                    break
            if improved or budget <= 0:
                break
    return example


def given(*strats: Strategy, max_examples: int = DEFAULT_MAX_EXAMPLES):
    """Decorator: run the test over ``max_examples`` generated examples,
    shrinking (and re-raising with) the minimal falsifying example."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(max_examples):
                size = 1 + i // 2  # examples grow as confidence does
                example = [s.generate(rng, size) for s in strats]
                try:
                    fn(*args, *example, **kwargs)
                except Exception:
                    def fails(ex):
                        try:
                            fn(*args, *ex, **kwargs)
                            return False
                        except Exception:
                            return True

                    minimal = _shrink_example(fails, strats, example)
                    try:
                        fn(*args, *minimal, **kwargs)
                    except Exception as e:
                        head = e.args[0] if e.args else ""
                        e.args = ((f"Falsifying example (shrunk, seed={seed}):"
                                   f" {minimal!r}\n{head}"),) + e.args[1:]
                        raise
                    raise  # flaky shrink target: surface the original
            return None

        # hide the generated parameters from pytest's fixture resolution
        # (hypothesis does the same); params beyond the strategies — e.g.
        # pytest fixtures — stay visible and are forwarded via *args
        extra = list(inspect.signature(fn).parameters.values())[len(strats):]
        wrapper.__signature__ = inspect.Signature(extra)
        return wrapper

    return deco
