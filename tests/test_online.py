"""Online-training subsystem: event bus, incremental vocab refresh,
freshness shedding, and the OnlineTrainer service loop (ISSUE 8).

The acceptance test at the bottom runs the full bursty posture — producer
at 2x the trainer's rate, shedding on, ≥2 incremental vocab swaps — and
pins the version contract: every delivered batch is bit-identical to a
from-scratch compile pinned at the state version that transformed it.
"""

import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import paper_pipeline
from repro.data.source import Source
from repro.online import (BusClient, BusServer, EventBus, FreshnessShedder,
                          OnlineConfig, OnlineTrainer, replay)
from repro.session import EtlJob


def _batches(n, *, batch=32, seed=0, schema="I"):
    return list(Source.synth(schema, rows=batch * n, batch_size=batch,
                             seed=seed))


def _toy_batch(i):
    return {"x": np.full((4,), i, dtype=np.int32)}


# ---------------- event bus ----------------

def test_bus_publish_subscribe_fifo():
    bus = EventBus()
    sub = bus.subscribe("t")
    for i in range(5):
        bus.publish("t", _toy_batch(i))
    got = [sub.get(timeout=1.0) for _ in range(5)]
    assert all(ev is not None for ev in got)
    vals = [int(ev[0]["x"][0]) for ev in got]
    assert vals == [0, 1, 2, 3, 4]
    arrivals = [ev[1] for ev in got]
    assert arrivals == sorted(arrivals)  # arrival stamps nondecreasing
    bus.close()


def test_bus_bounded_drop_oldest():
    bus = EventBus(capacity=4)
    sub = bus.subscribe("t")
    shed = sum(bus.publish("t", _toy_batch(i)) for i in range(10))
    assert shed == 6 and sub.dropped == 6
    vals = [int(ev[0]["x"][0]) for ev in iter(sub.get_nowait, None)]
    assert vals == [6, 7, 8, 9]  # newest kept, oldest dropped
    bus.close()


def test_bus_fanout_and_unrouted():
    bus = EventBus()
    a, b = bus.subscribe("t"), bus.subscribe("t")
    bus.publish("t", _toy_batch(1))
    bus.publish("nobody", _toy_batch(2))
    assert a.get(timeout=1.0) is not None
    assert b.get(timeout=1.0) is not None  # every subscriber sees every event
    c = bus.counts()
    assert c["t"]["published"] == 1 and c["nobody"]["unrouted"] == 1
    bus.close()


def test_bus_close_wakes_blocked_get():
    bus = EventBus()
    sub = bus.subscribe("t")
    t0 = time.monotonic()
    threading.Timer(0.05, bus.close).start()
    assert sub.get(timeout=10.0) is None
    assert time.monotonic() - t0 < 2.0  # woke on close, not timeout
    with pytest.raises(RuntimeError):
        bus.publish("t", _toy_batch(0))


def test_bus_socket_transport_roundtrip():
    bus = EventBus()
    sub = bus.subscribe("t")
    server = BusServer(bus)
    client = BusClient(server.address)
    sent = {"x": np.arange(6, dtype=np.int32).reshape(2, 3),
            "y": np.ones((2,), np.float32)}
    client.publish("t", sent)
    ev = sub.get(timeout=5.0)
    assert ev is not None
    got, arrival = ev
    np.testing.assert_array_equal(got["x"], sent["x"])
    np.testing.assert_array_equal(got["y"], sent["y"])
    assert arrival <= time.monotonic()
    client.close()
    server.close()
    bus.close()


def test_replay_paced_and_stoppable():
    bus = EventBus()
    sub = bus.subscribe("t")
    n = replay(bus, "t", [_toy_batch(i) for i in range(3)])
    assert n == 3 and len(sub) == 3
    stop = threading.Event()
    stop.set()
    assert replay(bus, "t", [_toy_batch(9)] * 5, rate_hz=1.0, stop=stop) == 0
    bus.close()


# ---------------- Source.events ----------------

def test_events_source_arrivals_flow_to_executor():
    bus = EventBus()
    src = Source.events(bus, "t")
    feed = _batches(6, batch=16)
    pipe = paper_pipeline("II", small_vocab=64, batch_size=16)
    job = EtlJob(pipe, src, backend="numpy")
    job.compiled.fit(iter(feed))

    def produce():
        replay(bus, "t", feed)
        bus.close()
    threading.Thread(target=produce).start()
    n = 0
    with job.batches() as ex:
        for _ in ex:
            n += 1
    assert n == 6
    # every delivered batch carried a real bus arrival stamp
    assert job.stats().staleness.count == 6
    pct = job.stats().staleness_percentiles()
    assert pct["p95"] >= pct["p50"] >= 0.0


def test_events_source_close_unblocks_reader():
    bus = EventBus()
    src = Source.events(bus, "t", poll_s=10.0)
    out = []

    def run():
        out.extend(iter(src))
    t = threading.Thread(target=run)
    t.start()
    time.sleep(0.1)
    src.close()           # no events ever published; reader parked on get
    t.join(timeout=2.0)
    assert not t.is_alive() and out == []
    bus.close()


# ---------------- incremental vocab refresh ----------------

def _fit_ranks(compiled, vid):
    state = compiled.state
    table = np.asarray(state.tables[vid])
    return {int(v): int(r) for v, r in enumerate(table) if r >= 0}


def test_fit_incremental_rank_stable_and_appends():
    pipe = paper_pipeline("II", small_vocab=256, batch_size=32)
    compiled = pipe.compile(backend="numpy")
    first = _batches(4, batch=32, seed=1)
    compiled.fit(iter(first))
    v1 = compiled.state.version
    before = {vid: _fit_ranks(compiled, vid)
              for vid in compiled.state.tables}
    n_before = dict(compiled.state.n_unique)

    compiled.fit_incremental(iter(_batches(4, batch=32, seed=99)))
    assert compiled.state.version == v1 + 1
    for vid, ranks in before.items():
        after = _fit_ranks(compiled, vid)
        # every pre-existing value keeps its exact rank (embedding rows
        # keep meaning across the swap)
        for val, rank in ranks.items():
            assert after[val] == rank
        # new values append strictly above the old n_unique
        new = {v: r for v, r in after.items() if v not in ranks}
        if new:
            assert min(new.values()) >= n_before[vid]
        assert compiled.state.n_unique[vid] == len(after)


def test_fit_incremental_first_occurrence_order():
    pipe = paper_pipeline("II", small_vocab=64, batch_size=8)
    compiled = pipe.compile(backend="numpy")
    compiled.fit(iter(_batches(1, batch=8, seed=1)))
    n0 = dict(compiled.state.n_unique)
    # a window whose values partly overlap the fitted vocab
    compiled.fit_incremental(iter(_batches(2, batch=8, seed=7)))
    for vid, n in compiled.state.n_unique.items():
        table = np.asarray(compiled.state.tables[vid])
        ranks = table[table >= 0]
        # ranks are a permutation of 0..n-1: dense, no gaps, no dups
        assert sorted(ranks.tolist()) == list(range(n))
        assert n >= n0[vid]


def test_fit_incremental_batches_match_fresh_compile():
    pipe = paper_pipeline("II", small_vocab=128, batch_size=16)
    compiled = pipe.compile(backend="numpy")
    compiled.fit(iter(_batches(2, batch=16, seed=1)))
    compiled.fit_incremental(iter(_batches(2, batch=16, seed=5)))
    state = compiled.state

    fresh = pipe.compile(backend="numpy")
    fresh.state = state
    for raw in _batches(3, batch=16, seed=9):
        a, b = compiled(raw), fresh(raw)
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]),
                                          np.asarray(b[k]))


def test_apply_versioned_tags_and_matches_call():
    pipe = paper_pipeline("II", small_vocab=64, batch_size=8)
    compiled = pipe.compile(backend="numpy")
    compiled.fit(iter(_batches(1, batch=8, seed=1)))
    raw = _batches(1, batch=8, seed=2)[0]
    packed, version = compiled.apply_versioned(raw)
    assert version == compiled.state.version
    direct = compiled(raw)
    for k in direct:
        np.testing.assert_array_equal(np.asarray(packed[k]),
                                      np.asarray(direct[k]))


# ---------------- freshness shedding ----------------

class _FakeClock:
    def __init__(self, t=100.0):
        self.t = t

    def __call__(self):
        return self.t


def _shedder_on(executor, bound, clock):
    return FreshnessShedder(executor, bound, slack=1.0, poll_s=0.01,
                            clock=clock)


def test_shed_drops_globally_oldest_first():
    from repro.etl_runtime.runtime import CreditQueue

    class _Ex:
        pass

    class _Item:
        def __init__(self, arrival):
            self.arrival = arrival

    stop = threading.Event()
    q1, q2 = CreditQueue(10, stop, name="a"), CreditQueue(10, stop, name="b")
    # oldest item lives in q2 — the global policy must find it there
    for a in (5.0, 9.0):
        q1.put(_Item(a))
    for a in (1.0, 7.0):
        q2.put(_Item(a))
    ex = _Ex()
    ex.stats = type("S", (), {"dropped_stale": 0})()
    ex.lookahead = None
    ex.stage_queues = lambda: {"a": q1, "b": q2}
    clock = _FakeClock(t=12.0)
    sh = _shedder_on(ex, 4.0, clock)
    dropped = sh.shed_once()
    # ages at t=12: 11, 7, 5, 3 -> three exceed bound 4, oldest-first
    assert dropped == 3
    arr = list(sh.stats.dropped_arrivals)
    assert arr == sorted(arr) == [1.0, 5.0, 7.0]
    assert ex.stats.dropped_stale == 3
    # only arrival 9.0 (age 3 <= bound) survives, in q1
    assert len(q1) == 1 and len(q2) == 0
    assert q1.peek_oldest_key(lambda it: it.arrival) == 9.0


def test_shed_respects_threshold_and_validates():
    from repro.etl_runtime.runtime import CreditQueue

    class _Ex:
        pass
    q = CreditQueue(10, threading.Event(), name="a")

    class _Item:
        def __init__(self, arrival):
            self.arrival = arrival
    q.put(_Item(10.0))
    ex = _Ex()
    ex.stats = type("S", (), {"dropped_stale": 0})()
    ex.lookahead = None
    ex.stage_queues = lambda: {"a": q}
    sh = _shedder_on(ex, 5.0, _FakeClock(t=14.0))
    assert sh.shed_once() == 0          # age 4 <= bound 5: keep
    assert sh.shed_once(now=16.0) == 1  # age 6 > bound: drop
    with pytest.raises(ValueError):
        FreshnessShedder(ex, 0.0)


def test_shed_excludes_ready_queue_under_lookahead():
    from repro.etl_runtime.runtime import CreditQueue

    class _Ex:
        pass

    class _Item:
        def __init__(self, arrival):
            self.arrival = arrival
    stop = threading.Event()
    placed = CreditQueue(10, stop, name="p")
    ready = CreditQueue(10, stop, name="r")
    ready.put(_Item(0.0))   # ancient planned batch: must NOT be dropped
    placed.put(_Item(1.0))
    ex = _Ex()
    ex.stats = type("S", (), {"dropped_stale": 0})()
    ex.lookahead = object()  # lookahead active
    ex.stage_queues = lambda: {"placed": placed, "ready": ready}
    sh = _shedder_on(ex, 1.0, _FakeClock(t=50.0))
    assert sh.shed_once() == 1
    assert len(ready) == 1 and len(placed) == 0


# ---------------- EmbedCache invalidation ----------------

def test_embed_cache_invalidate_bit_exact_after_vocab_swap():
    import jax.numpy as jnp
    from repro.etl_runtime.lookahead import (EmbedCache, EmbedCacheConfig,
                                             LookaheadPlanner,
                                             cached_embedding_lookup)
    from repro.kernels import ref

    rng = np.random.default_rng(0)
    F, V, D, B = 2, 64, 8, 16
    tables = jnp.asarray(rng.normal(size=(F, V, D)).astype(np.float32))
    cfg = EmbedCacheConfig(rows=16, window=2, row_bytes=4 * D, refresh=True)
    planner = LookaheadPlanner(cfg, F)
    cache = EmbedCache(cfg, F, D)

    def one_batch(tbl):
        idx = rng.integers(0, V, size=(B, F)).astype(np.int32)
        planner.push(idx)
        _, plan = planner.pop_plan()
        batch = cache.advance(tbl, plan.as_payload())
        orig = jnp.asarray(idx)
        out = cached_embedding_lookup(
            tbl, batch["emb_cache"], batch["emb_slot"], batch["emb_cold"],
            orig)
        want = jnp.stack([ref.embedding_bag(tbl[f], orig[:, f:f + 1])
                          for f in range(F)], axis=1)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    for _ in range(3):
        one_batch(tables)
    gen0 = cache.generation
    # vocab swap: table contents change wholesale (new ranks appended,
    # existing rows retrained); stale cached rows must not survive
    tables2 = jnp.asarray(rng.normal(size=(F, V, D)).astype(np.float32))
    cache.invalidate()
    assert cache.generation == gen0 + 1
    for _ in range(3):
        one_batch(tables2)  # bit-exact against the NEW tables


def test_embed_cache_invalidate_requires_refresh_for_online():
    from repro.etl_runtime.lookahead import EmbedCache, EmbedCacheConfig

    pipe = paper_pipeline("II", small_vocab=64, batch_size=8)
    job = EtlJob(pipe, Source.synth("I", rows=32, batch_size=8, seed=0),
                 backend="numpy")
    job.compiled.fit(iter(_batches(1, batch=8, seed=1)))
    cfg = EmbedCacheConfig(rows=8, window=2, row_bytes=32)  # refresh=False
    cache = EmbedCache(cfg, 2, 8)
    bus = EventBus()
    with pytest.raises(ValueError, match="refresh=True"):
        OnlineTrainer(job, object(), lambda s, b: (s, {}),
                      OnlineConfig(refit_every=5), bus=bus,
                      embed_cache=cache)
    bus.close()


# ---------------- checkpoint + staleness plumbing ----------------

def test_staleness_histogram_in_prometheus_text():
    from repro.etl_runtime import metrics as metrics_lib
    from repro.etl_runtime.runtime import RuntimeStats

    stats = RuntimeStats()
    now = time.monotonic()
    for age in (0.001, 0.03, 0.3, 3.0):
        stats.note_delivered(now - age, now=now)
    stats.ingest_events = 10
    stats.t_start = now - 5.0
    text = metrics_lib.stats_to_prometheus(stats)
    assert 'repro_etl_delivered_staleness_seconds_bucket{le="+Inf"} 4' in text
    assert "repro_etl_delivered_staleness_seconds_count 4" in text
    assert "repro_etl_ingest_events_per_second" in text
    # cumulative bucket counts are nondecreasing
    counts = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
              if "_staleness_seconds_bucket" in line]
    assert counts == sorted(counts)


# ---------------- OnlineTrainer service ----------------

class _ToyState:
    params = {"tables": None}


def _online_setup(*, vocab=64, batch=32, warm=8, seed=0):
    pipe = paper_pipeline("II", small_vocab=vocab, batch_size=batch)
    bus = EventBus(capacity=256)
    job = EtlJob(pipe, Source.events(bus, "events"), backend="numpy")
    warm_feed = _batches(warm, batch=batch, seed=seed)
    job.compiled.fit(iter(warm_feed))
    return pipe, bus, job


def test_online_trainer_bursty_acceptance():
    """The ISSUE-8 acceptance posture: producer at ~2x the trainer rate,
    shedding on, >=2 incremental swaps; every traced post-swap batch is
    bit-identical to a from-scratch compile pinned at its version, and
    sheds are strictly oldest-first."""
    pipe, bus, job = _online_setup()
    BOUND = 0.5
    steps = []

    def step_fn(state, batch):
        steps.append(1)
        time.sleep(0.01)      # trainer at ~100 steps/s ceiling
        return state, {"loss": np.float32(0.0)}

    cfg = OnlineConfig(refit_every=6, window_batches=64,
                       shed_max_staleness_s=BOUND, get_timeout_s=0.1)
    tr = OnlineTrainer(job, _ToyState(), step_fn, cfg, bus=bus,
                       topic="events", trace_batches=64)

    def producer():
        # ~200 events/s vs the trainer's ~100/s ceiling: bursty by design
        lap = 0
        t_end = time.monotonic() + 4.0
        while time.monotonic() < t_end:
            replay(bus, "events", _batches(20, batch=32, seed=100 + lap),
                   rate_hz=200.0)
            lap += 1
        bus.close()
    t = threading.Thread(target=producer)
    t.start()
    tr.run(deadline_s=15.0)   # ends on bus close; deadline is a backstop
    t.join()

    assert tr.stats.steps >= 10                      # no deadlock, it ran
    assert tr.stats.swaps >= 2                       # >=2 incremental swaps
    versions = tr.stats.versions
    assert versions == sorted(versions)              # monotonic version bumps

    # bit-equality: every traced batch (spanning >=2 versions) matches a
    # from-scratch compile pinned at the same state version
    traced_versions = {v for v, _, _ in tr.trace}
    assert len(traced_versions) >= 2
    fresh_by_version = {}
    for version, raw, packed in list(tr.trace):
        fresh = fresh_by_version.get(version)
        if fresh is None:
            fresh = pipe.compile(backend="numpy")
            fresh.state = tr.state_history[version]
            fresh_by_version[version] = fresh
        out = fresh(raw)
        for k in packed:
            np.testing.assert_array_equal(np.asarray(out[k]), packed[k])

    # freshness: delivered p95 under the bound; sheds oldest-first
    pct = tr.staleness_percentiles()
    assert pct["p95"] <= BOUND
    shed = tr.shed_stats()
    arr = list(shed.dropped_arrivals)
    assert arr == sorted(arr)                        # strictly oldest-first


def test_online_trainer_checkpoint_rollover(tmp_path):
    from repro.training import checkpoint as ck

    _, bus, job = _online_setup(batch=16, warm=2)

    class _St:
        params = {"tables": None}
        w = np.ones((2, 2), np.float32)

    def step_fn(state, batch):
        return state, {}

    cfg = OnlineConfig(checkpoint_every=3, ckpt_dir=str(tmp_path),
                       keep_ckpts=2, get_timeout_s=0.1)
    tr = OnlineTrainer(job, {"w": np.ones((2, 2), np.float32)}, step_fn, cfg)

    def producer():
        replay(bus, "events", _batches(12, batch=16, seed=3))
        bus.close()
    t = threading.Thread(target=producer)
    t.start()
    tr.run(deadline_s=20.0)
    t.join()
    assert tr.stats.steps == 12 and tr.stats.checkpoints == 4
    kept = sorted(p.name for p in tmp_path.iterdir()
                  if p.name.startswith("step_"))
    assert kept == ["step_00000009", "step_00000012"]  # exactly keep=2
    assert ck.latest_step(str(tmp_path)) == 12


def test_online_trainer_stop_is_prompt():
    _, bus, job = _online_setup(batch=16, warm=2)
    tr = OnlineTrainer(job, _ToyState(), lambda s, b: (s, {}),
                       OnlineConfig(get_timeout_s=0.1))
    done = threading.Event()

    def run():
        tr.run(deadline_s=30.0)
        done.set()
    t = threading.Thread(target=run)
    t.start()
    time.sleep(0.3)     # quiet bus: trainer parked on get_batch
    tr.stop()
    assert done.wait(timeout=5.0)
    bus.close()


@pytest.mark.slow
def test_online_trainer_sustained_smoke():
    """Nightly: a real (tiny) DLRM trained over the bus for ~15s wall —
    nonzero steps, >=1 vocab swap, p95 staleness under the bound."""
    from repro.launch.online import build_parser, build_service

    args = build_parser().parse_args([
        "--duration", "15", "--batch", "128", "--vocab", "2048",
        "--d-emb", "16", "--rate", "30", "--rate-mult", "2.0",
        "--refit-every", "10", "--shed-max-staleness", "0.5",
        "--checkpoint-every", "0", "--log-every", "0",
        "--etl-backend", "numpy"])
    trainer, bus, producer = build_service(args)
    t = threading.Thread(target=producer)
    t.start()
    trainer.run(deadline_s=25.0)
    t.join()
    assert trainer.stats.steps > 0
    assert trainer.stats.swaps >= 1
    assert trainer.staleness_percentiles()["p95"] <= 0.5
