"""Hypothesis property tests on system invariants.

``hypothesis`` is an *optional* test dependency: when absent the whole module
is skipped at collection so the tier-1 ``pytest -x`` run degrades gracefully
instead of dying with a collection error.
"""

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import operators as O
from repro.core.pipeline import Pipeline, paper_pipeline
from repro.core.schema import Schema
from repro.data import synth
from repro.kernels import ops, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
def test_vocab_bijection_and_order(vals):
    """Table maps the set of seen values bijectively onto [0, n_unique),
    ordered by first appearance."""
    arr = np.array(vals, np.int32)
    vg = O.VocabGen(64)
    table = vg.finalize(vg.update(vg.init_state(), arr, 0))
    seen_in_order = list(dict.fromkeys(vals))
    n = O.VocabGen.n_unique(table)
    assert n == len(seen_in_order)
    ranks = [int(table[v]) for v in seen_in_order]
    assert ranks == list(range(n))  # first-appearance order
    assert set(np.asarray(table[table >= 0])) == set(range(n))  # bijection


@given(st.lists(st.integers(0, 31), min_size=1, max_size=150),
       st.integers(1, 3))
def test_vocab_streaming_equals_batch(vals, n_chunks):
    """Chunked streaming fit == single-shot fit (any chunking)."""
    arr = np.array(vals, np.int32)
    vg = O.VocabGen(32)
    want = vg.finalize(vg.update(vg.init_state(), arr, 0))
    state = ref.vocab_state_init(32)
    for ci, chunk in enumerate(np.array_split(arr, n_chunks)):
        fp = ref.vocab_build_chunk(jnp.asarray(chunk.astype(np.int32)), 32)
        state = ref.vocab_merge(state, fp, ci)
    got = np.asarray(ref.vocab_finalize(state))
    np.testing.assert_array_equal(got, want)


@given(st.integers(-2 ** 31, 2 ** 31 - 1), st.integers(1, 2 ** 20))
def test_modulus_in_range(x, m):
    out = O.Modulus(m).numpy(np.array([x], np.int32))[0]
    assert 0 <= out < m


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1,
                max_size=50))
def test_clamp_idempotent(xs):
    op = O.Clamp(0.0, 100.0)
    x = np.array(xs, np.float32)
    once = op.numpy(x)
    np.testing.assert_array_equal(op.numpy(once), once)


@given(st.integers(0, 2 ** 32 - 1), st.integers(2, 2 ** 16))
def test_sigrid_hash_stable_and_bounded(x, m):
    op = O.SigridHash(m)
    a = op.numpy(np.array([x], np.uint32))[0]
    b = op.numpy(np.array([x], np.uint32))[0]
    assert a == b and 0 <= a < m


@given(st.integers(1, 30), st.integers(1, 5))
def test_packer_roundtrip(rows, nblocks):
    """unpack(pack(blocks)) == blocks (the packer loses nothing)."""
    rng = np.random.default_rng(rows * 31 + nblocks)
    widths = list(rng.integers(1, 9, size=nblocks))
    blocks = [rng.normal(size=(rows, w)).astype(np.float32) for w in widths]
    packed = np.asarray(ref.pack_blocks([jnp.asarray(b) for b in blocks],
                                        np.float32, 128))
    ofs = 0
    for b, w in zip(blocks, widths):
        np.testing.assert_allclose(packed[:, ofs:ofs + w], b, rtol=1e-6)
        ofs += w
    assert np.all(packed[:, ofs:] == 0)  # padding is zeros


@given(st.integers(0, 2 ** 31 - 1))
def test_hex_encode_decode_roundtrip(v):
    """synth hex encoder -> Hex2Int is the identity on [0, 2^31)."""
    enc = synth._hex_encode(np.array([v], np.uint32), 8)
    out = O.Hex2Int(8).numpy(enc.reshape(1, 1, 8))[0, 0]
    # note: v=0 encodes to ASCII "00000000" (0x30 bytes) which decodes to 0;
    # the MISSING sentinel is all-NUL (0x00) bytes, a distinct encoding
    assert out == v


@given(st.integers(2, 64))
def test_fused_equals_composition(seed):
    """Compiled fused stage == composing individual operator oracles."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(17, 5)) * 20).astype(np.float32)
    p = Pipeline(Schema([*Schema.criteo_kaggle()][:6]))  # label + 5 dense
    d = (p.dense("dense_*") | O.FillMissing(0.0) | O.Clamp(0.0, 50.0)
         | O.Logarithm() | O.Bucketize([0.5, 1.5, 3.0]))
    p.output("out", [d], dtype=np.int32)
    comp = p.compile(backend="jnp")
    raw = {"label": np.zeros(17, np.float32)}
    for i in range(5):
        raw[f"dense_{i}"] = x[:, i]
    got = np.asarray(comp(raw)["out"])
    want = O.Bucketize([0.5, 1.5, 3.0]).numpy(
        O.Logarithm().numpy(O.Clamp(0.0, 50.0).numpy(
            O.FillMissing(0.0).numpy(x))))
    np.testing.assert_array_equal(got[:, :5], want)
