"""Property tests on system invariants.

The suite runs everywhere on the vendored harness (``tests/proptest.py``) —
no collection-time skip.  ``hypothesis`` remains an optional *fast path*:
when installed, the ported invariants below run under it instead (set
``REPRO_FORCE_VENDORED_PROPTEST=1`` to force the vendored harness for
parity debugging).  The Source round-trip section always uses the vendored
harness so its strategies and shrinker are exercised even in
hypothesis-equipped environments.
"""

import os

import jax.numpy as jnp
import numpy as np
import pytest

import proptest as pt

try:
    if os.environ.get("REPRO_FORCE_VENDORED_PROPTEST"):
        raise ImportError("vendored harness forced")
    from hypothesis import given, settings, strategies as st

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
    PROPERTY_BACKEND = "hypothesis"
except ImportError:
    from proptest import given, strategies as st

    PROPERTY_BACKEND = "proptest"

from repro.core import operators as O
from repro.core.pipeline import Pipeline
from repro.core.schema import Schema
from repro.data import columnar, synth
from repro.data.source import Source
from repro.kernels import ref


@given(st.lists(st.integers(0, 63), min_size=1, max_size=200))
def test_vocab_bijection_and_order(vals):
    """Table maps the set of seen values bijectively onto [0, n_unique),
    ordered by first appearance."""
    arr = np.array(vals, np.int32)
    vg = O.VocabGen(64)
    table = vg.finalize(vg.update(vg.init_state(), arr, 0))
    seen_in_order = list(dict.fromkeys(vals))
    n = O.VocabGen.n_unique(table)
    assert n == len(seen_in_order)
    ranks = [int(table[v]) for v in seen_in_order]
    assert ranks == list(range(n))  # first-appearance order
    assert set(np.asarray(table[table >= 0])) == set(range(n))  # bijection


@given(st.lists(st.integers(0, 31), min_size=1, max_size=150),
       st.integers(1, 3))
def test_vocab_streaming_equals_batch(vals, n_chunks):
    """Chunked streaming fit == single-shot fit (any chunking)."""
    arr = np.array(vals, np.int32)
    vg = O.VocabGen(32)
    want = vg.finalize(vg.update(vg.init_state(), arr, 0))
    state = ref.vocab_state_init(32)
    for ci, chunk in enumerate(np.array_split(arr, n_chunks)):
        fp = ref.vocab_build_chunk(jnp.asarray(chunk.astype(np.int32)), 32)
        state = ref.vocab_merge(state, fp, ci)
    got = np.asarray(ref.vocab_finalize(state))
    np.testing.assert_array_equal(got, want)


@given(st.integers(-2 ** 31, 2 ** 31 - 1), st.integers(1, 2 ** 20))
def test_modulus_in_range(x, m):
    out = O.Modulus(m).numpy(np.array([x], np.int32))[0]
    assert 0 <= out < m


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False), min_size=1,
                max_size=50))
def test_clamp_idempotent(xs):
    op = O.Clamp(0.0, 100.0)
    x = np.array(xs, np.float32)
    once = op.numpy(x)
    np.testing.assert_array_equal(op.numpy(once), once)


@given(st.integers(0, 2 ** 32 - 1), st.integers(2, 2 ** 16))
def test_sigrid_hash_stable_and_bounded(x, m):
    op = O.SigridHash(m)
    a = op.numpy(np.array([x], np.uint32))[0]
    b = op.numpy(np.array([x], np.uint32))[0]
    assert a == b and 0 <= a < m


@given(st.integers(1, 30), st.integers(1, 5))
def test_packer_roundtrip(rows, nblocks):
    """unpack(pack(blocks)) == blocks (the packer loses nothing)."""
    rng = np.random.default_rng(rows * 31 + nblocks)
    widths = list(rng.integers(1, 9, size=nblocks))
    blocks = [rng.normal(size=(rows, w)).astype(np.float32) for w in widths]
    packed = np.asarray(ref.pack_blocks([jnp.asarray(b) for b in blocks],
                                        np.float32, 128))
    ofs = 0
    for b, w in zip(blocks, widths):
        np.testing.assert_allclose(packed[:, ofs:ofs + w], b, rtol=1e-6)
        ofs += w
    assert np.all(packed[:, ofs:] == 0)  # padding is zeros


@given(st.integers(0, 2 ** 31 - 1))
def test_hex_encode_decode_roundtrip(v):
    """synth hex encoder -> Hex2Int is the identity on [0, 2^31)."""
    enc = synth._hex_encode(np.array([v], np.uint32), 8)
    out = O.Hex2Int(8).numpy(enc.reshape(1, 1, 8))[0, 0]
    # note: v=0 encodes to ASCII "00000000" (0x30 bytes) which decodes to 0;
    # the MISSING sentinel is all-NUL (0x00) bytes, a distinct encoding
    assert out == v


@given(st.integers(0, 2 ** 31 - 1))
def test_embedding_bag_cached_bit_equal_on_skewed_inputs(seed):
    """ISSUE 7 acceptance property: the two-level cached kernel is
    bit-identical to the uncached kernel on random Zipf-skewed inputs, for
    any consistent hot-set remap (cache rows mirror their table rows)."""
    from repro.kernels import ops

    rng = np.random.default_rng(seed)
    vocab = int(rng.integers(20, 200))
    dim = int(rng.integers(2, 24))
    batch = int(rng.integers(1, 70))
    nnz = int(rng.integers(1, 7))
    parts = int(rng.integers(1, 5))
    cache_rows = int(rng.integers(1, vocab + 1))
    tbl = rng.normal(size=(vocab, dim)).astype(np.float32)
    idx = (rng.zipf(1.2, size=(batch, nnz)).clip(max=vocab) - 1).astype(
        np.int32)
    idx[rng.random(idx.shape) < 0.1] = -1  # padding lanes
    hot = rng.choice(vocab, size=cache_rows, replace=False)
    slot_of = np.full(vocab, -1, np.int64)
    slot_of[hot] = np.arange(cache_rows)
    slot = np.where(idx >= 0, slot_of[idx.clip(min=0)], -1).astype(np.int32)
    cold = np.where(slot < 0, idx, -1).astype(np.int32)
    got = np.asarray(ops.embedding_bag_cached(
        jnp.asarray(tbl), jnp.asarray(tbl[hot]), jnp.asarray(slot),
        jnp.asarray(cold), partitions=parts, interpret=True))
    want = np.asarray(ops.embedding_bag(
        jnp.asarray(tbl), jnp.asarray(idx), partitions=parts,
        interpret=True))
    np.testing.assert_array_equal(got, want)


@given(st.integers(2, 64))
def test_fused_equals_composition(seed):
    """Compiled fused stage == composing individual operator oracles."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(17, 5)) * 20).astype(np.float32)
    p = Pipeline(Schema([*Schema.criteo_kaggle()][:6]))  # label + 5 dense
    d = (p.dense("dense_*") | O.FillMissing(0.0) | O.Clamp(0.0, 50.0)
         | O.Logarithm() | O.Bucketize([0.5, 1.5, 3.0]))
    p.output("out", [d], dtype=np.int32)
    comp = p.compile(backend="jnp")
    raw = {"label": np.zeros(17, np.float32)}
    for i in range(5):
        raw[f"dense_{i}"] = x[:, i]
    got = np.asarray(comp(raw)["out"])
    want = O.Bucketize([0.5, 1.5, 3.0]).numpy(
        O.Logarithm().numpy(O.Clamp(0.0, 50.0).numpy(
            O.FillMissing(0.0).numpy(x))))
    np.testing.assert_array_equal(got[:, :5], want)


# ------------- optimizer equivalence (random shared-prefix DAGs) ------------
#
# Vendored-harness property: random DAGs where every output rebuilds the
# same prefixes from scratch.  ``optimize="auto"`` must (a) produce
# bit-identical packed outputs to ``optimize="off"`` and (b) report CSE
# merge counts that exactly match the number of duplicated prefixes.

_DENSE_CHAINS = [
    lambda: [O.FillMissing(0.0), O.Clamp(0.0, 50.0)],
    lambda: [O.FillMissing(0.0), O.Clamp(0.0, 50.0), O.Logarithm()],
    lambda: [O.FillMissing(-1.0), O.Clamp(0.0, 9.0),
             O.Bucketize([0.5, 1.5, 3.0])],
]


def _shared_prefix_dag(n_dup: int, chain_i: int):
    """n_dup outputs, each re-deriving the SAME dense chain and the SAME
    sparse decode+bound+vocab chain from fresh source nodes."""
    from repro.core.pipeline import Vocab
    p = Pipeline(Schema.criteo_kaggle())
    for i in range(n_dup):
        d = p.dense("dense_*")
        for op in _DENSE_CHAINS[chain_i]():
            d = d | op
        s = (p.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(256)
             | Vocab(256))
        p.output(f"out{i}", [d, s], dtype=np.float32)
    return p


@pt.given(pt.strategies.integers(2, 4), pt.strategies.integers(0, 2),
          pt.strategies.integers(0, 99), max_examples=6)
def test_optimizer_auto_bit_equal_to_off_on_shared_prefix_dags(
        n_dup, chain_i, seed):
    raw = next(synth.dataset_batches("I", rows=200, batch_size=200,
                                     seed=seed))
    fit = list(synth.dataset_batches("I", rows=200, batch_size=100,
                                     seed=seed + 1))
    outs = {}
    for mode in ("auto", "off"):
        c = _shared_prefix_dag(n_dup, chain_i).compile(backend="jnp",
                                                       optimize=mode)
        c.fit(iter(fit))
        outs[mode] = {k: np.asarray(v) for k, v in c(raw).items()}
        if mode == "auto":
            rep = c.optimize_report()
            # each duplicated copy is 3 stages (dense chain, sparse chain,
            # vocab lookup) and one VocabFit; n_dup-1 copies merge away
            assert rep["cse"]["merged_stages"] == 3 * (n_dup - 1)
            assert rep["cse"]["merged_vocabs"] == n_dup - 1
            assert len(c.plan.stages) == 3
    assert sorted(outs["auto"]) == sorted(outs["off"])
    for k in outs["auto"]:
        np.testing.assert_array_equal(outs["auto"][k], outs["off"][k])


# ------------- Source round-trips (always on the vendored harness) ----------
#
# These use ``proptest`` directly (not the hypothesis fast path) so the
# vendored strategies + shrinker are exercised in every environment.

pst = pt.strategies


def _concat(batches):
    batches = list(batches)
    assert batches, "empty stream"
    return {k: np.concatenate([b[k] for b in batches]) for k in batches[0]}


@pt.given(pst.integers(1, 200), pst.integers(1, 64), pst.integers(1, 64),
          max_examples=15)
def test_rebatch_roundtrip_preserves_rows_and_order(rows, src_batch, rebatch):
    """Any (source batch, rebatch) geometry preserves row order and count,
    and every non-final batch has exactly ``rebatch`` rows."""
    src = Source.synth("I", rows=rows, batch_size=src_batch, seed=3)
    want = _concat(src)
    got_batches = list(src.rebatch(rebatch))
    sizes = [len(next(iter(b.values()))) for b in got_batches]
    assert all(s == rebatch for s in sizes[:-1])
    assert 0 < sizes[-1] <= rebatch
    assert sum(sizes) == rows
    got = _concat(got_batches)
    for k in want:
        np.testing.assert_array_equal(want[k], got[k])


@pt.given(pst.integers(1, 200), pst.integers(1, 64), pst.integers(1, 64),
          max_examples=10)
def test_rebatch_drop_remainder_drops_only_the_tail(rows, src_batch, rebatch):
    src = Source.synth("I", rows=rows, batch_size=src_batch, seed=5)
    want = _concat(src)
    kept = list(src.rebatch(rebatch, drop_remainder=True))
    assert all(len(next(iter(b.values()))) == rebatch for b in kept)
    n_kept = (rows // rebatch) * rebatch
    assert sum(len(next(iter(b.values()))) for b in kept) == n_kept
    if kept:
        got = _concat(kept)
        for k in want:
            np.testing.assert_array_equal(want[k][:n_kept], got[k])


@pt.given(pst.integers(1, 120), pst.integers(1, 32), pst.integers(1, 5),
          max_examples=10)
def test_shard_partitions_generated_stream(rows, src_batch, n_shards):
    """Shards of a generated stream are disjoint, order-preserving, and
    their union is exactly the unsharded stream (batch round-robin)."""
    src = Source.synth("I", rows=rows, batch_size=src_batch, seed=11)
    all_batches = list(src)
    shard_batches = [list(src.shard(i, n_shards)) for i in range(n_shards)]
    assert sum(len(s) for s in shard_batches) == len(all_batches)
    for i, batches in enumerate(shard_batches):
        want = all_batches[i::n_shards]
        assert len(batches) == len(want)
        for w, g in zip(want, batches):
            np.testing.assert_array_equal(w["label"], g["label"])


@pytest.fixture(scope="module")
def columnar_dir(tmp_path_factory):
    """One small on-disk columnar dataset for the file-shard property
    (3 shard files of 300 rows each); built only when the test runs."""
    d = str(tmp_path_factory.mktemp("prop-columnar"))
    columnar.write_dataset(
        d, Schema.criteo_kaggle(),
        synth.dataset_batches("I", rows=900, batch_size=300, seed=13))
    return d


@pt.given(pst.integers(1, 6), max_examples=6)
def test_columnar_shard_partitions_files(n_shards, columnar_dir):
    """Columnar ``.shard(i, n)`` partitions the shard *files*: every row of
    the dataset is delivered exactly once across the n readers (shard counts
    above the file count leave the extra readers legitimately empty)."""
    want = _concat(Source.columnar(columnar_dir))
    parts = [list(Source.columnar(columnar_dir).shard(i, n_shards))
             for i in range(n_shards)]
    union = [b for p in parts for b in p]
    assert sum(len(next(iter(b.values()))) for b in union) \
        == len(want["label"])
    got = _concat(union)
    for k in want:  # exact multiset equality, column by column
        g, w = np.asarray(got[k]), np.asarray(want[k])
        if g.ndim == 1:  # dense/label; NaN-tolerant (missing values)
            np.testing.assert_array_equal(np.sort(g), np.sort(w))
        else:  # hex blocks: compare as row tuples
            assert sorted(g.tolist()) == sorted(w.tolist())


# ------------- the vendored harness's own invariants ------------------------


def test_vendored_harness_runs_and_reports_backend():
    assert PROPERTY_BACKEND in ("hypothesis", "proptest")


def test_vendored_strategies_are_seeded_and_bounded():
    rng1, rng2 = np.random.default_rng(7), np.random.default_rng(7)
    ints = pst.integers(-5, 40)
    a = [ints.generate(rng1, s) for s in range(10)]
    b = [ints.generate(rng2, s) for s in range(10)]
    assert a == b  # deterministic per seed
    assert all(-5 <= v <= 40 for v in a)
    arrs = pst.arrays(np.int32, (pst.integers(1, 8), 3))
    x = arrs.generate(np.random.default_rng(0), 4)
    assert x.dtype == np.int32 and x.ndim == 2 and x.shape[1] == 3
    cols = pst.column_dicts({"a": np.float32, "b": np.int32})
    batch = cols.generate(np.random.default_rng(1), 4)
    assert batch["a"].shape == batch["b"].shape
    assert batch["a"].dtype == np.float32 and batch["b"].dtype == np.int32


def test_vendored_shrinker_minimizes_counterexample():
    """The shrink loop reaches the canonical minimal failing example."""

    @pt.given(pst.lists(pst.integers(0, 100), min_size=0, max_size=20),
              max_examples=50)
    def prop(xs):
        assert max(xs, default=0) < 25  # minimal reproducer is [25]

    with pytest.raises(AssertionError) as ei:
        prop()
    msg = str(ei.value)
    assert "Falsifying example" in msg
    assert "[[25]]" in msg


def test_vendored_shrinker_error_keeps_type():
    @pt.given(pst.integers(0, 1000), max_examples=20)
    def prop(v):
        if v > 10:
            raise ValueError(f"boom {v}")

    with pytest.raises(ValueError) as ei:
        prop()
    assert "Falsifying example" in str(ei.value)
    assert "[11]" in str(ei.value)  # shrunk to the boundary
