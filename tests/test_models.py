"""Per-arch smoke tests (reduced configs) + serve-path consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeCfg
from repro.configs.registry import ARCH_IDS, get_config, get_reduced
from repro.models.api import build_model, input_specs, random_batch

SHAPE = ShapeCfg("smoke", seq_len=32, global_batch=4, kind="train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = random_batch(cfg, SHAPE)
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss)), arch
    grads = jax.grad(model.loss)(params, batch)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.isfinite(np.asarray(leaf)).all(), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_serve(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = random_batch(cfg, SHAPE)
    sb = {k: (v[:, :16] if v.ndim == 2 else v) for k, v in batch.items()}
    logits, cache = model.prefill(params, sb, 32)
    assert np.isfinite(np.asarray(logits)).all(), arch
    tok = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    lg2, cache2 = jax.jit(model.decode_step)(params, cache, tok, jnp.int32(16))
    assert np.isfinite(np.asarray(lg2)).all(), arch


@pytest.mark.parametrize("arch", ["llama3_2_3b", "chatglm3_6b", "qwen3_32b",
                                  "mamba2_370m", "zamba2_2_7b"])
def test_prefill_decode_matches_forward(arch):
    """prefill(16) + decode(1) logits == full forward logits at position 16."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b = random_batch(cfg, ShapeCfg("s", 33, 2, "train"), seed=5)
    toks = b["tokens"]
    want = np.asarray(model.forward(params, {"tokens": toks[:, :18]})[:, 16])
    lg, cache = model.prefill(params, {"tokens": toks[:, :16]}, 33)
    lg2, _ = model.decode_step(params, cache, toks[:, 16:17], jnp.int32(16))
    err = np.abs(np.asarray(lg2[:, 0]) - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, (arch, err)


def test_moe_consistency_with_high_capacity():
    """MoE divergence between forward and decode is ONLY capacity dropping."""
    cfg = get_reduced("mixtral_8x7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b = random_batch(cfg, ShapeCfg("s", 33, 2, "train"), seed=5)
    toks = b["tokens"]
    want = np.asarray(model.forward(params, {"tokens": toks[:, :18]})[:, 16])
    lg, cache = model.prefill(params, {"tokens": toks[:, :16]}, 33)
    lg2, _ = model.decode_step(params, cache, toks[:, 16:17], jnp.int32(16))
    err = np.abs(np.asarray(lg2[:, 0]) - want).max() / (np.abs(want).max() + 1e-9)
    assert err < 1e-4, err


def test_sliding_window_ring_cache_drops_old_tokens():
    """With a ring cache, tokens beyond the window no longer affect logits."""
    cfg = get_reduced("mixtral_8x7b")  # window 16
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    model = build_model(cfg)
    params = model.init(jax.random.key(2))
    b = random_batch(cfg, ShapeCfg("s", 64, 1, "train"), seed=6)
    toks = np.asarray(b["tokens"])
    # two prompts differing ONLY at position 0, decoded at position 40:
    toks2 = toks.copy()
    toks2[:, 0] = (toks2[:, 0] + 1) % cfg.vocab_size
    outs = []
    for t in (toks, toks2):
        lg, cache = model.prefill(params, {"tokens": jnp.asarray(t[:, :40])},
                                  64)
        lg2, _ = model.decode_step(params, cache,
                                   jnp.asarray(t[:, 40:41]), jnp.int32(40))
        outs.append(np.asarray(lg2))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)


def test_input_specs_cover_full_configs():
    from repro.configs.base import ALL_SHAPES
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in ALL_SHAPES:
            specs = input_specs(cfg, shape)
            assert "tokens" in specs or "frames" in specs
            for s in specs.values():
                assert isinstance(s, jax.ShapeDtypeStruct)


def test_param_counts_close_to_nominal():
    """Analytic param_count ~ actual init sizes (reduced configs)."""
    for arch in ["llama3_2_3b", "mamba2_370m", "mixtral_8x7b"]:
        cfg = get_reduced(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.key(0))
        actual = sum(np.prod(s.shape) for s in jax.tree_util.tree_leaves(shapes))
        nominal = cfg.param_count()
        # padded vocab + norm scales make actual slightly larger
        assert 0.7 < actual / nominal < 1.6, (arch, actual, nominal)


def test_full_config_param_counts():
    """Full configs match public parameter counts within tolerance."""
    expect = {"llama3_405b": 405e9, "qwen3_32b": 32.8e9,
              "mixtral_8x7b": 46.7e9, "kimi_k2": 1.04e12,
              "llama3_2_3b": 3.2e9}
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert 0.8 < got / n < 1.25, (arch, got, n)
