"""Pipeline compiler: backend equality, planner fusion, semantics, packing."""

import numpy as np
import pytest

from repro.core import operators as O
from repro.core.dag import Vocab
from repro.core.pipeline import Pipeline, lm_token_pipeline, paper_pipeline
from repro.core.planner import FusedStage, VocabLookupStage
from repro.core.schema import Schema
from repro.core.semantics import BatchingPolicy, OrderingPolicy
from repro.data import synth


def _fit_batches():
    return synth.dataset_batches("I", rows=3000, batch_size=1000, seed=7)


@pytest.fixture(scope="module")
def raw_batch():
    return next(synth.dataset_batches("I", rows=600, batch_size=600, seed=9))


@pytest.mark.parametrize("which", ["I", "II", "III"])
def test_backend_equality(which, raw_batch):
    outs = {}
    for backend in ["numpy", "jnp", "pallas"]:
        p = paper_pipeline(which, modulus=4096, small_vocab=2048,
                           large_vocab=8192).compile(backend=backend)
        p.fit(_fit_batches())
        outs[backend] = {k: np.asarray(v) for k, v in p(raw_batch).items()}
    for k in outs["numpy"]:
        np.testing.assert_allclose(outs["numpy"][k], outs["jnp"][k],
                                   rtol=1e-5, err_msg=f"{which}/{k}")
        np.testing.assert_allclose(outs["numpy"][k], outs["pallas"][k],
                                   rtol=1e-5, err_msg=f"{which}/{k}")


def test_planner_fuses_stateless_chain():
    p = paper_pipeline("II", small_vocab=512)
    compiled = p.compile(backend="jnp")
    plan = compiled.plan
    fused = [s for s in plan.stages if isinstance(s, FusedStage)]
    # dense chain (FillMissing|Clamp|Log) fused into ONE stage; sparse chain
    # (Hex2Int|Modulus) fused into ONE stage feeding the vocab
    assert len(fused) == 2
    assert [op.name for op in fused[0].ops] == ["FillMissing", "Clamp",
                                                "Logarithm"]
    assert [op.name for op in fused[1].ops] == ["Hex2Int", "Modulus"]
    lookups = [s for s in plan.stages if isinstance(s, VocabLookupStage)]
    assert len(lookups) == 1 and lookups[0].placement == "vmem"


def test_planner_state_placement_hbm():
    p = paper_pipeline("III", large_vocab=2 ** 21)  # 8 MiB table > 4 MiB
    plan = p.compile(backend="jnp").plan
    lookups = [s for s in plan.stages if isinstance(s, VocabLookupStage)]
    assert lookups[0].placement == "hbm"


def test_fit_before_apply_oov(raw_batch):
    """Unfitted pipeline maps every value to OOV index 0 (n_unique == 0)."""
    p = paper_pipeline("II", small_vocab=512).compile(backend="jnp")
    out = p(raw_batch)
    assert int(np.asarray(out["sparse"]).max()) == 0


def test_vocab_version_increments():
    p = paper_pipeline("II", small_vocab=512).compile(backend="jnp")
    assert p.state.version == 0
    p.fit(_fit_batches())
    assert p.state.version == 1
    p.fit(_fit_batches())
    assert p.state.version == 2  # point-in-time correctness bookkeeping


def test_pack_shapes_aligned(raw_batch):
    p = paper_pipeline("I", modulus=4096).compile(backend="jnp")
    out = p(raw_batch)
    assert np.asarray(out["dense"]).shape == (600, 16)  # 13 -> pad 16
    assert np.asarray(out["sparse"]).shape == (600, 32)  # 26 -> pad 32
    assert np.asarray(out["label"]).shape == (600,)
    assert np.asarray(out["dense"]).dtype == np.float32
    assert np.asarray(out["sparse"]).dtype == np.int32


def test_cross_feature():
    schema = Schema.criteo_kaggle()
    p = Pipeline(schema)
    a = p.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(128)
    b = p.sparse("sparse_1") | O.Hex2Int(8) | O.Modulus(128)
    x = p.cross(a, b, m=997)
    p.output("crossed", [x], dtype=np.int32)
    compiled = p.compile(backend="jnp")
    raw = next(synth.dataset_batches("I", rows=100, batch_size=100))
    out = np.asarray(compiled(raw)["crossed"])
    assert out.min() >= 0 and out.max() < 997
    # numpy backend agrees (fresh graph needed; rebuild)
    p2 = Pipeline(schema)
    a2 = p2.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(128)
    b2 = p2.sparse("sparse_1") | O.Hex2Int(8) | O.Modulus(128)
    p2.output("crossed", [p2.cross(a2, b2, m=997)], dtype=np.int32)
    out2 = np.asarray(p2.compile(backend="numpy")(raw)["crossed"])
    np.testing.assert_array_equal(out[:, :1], out2[:, :1])


def test_lm_token_pipeline_bounds_vocab():
    p = lm_token_pipeline(seq_len=64, vocab_size=1000).compile(backend="jnp")
    raw = next(synth.lm_event_batches(64, rows=32, batch_size=32))
    out = p(raw)
    toks = np.asarray(out["tokens"])
    assert toks.shape == (32, 64) and toks.max() < 1000 and toks.min() >= 0


def test_semantics_validation():
    with pytest.raises(ValueError):
        BatchingPolicy(0)
    with pytest.raises(ValueError):
        OrderingPolicy("fifo", reorder_window=4)
    with pytest.raises(ValueError):  # window < 2 cannot reorder anything
        OrderingPolicy("bucket_by_length", reorder_window=1)
    assert OrderingPolicy("bucket_by_length", reorder_window=2).reorder_window == 2


def test_schema_validation_catches_bad_batch():
    schema = Schema.criteo_kaggle()
    batch = next(synth.dataset_batches("I", rows=10, batch_size=10))
    schema.validate_batch(batch)  # ok
    bad = dict(batch)
    bad["dense_0"] = bad["dense_0"].astype(np.float64)
    with pytest.raises(TypeError):
        schema.validate_batch(bad)


def test_resource_summary():
    p = paper_pipeline("III", large_vocab=2 ** 19).compile(backend="jnp")
    rs = p.resource_summary()
    assert rs["n_vocabs"] == 1
    assert rs["hbm_table_bytes"] == 4 * 2 ** 19 or rs["vmem_table_bytes"] > 0
    assert rs["flops_per_row"] > 0


# ---------------- fused streaming dataflow (plan-level fusion) ----------------


def _assert_outputs_match(want, got, msg):
    for k in want:
        a, b = np.asarray(want[k]), np.asarray(got[k])
        if np.issubdtype(a.dtype, np.integer):
            np.testing.assert_array_equal(a, b, err_msg=f"{msg}/{k}")
        else:
            np.testing.assert_allclose(a, b, rtol=1e-5, err_msg=f"{msg}/{k}")


@pytest.mark.parametrize("which", ["I", "II", "III"])
def test_fused_dataflow_matches_numpy_oracle(which, raw_batch):
    """Grouped and staged pallas lowerings both pin to the numpy oracle."""
    ref = paper_pipeline(which, modulus=4096, small_vocab=2048,
                         large_vocab=8192).compile(backend="numpy")
    ref.fit(_fit_batches())
    want = ref(raw_batch)
    for fuse in ["auto", "off"]:
        p = paper_pipeline(which, modulus=4096, small_vocab=2048,
                           large_vocab=8192).compile(backend="pallas",
                                                     fuse=fuse)
        p.fit(_fit_batches())
        _assert_outputs_match(want, p(raw_batch), f"{which}/fuse={fuse}")
        paths = {v["path"] for v in p.lowering_report().values()}
        # all three outputs fit one VMEM budget, so the optimizer groups
        # them into a single multi-output kernel under fuse="auto"
        assert paths == ({"grouped"} if fuse == "auto" else {"staged"})


def test_fused_single_pallas_call_per_output(raw_batch):
    """The acceptance invariant, per lowering rung: the grouped lowering
    traces FEWER kernels than outputs (one per DataflowGroup); the
    ungrouped fused lowering traces exactly one per output; staged traces
    one per stage plus packers."""
    p = paper_pipeline("II", small_vocab=2048).compile(backend="pallas")
    p.fit(_fit_batches())
    assert p.traced_pallas_call_count(raw_batch) == 1 < len(p.plan.pack) == 3
    solo = paper_pipeline("II", small_vocab=2048).compile(backend="pallas",
                                                          optimize="off")
    solo.fit(_fit_batches())
    assert solo.traced_pallas_call_count(raw_batch) == len(solo.plan.pack) == 3
    staged = paper_pipeline("II", small_vocab=2048).compile(backend="pallas",
                                                            fuse="off")
    staged.fit(_fit_batches())
    assert staged.traced_pallas_call_count(raw_batch) > len(staged.plan.pack)


def test_fused_fallback_hbm_vocab(raw_batch):
    """HBM-resident tables route their output through the staged path."""
    p = paper_pipeline("III", large_vocab=2 ** 21).compile(backend="pallas")
    rep = p.lowering_report()
    assert rep["sparse"]["path"] == "staged"
    assert "hbm" in rep["sparse"]["reason"]
    assert rep["sparse"]["reason_kind"] == "hbm-table"
    # the two legal outputs still group with each other around the fallback
    assert rep["dense"]["path"] == "grouped"
    assert rep["label"]["path"] == "grouped"
    assert rep["dense"]["group"] == rep["label"]["group"] == ["dense", "label"]
    # the mixed grouped/staged program still matches the oracle end to end
    ref = paper_pipeline("III", large_vocab=2 ** 21).compile(backend="numpy")
    for c in (p, ref):
        c.fit(_fit_batches())
    _assert_outputs_match(ref(raw_batch), p(raw_batch), "hbm-fallback")


def test_fused_cross_pipeline_single_kernel():
    """A cross (binary join) fuses into the same streaming kernel."""
    def build():
        p = Pipeline(Schema.criteo_kaggle())
        a = p.sparse("sparse_0") | O.Hex2Int(8) | O.Modulus(128)
        b = p.sparse("sparse_1") | O.Hex2Int(8) | O.Modulus(128)
        p.output("crossed", [p.cross(a, b, m=997)], dtype=np.int32)
        return p
    raw = next(synth.dataset_batches("I", rows=100, batch_size=100))
    fused = build().compile(backend="pallas")
    assert fused.lowering_report()["crossed"]["path"] == "fused"
    assert fused.traced_pallas_call_count(raw) == 1
    _assert_outputs_match(build().compile(backend="numpy")(raw),
                          fused(raw), "cross")


def test_fused_lm_token_pipeline():
    raw = next(synth.lm_event_batches(64, rows=32, batch_size=32))
    fused = lm_token_pipeline(seq_len=64, vocab_size=1000).compile(
        backend="pallas")
    assert all(v["path"] == "grouped"
               for v in fused.lowering_report().values())
    assert fused.traced_pallas_call_count(raw) == 1  # tokens+labels grouped
    ref = lm_token_pipeline(seq_len=64, vocab_size=1000).compile(
        backend="numpy")
    _assert_outputs_match(ref(raw), fused(raw), "lm")


# ---------------- fused streaming *fit* dataflow ------------------------------


def _state_tables(p):
    """Vocab tables in plan order (ids differ per pipeline instance)."""
    return [np.asarray(t) for t in p.state.tables.values()]


def _assert_states_match(want, got, msg):
    for a, b in zip(_state_tables(want), _state_tables(got)):
        np.testing.assert_array_equal(a, b, err_msg=msg)
    assert list(want.state.n_unique.values()) == \
        list(got.state.n_unique.values()), msg
    assert want.state.version == got.state.version, msg


@pytest.mark.slow
@pytest.mark.parametrize("which", ["II", "III"])
def test_fused_fit_bit_equal_across_lowerings(which, raw_batch):
    """Fused fit == staged fit == numpy oracle: identical PipelineState
    (first-occurrence ranks + frequency counts) on the hex-column paper
    pipelines, and the downstream apply agrees end to end."""
    ref = paper_pipeline(which, modulus=4096, small_vocab=2048,
                         large_vocab=8192).compile(backend="numpy")
    ref.fit(_fit_batches())
    want = ref(raw_batch)
    for fuse in ["auto", "off"]:
        p = paper_pipeline(which, modulus=4096, small_vocab=2048,
                           large_vocab=8192).compile(backend="pallas",
                                                     fuse=fuse)
        p.fit(_fit_batches())
        _assert_states_match(ref, p, f"{which}/fuse={fuse}")
        _assert_outputs_match(want, p(raw_batch), f"fit/{which}/fuse={fuse}")
        paths = {v["path"] for v in p.fit_lowering_report().values()}
        assert paths == ({"fused"} if fuse == "auto" else {"staged"})


def test_fused_fit_min_count_counts_bit_equal(raw_batch):
    """The fused kernel's in-kernel counts drive the frequency filter to the
    same filtered table as the staged bincount path."""
    ref = paper_pipeline("II", small_vocab=2048,
                         min_count=3).compile(backend="numpy")
    fused = paper_pipeline("II", small_vocab=2048,
                           min_count=3).compile(backend="pallas")
    assert all(v["path"] == "fused"
               for v in fused.fit_lowering_report().values())
    for c in (ref, fused):
        c.fit(_fit_batches())
    _assert_states_match(ref, fused, "min_count")


def test_fused_fit_non_hex_token_vocab():
    """A non-hex (token-sequence) vocab fuses its fit too: SigridHash chain
    + first-occurrence build in one kernel, bit-equal to the oracle."""
    def build():
        p = Pipeline(Schema.lm_events(32), batch_size=64)
        t = p.tokens("tokens_raw") | O.SigridHash(512) | Vocab(512)
        p.output("tokens", [t], dtype=np.int32)
        return p

    def fitb():
        return synth.lm_event_batches(32, rows=256, batch_size=64, seed=3)

    ref = build().compile(backend="numpy")
    ref.fit(fitb())
    fused = build().compile(backend="pallas")
    (rep,) = fused.fit_lowering_report().values()
    assert rep["path"] == "fused" and rep["n_stages"] == 1
    fused.fit(fitb())
    _assert_states_match(ref, fused, "token-vocab")


def test_fused_fit_fallback_hbm_vocab():
    """HBM-placed capacities fall back to the staged fit build (their
    first-pos/count accumulators cannot stay VMEM-resident) and still
    produce a bit-identical state."""
    p = paper_pipeline("III", large_vocab=2 ** 21).compile(backend="pallas")
    (rep,) = p.fit_lowering_report().values()
    assert rep["path"] == "staged" and not rep["legal"]
    assert "hbm" in rep["reason"] and rep["placement"] == "hbm"
    ref = paper_pipeline("III", large_vocab=2 ** 21).compile(backend="numpy")
    for c in (p, ref):
        c.fit(_fit_batches())
    _assert_states_match(ref, p, "hbm-fit-fallback")


def test_fused_fit_single_pallas_call_per_vocab(raw_batch):
    """The fit acceptance invariant: the fused fit chunk traces to exactly
    one pallas_call per legally-fused vocab; the staged lowering traces
    more (per-stage kernels + the build kernel)."""
    p = paper_pipeline("II", small_vocab=2048).compile(backend="pallas")
    n_fused = sum(1 for v in p.fit_lowering_report().values()
                  if v["path"] == "fused")
    assert n_fused == len(p.plan.vocab_fits) == 1
    assert p.traced_pallas_call_count(raw_batch, phase="fit") == n_fused
    staged = paper_pipeline("II", small_vocab=2048).compile(backend="pallas",
                                                            fuse="off")
    assert staged.traced_pallas_call_count(raw_batch, phase="fit") > n_fused


def test_frequency_filter_backend_equality(raw_batch):
    """Pipeline II with min_count=3: rare ids -> OOV, all backends agree."""
    outs = {}
    n_uniq = {}
    for backend in ["numpy", "jnp", "pallas"]:
        p = paper_pipeline("II", small_vocab=2048,
                           min_count=3).compile(backend=backend)
        p.fit(_fit_batches())
        outs[backend] = np.asarray(p(raw_batch)["sparse"])
        n_uniq[backend] = max(p.state.n_unique.values())
    p1 = paper_pipeline("II", small_vocab=2048).compile(backend="numpy")
    p1.fit(_fit_batches())
    assert n_uniq["numpy"] < max(p1.state.n_unique.values())  # filter bites
    np.testing.assert_array_equal(outs["numpy"], outs["jnp"])
    np.testing.assert_array_equal(outs["numpy"], outs["pallas"])
