"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024, 2d-RoPE (rotary on half the head dims). [arXiv:2406.12793]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense",
    n_layers=28, d_model=4096, n_heads=32, n_kv_heads=2, head_dim=128,
    d_ff=13696, vocab_size=65024,
    rope_style="half", rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=384, vocab_size=512)
