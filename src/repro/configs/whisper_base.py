"""whisper-base [audio]: enc-dec, 6L(+6L enc) d_model=512 8H d_ff=2048
vocab=51865, conv frontend STUB (precomputed frame embeddings).
[arXiv:2212.04356]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="encdec",
    n_layers=6, enc_layers=6, enc_seq=1500,
    d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab_size=51865,
    rope_style="none", norm="layernorm", mlp="gelu",
    tie_embeddings=True, frontend="audio",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, enc_layers=2, enc_seq=32, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512)
