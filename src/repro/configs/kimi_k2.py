"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8 (+1 shared expert, 1 leading dense
layer) — trillion-param MoE. [arXiv:2501.kimi2 paper-table]

Expert-parallel over the model axis (384 % 16 == 0); bf16 everything +
Adafactor-style factored optimizer state for HBM fit (see EXPERIMENTS.md).
"""

import dataclasses

from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, head_dim=112,
    d_ff=2048, vocab_size=163840,
    rope_style="full", rope_theta=50000.0,
    moe=MoECfg(n_experts=384, top_k=8, d_ff_expert=2048,
               n_shared_experts=1, first_dense_layers=1),
    param_dtype="bfloat16",
)  # seq_parallel OFF: §Perf K3 — SP boundary gathers cost more than
   # the activation savings once MoE grouped dispatch owns the reshards


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512, param_dtype="float32",
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=128,
                   n_shared_experts=1, first_dense_layers=1))
