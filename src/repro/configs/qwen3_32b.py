"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936, qk_norm. [hf:Qwen/Qwen3-32B]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=25600, vocab_size=151936,
    qk_norm=True, rope_style="full", rope_theta=1000000.0,
    seq_parallel=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512)
