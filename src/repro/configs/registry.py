"""Architecture registry: ``--arch <id>`` -> ModelConfig.

Each arch module defines CONFIG (full, paper-exact) and reduced() (smoke)."""

from __future__ import annotations

import importlib

ARCH_IDS = [
    "whisper_base", "llama3_2_3b", "llama3_405b", "chatglm3_6b", "qwen3_32b",
    "internvl2_2b", "mixtral_8x7b", "kimi_k2", "zamba2_2_7b", "mamba2_370m",
]

_ALIASES = {
    "whisper-base": "whisper_base", "llama3.2-3b": "llama3_2_3b",
    "llama3-405b": "llama3_405b", "chatglm3-6b": "chatglm3_6b",
    "qwen3-32b": "qwen3_32b", "internvl2-2b": "internvl2_2b",
    "mixtral-8x7b": "mixtral_8x7b", "kimi-k2-1t-a32b": "kimi_k2",
    "zamba2-2.7b": "zamba2_2_7b", "mamba2-370m": "mamba2_370m",
}


def canonical(arch: str) -> str:
    a = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if a not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return a


def get_config(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_reduced(arch: str):
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.reduced()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}