"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention (4096). [arXiv:2401.04088]

SWA makes ``long_500k`` decode runnable: the KV cache is a ring of size 4096.
"""

import dataclasses

from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    rope_style="full", rope_theta=1000000.0, sliding_window=4096,
    moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=14336),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, sliding_window=16,
        moe=MoECfg(n_experts=4, top_k=2, d_ff_expert=256))
