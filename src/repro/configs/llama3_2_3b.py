"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256. [hf:meta-llama/Llama-3.2-3B]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=128256,
    rope_style="full", rope_theta=500000.0, tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512)
