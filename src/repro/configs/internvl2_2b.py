"""internvl2-2b [vlm]: InternLM2 backbone 24L d_model=2048 16H (GQA kv=8)
d_ff=8192 vocab=92553; InternViT frontend STUB (precomputed patch embeddings
prepended to the token sequence). [arXiv:2404.16821]"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=92553,
    rope_style="full", rope_theta=1000000.0, tie_embeddings=True,
    n_patches=256, frontend="vision",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
        d_ff=256, vocab_size=512, n_patches=8)
