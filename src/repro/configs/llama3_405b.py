"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783]

Scale case: bf16 params + bf16 optimizer moments + FSDP(ZeRO-3) over the data
axes are required to fit 16 GB/chip HBM on 256 chips (see EXPERIMENTS.md).
"""

import dataclasses

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53248, vocab_size=128256,
    rope_style="full", rope_theta=500000.0,
    param_dtype="bfloat16", seq_parallel=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=128, n_heads=8, n_kv_heads=4, head_dim=16,
        d_ff=384, vocab_size=512, param_dtype="float32")
