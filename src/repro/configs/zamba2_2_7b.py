"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560, ssm_state=64 + one
SHARED attention block (32H kv=32, d_ff=10240) applied every 9th layer.
[arXiv:2411.15242]

Hybrid family: ``long_500k`` runs — SSM state is O(1); the shared attention
block serves long contexts with a sliding window (4096) ring cache.
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    rope_style="full", rope_theta=10000.0,
    ssm=SSMCfg(d_state=64, head_dim=64, expand=2, d_conv=4, chunk=128),
    shared_attn_period=9, sliding_window=4096,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
        d_ff=256, vocab_size=512, shared_attn_period=2, sliding_window=16,
        ssm=SSMCfg(d_state=16, head_dim=32, expand=2, d_conv=4, chunk=32))
