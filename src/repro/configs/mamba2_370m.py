"""mamba2-370m [ssm]: 48L d_model=1024 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]

``long_500k`` runs: O(1) recurrent state, no KV cache.
"""

import dataclasses

from repro.configs.base import ModelConfig, SSMCfg

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, d_conv=4, chunk=128),
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=128, vocab_size=512,
        ssm=SSMCfg(d_state=16, head_dim=32, expand=2, d_conv=4, chunk=32))
