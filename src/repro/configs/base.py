"""Model / training configuration dataclasses."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    first_dense_layers: int = 0  # leading dense layers (Kimi-K2 style)


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4
    chunk: int = 128
    n_groups: int = 1


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    rope_style: str = "full"  # full | half | none
    rope_theta: float = 500000.0
    sliding_window: int = 0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid (zamba2): one shared attention block applied every k-th layer
    shared_attn_period: int = 0
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500
    # vlm (internvl2): number of prepended patch embeddings
    n_patches: int = 0
    # modality frontend stub: "audio" | "vision" | "" (none)
    frontend: str = ""
    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # remat: "none" | "full"
    remat: str = "full"
    # Megatron-style sequence parallelism: residuals/saved activations are
    # sequence-sharded over the model axis (allgather before attention/MLP,
    # reduce-scatter after) — activation memory / model_axis
    seq_parallel: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def padded_vocab(self) -> int:
        """Embedding/head rows padded to a 256 multiple so the vocab dim
        shards over the model axis (ids >= vocab_size are masked in the
        loss).  256 = lcm-friendly for 16/32-way model axes + lane width."""
        return -(-self.vocab_size // 256) * 256

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND model-flops and memory checks)."""
        d, v, L = self.d_model, self.vocab_size, self.n_layers
        hd = self.hd
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di = self.ssm.expand * d
            g, n = self.ssm.n_groups, self.ssm.d_state
            per = (d * (2 * di + 2 * g * n + di // self.ssm.head_dim)
                   + di * d + di)
            return emb + L * per
        attn = d * (self.n_heads * hd) * 2 + d * (self.n_kv_heads * hd) * 2
        if self.moe:
            e = self.moe
            ffn = ((e.n_experts + e.n_shared_experts) * 3 * d * e.d_ff_expert)
            dense_ffn = 3 * d * self.d_ff if e.first_dense_layers else 0
            per = attn + ffn
            total = emb + (L - e.first_dense_layers) * per \
                + e.first_dense_layers * (attn + dense_ffn) \
                + L * d * e.n_experts  # router
            return total
        mult = 3 if self.mlp == "swiglu" else 2
        per = attn + mult * d * self.d_ff
        if self.family == "hybrid":
            di = self.ssm.expand * d
            g, n = self.ssm.n_groups, self.ssm.d_state
            per_m = (d * (2 * di + 2 * g * n + di // self.ssm.head_dim)
                     + di * d)
            shared = attn + mult * d * self.d_ff
            return emb + L * per_m + shared
        if self.family == "encdec":
            # decoder layers carry an extra cross-attention block
            return emb + self.enc_layers * per + L * (per + attn)
        return emb + L * per

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        e = self.moe
        attn = d * (self.n_heads * self.hd) * 2 + d * (self.n_kv_heads * self.hd) * 2
        act_ffn = (e.top_k + e.n_shared_experts) * 3 * d * e.d_ff_expert
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + act_ffn + d * e.n_experts)


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    """One (input-shape) cell: training or serving geometry."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCfg("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCfg("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCfg("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCfg("long_500k", 524288, 1, "decode")
ALL_SHAPES = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"  # adamw | adafactor
    lr: float = 3e-4
    weight_decay: float = 0.01
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    opt_state_dtype: str = "float32"  # bf16 halves optimizer HBM (405B/1T)
    accum_dtype: str = "float32"  # grad-accumulation dtype (bf16 at 405B/1T)
    microbatch: int = 0  # number of grad-accumulation chunks (0/1 = off)
    grad_compression: str = "none"  # none | int8_ef
    fsdp: bool = False
    max_grad_norm: float = 1.0
