"""DLRM (arXiv:1906.00091) — the paper's own trainer.

The ETL engine's packed output feeds this directly:
  dense  : (B, D_dense_padded) f32  -> bottom MLP -> (B, d_emb)
  sparse : (B, F) int32 indices     -> per-feature embedding lookup
  label  : (B,) f32 click           -> BCE loss

Feature interaction = pairwise dots between the bottom-MLP output and all
embedding vectors (upper triangle), concatenated back with the dense vector
into the top MLP.  Embedding tables are stacked (F, V, d_emb) and sharded
over the model axis on V (the paper's "sparse embeddings alongside small MLP
stacks"); the Pallas ``embedding_bag`` kernel is the multi-hot path.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard_hint
from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm_criteo"
    n_dense: int = 13
    n_sparse: int = 26
    vocab_size: int = 524288  # per-feature (post VocabMap, +1 OOV)
    d_emb: int = 128
    bot_mlp: tuple = (512, 256, 128)
    top_mlp: tuple = (1024, 1024, 512, 256, 1)
    dense_padded: int = 16  # packer pads 13 -> 16 (§Perf E3)
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    def param_count(self) -> int:
        emb = self.n_sparse * self.vocab_size * self.d_emb
        dims_b = (self.dense_padded,) + self.bot_mlp
        mb = sum(a * b + b for a, b in zip(dims_b[:-1], dims_b[1:]))
        n_pairs = (self.n_sparse + 1) * self.n_sparse // 2
        top_in = self.bot_mlp[-1] + n_pairs
        dims_t = (top_in,) + self.top_mlp
        mt = sum(a * b + b for a, b in zip(dims_t[:-1], dims_t[1:]))
        return emb + mb + mt


def _mlp_init(key, dims, dtype):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": L.truncated_normal(k, (a, b), dtype, 1.0 / math.sqrt(a)),
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp_apply(layers, x, *, final_linear=True):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1 or not final_linear:
            x = jax.nn.relu(x)
    return x


def init(key, cfg: DLRMConfig):
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    n_pairs = (cfg.n_sparse + 1) * cfg.n_sparse // 2
    return {
        "tables": L.truncated_normal(
            k1, (cfg.n_sparse, cfg.vocab_size, cfg.d_emb), dt,
            1.0 / math.sqrt(cfg.d_emb)),
        "bot_mlp": _mlp_init(k2, (cfg.dense_padded,) + cfg.bot_mlp, dt),
        "top_mlp": _mlp_init(k3, (cfg.bot_mlp[-1] + n_pairs,) + cfg.top_mlp,
                             dt),
    }


def forward(params, batch, cfg: DLRMConfig):
    dense = batch["dense"].astype(jnp.dtype(cfg.compute_dtype))
    sparse = batch["sparse"][:, :cfg.n_sparse]  # drop packer padding lanes

    bot = _mlp_apply(params["bot_mlp"], dense, final_linear=False)  # (B, d)

    tables = shard_hint(params["tables"], (None, "model", None))
    if "emb_cache" in batch:
        # lookahead-planned path (etl_runtime/lookahead.py): hot rows from
        # the device-resident cache via the two-level Pallas kernel; the
        # backward pass scatter-adds into the tables at the ORIGINAL ids,
        # so gradients match the uncached lookup exactly
        from repro.etl_runtime.lookahead import cached_embedding_lookup
        emb = cached_embedding_lookup(
            tables, batch["emb_cache"][:cfg.n_sparse],
            batch["emb_slot"][:, :cfg.n_sparse],
            batch["emb_cold"][:, :cfg.n_sparse], sparse)
    else:
        # per-feature single-hot lookup from stacked tables: (B, F, d)
        emb = jax.vmap(lambda tbl, idx: jnp.take(tbl, idx, axis=0),
                       in_axes=(0, 1), out_axes=1)(tables, sparse)
    emb = emb.astype(bot.dtype)

    z = jnp.concatenate([bot[:, None, :], emb], axis=1)  # (B, F+1, d)
    inter = jnp.einsum("bfd,bgd->bfg", z, z,
                       preferred_element_type=jnp.float32)
    f = z.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    pairs = inter[:, iu, ju]  # (B, F(F+1)/2)

    top_in = jnp.concatenate([bot, pairs.astype(bot.dtype)], axis=1)
    logit = _mlp_apply(params["top_mlp"], top_in)[:, 0]
    return logit


def loss_fn(params, batch, cfg: DLRMConfig):
    logit = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    # numerically-stable BCE with logits
    per = jnp.maximum(logit, 0) - logit * y + jnp.log1p(jnp.exp(-jnp.abs(logit)))
    return per.mean()


def predict(params, batch, cfg: DLRMConfig):
    return jax.nn.sigmoid(forward(params, batch, cfg))