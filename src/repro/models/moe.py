"""Mixture-of-Experts layer: token-choice top-k routing, capacity-bounded,
sort-free dispatch (no N x E one-hot tensors — scales to Kimi-K2's 384 experts).

Dispatch
--------
1. router logits -> top-k experts per token (softmax-renormalized weights);
2. position-within-expert via an argsort over expert ids (grouped order);
3. tokens scattered into a dense (E, C, D) expert batch (capacity C, overflow
   dropped — the standard TPU formulation, keeps shapes static for pjit);
4. batched expert FFN as einsum over the stacked expert weights — the E axis
   is expert-parallel over the "model" mesh axis when divisible (GSPMD then
   inserts the all-to-all exactly like a routed dispatch), otherwise the FFN
   dim is tensor-parallel;
5. weighted scatter-add back to token order (+ shared experts, Kimi style).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.models import layers as L


def moe_init(key, cfg: ModelConfig, dtype):
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff_expert
    ks = jax.random.split(key, 5)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {
        "router": L.truncated_normal(ks[0], (d, e.n_experts), jnp.float32,
                                     sc_in),
        "experts": {
            "w1": L.truncated_normal(ks[1], (e.n_experts, d, f), dtype, sc_in),
            "w3": L.truncated_normal(ks[2], (e.n_experts, d, f), dtype, sc_in),
            "w2": L.truncated_normal(ks[3], (e.n_experts, f, d), dtype, sc_out),
        },
    }
    if e.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, e.n_shared_experts * f, "swiglu",
                                 dtype)
    return p


def _dispatch_ffn(p, xf, cfg: ModelConfig, cap: int):
    """Token-choice top-k dispatch + expert FFN + combine for ONE token group.

    xf: (Ng, D).  Everything here is group-local; with the group axis sharded
    over the data axes, the argsort/bincount/gather/scatter never cross data
    shards — only the expert einsum crosses the model axis (EP). (§Perf K2)
    """
    e = cfg.moe
    Ng, D = xf.shape
    k, E = e.top_k, e.n_experts
    logits = xf.astype(jnp.float32) @ p["router"]  # (Ng, E) in f32
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (Ng, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    flat_e = top_e.reshape(-1)  # (Ng*k,)
    flat_t = jnp.repeat(jnp.arange(Ng), k)
    flat_w = top_w.reshape(-1)
    order = jnp.argsort(flat_e)  # stable grouping by expert
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(Ng * k) - starts[se]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, E * cap)  # overflow -> dump

    disp = jnp.zeros((E * cap + 1, D), xf.dtype).at[slot].set(xf[st])
    disp = disp[:E * cap].reshape(E, cap, D)

    w1, w3, w2 = p["experts"]["w1"], p["experts"]["w3"], p["experts"]["w2"]
    hgate = jnp.einsum("ecd,edf->ecf", disp, w1.astype(xf.dtype))
    hlin = jnp.einsum("ecd,edf->ecf", disp, w3.astype(xf.dtype))
    hexp = jax.nn.silu(hgate) * hlin
    eout = jnp.einsum("ecf,efd->ecd", hexp, w2.astype(xf.dtype))

    eflat = eout.reshape(E * cap, D)
    gathered = jnp.where(keep[:, None], eflat[jnp.minimum(slot, E * cap - 1)],
                         0.0)
    out = jnp.zeros((Ng, D), xf.dtype).at[st].add(
        gathered * sw[:, None].astype(xf.dtype))
    return out


def _n_token_groups(N: int) -> int:
    """Dispatch group count = data-parallel degree when it divides N."""
    from repro.distributed.sharding import data_axes, get_active_mesh
    mesh = get_active_mesh()
    if mesh is None:
        return 1
    import numpy as np
    dp = int(np.prod([mesh.shape[a] for a in data_axes(mesh)])) or 1
    return dp if dp > 1 and N % dp == 0 else 1


def moe_apply(p, x, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D).

    Grouped local dispatch (§Perf K2): tokens are split into G = dp groups
    with per-group capacity; each group's sort/dispatch/combine is local to
    its data shard (the industry-standard "dropping" MoE formulation —
    capacity is enforced per shard, so drop decisions differ slightly from a
    global-capacity oracle; equal when capacity_factor is generous).
    """
    e = cfg.moe
    B, S, D = x.shape
    N = B * S
    G = _n_token_groups(N)
    cap = int(max(1, math.ceil(N // G * e.top_k / e.n_experts
                               * e.capacity_factor)))
    cap = -(-cap // 8) * 8  # lane-aligned expert batches

    xf = shard_hint(x.reshape(N, D), ("data", None))
    xg = xf.reshape(G, N // G, D)
    xg = shard_hint(xg, ("data", None, None))
    out = jax.vmap(lambda t: _dispatch_ffn(p, t, cfg, cap))(xg)
    out = shard_hint(out, ("data", None, None)).reshape(N, D)

    if e.n_shared_experts:
        out = out + L.mlp_apply(p["shared"], xf, "swiglu")
    return out.reshape(B, S, D)


def aux_load_balance_loss(p, x, cfg: ModelConfig):
    """Switch-style load-balance auxiliary loss (fraction x router prob)."""
    e = cfg.moe
    N = x.shape[0] * x.shape[1]
    xf = x.reshape(N, -1).astype(jnp.float32)
    probs = jax.nn.softmax(xf @ p["router"], axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.bincount(top_e, length=e.n_experts) / N
    imp = probs.mean(0)
    return e.n_experts * jnp.sum(frac * imp)
