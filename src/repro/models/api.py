"""Unified model API: build_model(cfg) -> Model with init/loss/serve entry
points and ShapeDtypeStruct input_specs per shape cell (dry-run contract)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeCfg
from repro.models import encdec, hybrid, ssm, transformer


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    init: Callable  # (key) -> params
    loss: Callable  # (params, batch) -> scalar
    forward: Callable  # (params, batch) -> logits
    init_cache: Callable  # (batch, max_len) -> cache
    prefill: Callable  # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable  # (params, cache, tokens, pos) -> (logits, cache)

    def param_shapes(self):
        return jax.eval_shape(self.init, jax.random.key(0))


def _token_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    B, S = shape.global_batch, shape.seq_len
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of a shape cell.

    train/prefill: the full batch; decode: the per-step token batch (the KV
    cache / SSM state is an internal spec produced by cache_specs()).
    Modality frontends are stubs: whisper gets precomputed frame embeddings,
    internvl2 gets patch embeddings (see DESIGN.md).
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
    if cfg.family == "encdec":
        # audio stub: frame embeddings; decoder trains on `seq_len` tokens
        return {"frames": jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                               jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.family == "vlm" and shape.kind == "train":
        text = max(S - cfg.n_patches, 1)
        return {"patch_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.n_patches, cfg.d_model), jnp.float32),
                "tokens": jax.ShapeDtypeStruct((B, text), jnp.int32),
                "labels": jax.ShapeDtypeStruct((B, text), jnp.int32)}
    return _token_specs(cfg, shape)


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        mod = transformer

        def loss(params, batch):
            return mod.loss_fn(params, batch, cfg)

        def fwd(params, batch):
            return mod.forward(params, batch["tokens"], cfg,
                               prefix_embeds=batch.get("patch_embeds"))

        def pre(params, batch, max_len):
            return mod.prefill(params, batch["tokens"], cfg, max_len)

        return Model(cfg=cfg,
                     init=lambda key: mod.init(key, cfg),
                     loss=loss, forward=fwd,
                     init_cache=lambda b, m: mod.init_cache(cfg, b, m),
                     prefill=pre,
                     decode_step=lambda p, c, t, pos: mod.decode_step(
                         p, c, t, pos, cfg))
    if fam == "ssm":
        return Model(cfg=cfg,
                     init=lambda key: ssm.init(key, cfg),
                     loss=lambda p, b: ssm.loss_fn(p, b, cfg),
                     forward=lambda p, b: ssm.forward(p, b["tokens"], cfg),
                     init_cache=lambda b, m: ssm.init_cache(cfg, b, m),
                     prefill=lambda p, b, m: ssm.prefill(p, b["tokens"], cfg,
                                                         m),
                     decode_step=lambda p, c, t, pos: ssm.decode_step(
                         p, c, t, pos, cfg))
    if fam == "hybrid":
        return Model(cfg=cfg,
                     init=lambda key: hybrid.init(key, cfg),
                     loss=lambda p, b: hybrid.loss_fn(p, b, cfg),
                     forward=lambda p, b: hybrid.forward(p, b["tokens"], cfg),
                     init_cache=lambda b, m: hybrid.init_cache(cfg, b, m),
                     prefill=lambda p, b, m: hybrid.prefill(p, b["tokens"],
                                                            cfg, m),
                     decode_step=lambda p, c, t, pos: hybrid.decode_step(
                         p, c, t, pos, cfg))
    if fam == "encdec":
        return Model(cfg=cfg,
                     init=lambda key: encdec.init(key, cfg),
                     loss=lambda p, b: encdec.loss_fn(p, b, cfg),
                     forward=lambda p, b: encdec.forward(p, b, cfg),
                     init_cache=lambda b, m: encdec.init_cache(cfg, b, m,
                                                               cfg.enc_seq),
                     prefill=lambda p, b, m: encdec.prefill(
                         p, b["frames"], b["tokens"], cfg, m),
                     decode_step=lambda p, c, t, pos: encdec.decode_step(
                         p, c, t, pos, cfg))
    raise ValueError(f"unknown family {fam!r}")


def cache_specs(model: Model, shape: ShapeCfg) -> Any:
    """ShapeDtypeStruct pytree of the decode cache for a shape cell."""
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def random_batch(cfg: ModelConfig, shape: ShapeCfg, seed: int = 0) -> dict:
    """Concrete random batch matching input_specs (smoke tests)."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, spec in input_specs(cfg, shape).items():
        if np.issubdtype(spec.dtype, np.integer):
            out[k] = jnp.asarray(
                rng.integers(0, cfg.vocab_size, size=spec.shape), spec.dtype)
        else:
            out[k] = jnp.asarray(
                rng.normal(size=spec.shape).astype(np.float32), spec.dtype)
    return out