"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Training uses the chunked SSD algorithm: quadratic attention-like compute
inside chunks of Q tokens (MXU-friendly einsums) + a linear recurrent state
pass between chunks (lax.scan).  Decoding is the O(1)-per-token recurrence on
the (H, N, P) state — no KV cache, which is why the ``long_500k`` shape runs
for this family.

Head layout: d_inner = expand*d_model split into H heads of P=head_dim;
B/C projections are per-group (G groups broadcast over heads).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.models import layers as L


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.n_groups, s.d_state, s.head_dim


def mixer_init(key, cfg: ModelConfig, dtype):
    """Per-stream projections (z/x/B/C/dt) instead of one fused in_proj:
    a fused projection's mixed-size split offsets do not align with model-
    axis shard boundaries, forcing GSPMD to all-gather inside the layer scan.
    Separate weights keep every output cleanly sharded (same flops)."""
    s = cfg.ssm
    d = cfg.d_model
    d_inner, H, G, N, P = dims(cfg)
    ks = jax.random.split(key, 9)
    sc = 1.0 / math.sqrt(d)
    return {
        "z_proj": L.truncated_normal(ks[0], (d, d_inner), dtype, sc),
        "x_proj": L.truncated_normal(ks[1], (d, d_inner), dtype, sc),
        "b_proj": L.truncated_normal(ks[2], (d, G * N), dtype, sc),
        "c_proj": L.truncated_normal(ks[3], (d, G * N), dtype, sc),
        "dt_proj": L.truncated_normal(ks[4], (d, H), dtype, sc),
        "conv_wx": L.truncated_normal(ks[5], (s.d_conv, d_inner), dtype, 0.5),
        "conv_bx": jnp.zeros((d_inner,), dtype),
        "conv_wb": L.truncated_normal(ks[6], (s.d_conv, G * N), dtype, 0.5),
        "conv_bb": jnp.zeros((G * N,), dtype),
        "conv_wc": L.truncated_normal(ks[7], (s.d_conv, G * N), dtype, 0.5),
        "conv_bc": jnp.zeros((G * N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),  # A = -exp(A_log) = -1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus(-2) ~ 0.12
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": L.truncated_normal(ks[8], (d_inner, d), dtype,
                                       1.0 / math.sqrt(d_inner)),
    }


def _causal_conv(u, w, b, *, state=None):
    """Depthwise causal conv. u: (B,S,C); w: (K,C). state: (B,K-1,C) or None.

    Returns (y, new_state) where new_state holds the last K-1 inputs.
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], K - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    y = sum(up[:, i:i + u.shape[1], :] * w[i] for i in range(K))
    y = y + b
    new_state = up[:, -(K - 1):, :]
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh, dt, A, Bh, Ch, chunk, init_state=None):
    """Chunked SSD scan.

    xh: (B,S,H,P) f32; dt: (B,S,H) f32 (post-softplus); A: (H,) f32 (negative);
    Bh, Ch: (B,S,H,N) f32.  Returns (y: (B,S,H,P), final_state: (B,H,N,P)).
    """
    Bsz, S, H, P = xh.shape
    N = Bh.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    r = lambda t: t.reshape((Bsz, nc, Q) + t.shape[2:])
    xc, dtc, Bc, Cc = r(xh), r(dt), r(Bh), r(Ch)

    dA = dtc * A  # (B,nc,Q,H), negative
    cs = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk
    total = cs[:, :, -1, :]  # (B,nc,H)

    # intra-chunk: y[i] = sum_{j<=i} (C_i . B_j) exp(cs_i - cs_j) dt_j x_j
    CB = jnp.einsum("bcihn,bcjhn->bcijh", Cc, Bc,
                    preferred_element_type=jnp.float32)
    seg = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (b,c,i,j,h)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(seg), 0.0)
    scores = CB * decay * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc,
                         preferred_element_type=jnp.float32)

    # per-chunk local end state: S_c = sum_j exp(total - cs_j) dt_j B_j x_j^T
    w = jnp.exp(total[:, :, None, :] - cs) * dtc  # (b,c,j,h)
    S_local = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", w, Bc, xc,
                         preferred_element_type=jnp.float32)

    # inter-chunk recurrence over c: S_prev[c] = S_prev[c-1]*exp(total) + local
    def step(s_prev, inp):
        tot_c, loc_c = inp
        s_new = s_prev * jnp.exp(tot_c)[:, :, None, None] + loc_c
        return s_new, s_prev

    s0 = (jnp.zeros((Bsz, H, N, P), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    final, S_prevs = jax.lax.scan(
        step, s0, (jnp.moveaxis(total, 1, 0), jnp.moveaxis(S_local, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (B,nc,H,N,P): state BEFORE chunk

    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Cc, S_prevs,
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)
    return y, final


def mixer_apply(p, x, cfg: ModelConfig, *, conv_state=None, ssm_state=None,
                return_state=False):
    """Full-sequence mixer. x: (B,S,D). Returns y [, (conv_state, ssm_state)]."""
    d_inner, H, G, N, P = dims(cfg)
    p = L.cast_tree_except(p, x.dtype, ("A_log", "D", "dt_bias"))
    cs = conv_state or {}
    z = x @ p["z_proj"]
    xr, ncx = _causal_conv(x @ p["x_proj"], p["conv_wx"], p["conv_bx"],
                           state=cs.get("x"))
    Braw, ncb = _causal_conv(x @ p["b_proj"], p["conv_wb"], p["conv_bb"],
                             state=cs.get("b"))
    Craw, ncc = _causal_conv(x @ p["c_proj"], p["conv_wc"], p["conv_bc"],
                             state=cs.get("c"))
    dtraw = x @ p["dt_proj"]
    new_conv = {"x": ncx, "b": ncb, "c": ncc}

    Bsz, S, _ = x.shape
    xh = xr.reshape(Bsz, S, H, P).astype(jnp.float32)
    Bh = Braw.reshape(Bsz, S, G, N).astype(jnp.float32)
    Ch = Craw.reshape(Bsz, S, G, N).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bh, rep, axis=2)
    Ch = jnp.repeat(Ch, rep, axis=2)
    dt = jax.nn.softplus(dtraw.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    y, final = _ssd_chunked(xh, dt, A, Bh, Ch, cfg.ssm.chunk,
                            init_state=ssm_state)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, (new_conv, final)
    return out


def mixer_decode(p, x, cfg: ModelConfig, conv_state, ssm_state):
    """One-token recurrence. x: (B,1,D). Returns (y, (conv_state, ssm_state))."""
    d_inner, H, G, N, P = dims(cfg)
    p = L.cast_tree_except(p, x.dtype, ("A_log", "D", "dt_bias"))
    z = x @ p["z_proj"]
    xr, ncx = _causal_conv(x @ p["x_proj"], p["conv_wx"], p["conv_bx"],
                           state=conv_state["x"])
    Braw, ncb = _causal_conv(x @ p["b_proj"], p["conv_wb"], p["conv_bb"],
                             state=conv_state["b"])
    Craw, ncc = _causal_conv(x @ p["c_proj"], p["conv_wc"], p["conv_bc"],
                             state=conv_state["c"])
    dtraw = x @ p["dt_proj"]
    new_conv = {"x": ncx, "b": ncb, "c": ncc}

    Bsz = x.shape[0]
    xh = xr.reshape(Bsz, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Braw.reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(Craw.reshape(Bsz, G, N), H // G, axis=1).astype(jnp.float32)
    dt = jax.nn.softplus(dtraw.astype(jnp.float32)[:, 0, :] + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)  # (B,H)
    # state update: S = S*dA + dt * B x^T
    upd = dt[..., None, None] * Bh[..., :, None] * xh[..., None, :]
    new_state = ssm_state * dA[..., None, None] + upd
    y = jnp.einsum("bhn,bhnp->bhp", Ch, new_state,
                   preferred_element_type=jnp.float32)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(Bsz, 1, d_inner).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], (new_conv, new_state)


# ---------------------------------------------------------------------------
# pure-Mamba2 LM (mamba2-370m)
# ---------------------------------------------------------------------------

def init(key, cfg: ModelConfig):
    dt = cfg.pdtype()
    ks = jax.random.split(key, 3)
    d_inner, H, G, N, P = dims(cfg)

    def layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln": L.norm_init(cfg.d_model, cfg.norm, dt),
                "mixer": mixer_init(k1, cfg, dt)}

    params = {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "blocks": jax.vmap(layer)(jax.random.split(ks[1], cfg.n_layers)),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm, dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncated_normal(
            ks[2], (cfg.d_model, cfg.padded_vocab), dt,
            1.0 / math.sqrt(cfg.d_model))
    return params


def _block(cfg, p, x):
    y = mixer_apply(p["mixer"], L.norm_apply(x, p["ln"], cfg.norm,
                                             cfg.norm_eps), cfg)
    return shard_hint(x + y, ("data", None, None))


def hidden_states(params, tokens, cfg: ModelConfig):
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    fwd = functools.partial(_block, cfg)
    if cfg.remat == "full":
        fwd = jax.checkpoint(fwd)

    def step(carry, p):
        return fwd(p, carry), None

    x, _ = jax.lax.scan(step, x, params["blocks"])
    return L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig):
    x = hidden_states(params, tokens, cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.lm_logits(x, head, cfg.tie_embeddings)


def loss_fn(params, batch, cfg: ModelConfig):
    return L.cross_entropy(forward(params, batch["tokens"], cfg),
                           batch["labels"], valid_vocab=cfg.vocab_size)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    del max_len  # O(1) state — the whole point
    d_inner, H, G, N, P = dims(cfg)
    Lr = cfg.n_layers
    k = cfg.ssm.d_conv - 1
    return {
        "conv": {
            "x": jnp.zeros((Lr, batch, k, d_inner), cfg.cdtype()),
            "b": jnp.zeros((Lr, batch, k, G * N), cfg.cdtype()),
            "c": jnp.zeros((Lr, batch, k, G * N), cfg.cdtype()),
        },
        "ssm": jnp.zeros((Lr, batch, H, N, P), jnp.float32),
    }


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    del pos  # recurrent: position-free
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())

    def step(carry, pc):
        p, conv, ssm = pc
        y, (nconv, nssm) = mixer_decode(
            p["mixer"], L.norm_apply(carry, p["ln"], cfg.norm, cfg.norm_eps),
            cfg, conv, ssm)
        return carry + y, (nconv, nssm)

    x, (nconv, nssm) = jax.lax.scan(
        step, x, (params["blocks"], cache["conv"], cache["ssm"]))
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.lm_logits(x, head, cfg.tie_embeddings), \
        {"conv": nconv, "ssm": nssm}


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Chunked-SSD prefill; returns (last-token logits, decode-ready cache)."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())

    def step(carry, p):
        y, (conv, ssm) = mixer_apply(
            p["mixer"], L.norm_apply(carry, p["ln"], cfg.norm, cfg.norm_eps),
            cfg, return_state=True)
        return carry + y, (conv, ssm)

    x, (convs, ssms) = jax.lax.scan(step, x, params["blocks"])
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(x[:, -1:, :], head, cfg.tie_embeddings)
    return logits, {"conv": convs, "ssm": ssms}
