"""Zamba2-style hybrid: Mamba2 backbone + one SHARED attention block applied
every ``shared_attn_period`` layers (arXiv:2411.15242).

The shared block's parameters are reused at each application point (Zamba's
parameter-efficiency trick), but each application keeps its own KV cache.
For long-context serving the shared block uses a sliding window (size
``cfg.sliding_window`` if set, else full) — this is what makes ``long_500k``
runnable for the hybrid family: SSM state is O(1) and the shared-attn cache is
bounded by the window.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T


def n_shared_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_period


def init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    params = S.init(ks[0], cfg)  # embed + mamba blocks + final norm (+head)
    params["shared_attn"] = T.block_init(ks[1], cfg, moe_layer=False)
    return params


def _mamba_block(cfg, p, x):
    y = S.mixer_apply(p["mixer"],
                      L.norm_apply(x, p["ln"], cfg.norm, cfg.norm_eps), cfg)
    return shard_hint(x + y, ("data", None, None))


def hidden_states(params, tokens, cfg: ModelConfig):
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    period = cfg.shared_attn_period
    mamba_fwd = functools.partial(_mamba_block, cfg)
    attn_fwd = functools.partial(T._block_fwd, cfg, params["shared_attn"],
                                 moe_layer=False)
    if cfg.remat == "full":
        mamba_fwd = jax.checkpoint(mamba_fwd)
        attn_fwd = jax.checkpoint(attn_fwd)

    # scan over groups of `period` mamba layers; after each group apply the
    # shared attention block (params broadcast — reused, not scanned)
    n_groups = cfg.n_layers // period
    grouped = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]),
        params["blocks"])

    def group_step(carry, group_params):
        def inner(c, p):
            return mamba_fwd(p, c), None

        y, _ = jax.lax.scan(inner, carry, group_params)
        y = attn_fwd(y)
        return y, None

    x, _ = jax.lax.scan(group_step, x, grouped)
    return L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig):
    x = hidden_states(params, tokens, cfg)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.lm_logits(x, head, cfg.tie_embeddings)


def loss_fn(params, batch, cfg: ModelConfig):
    return L.cross_entropy(forward(params, batch["tokens"], cfg),
                           batch["labels"], valid_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    cache = S.init_cache(cfg, batch, max_len)
    n_app = n_shared_applications(cfg)
    window = cfg.sliding_window or max_len
    kv_len = min(window, max_len)
    one = L.cache_init(batch, kv_len, cfg.n_kv_heads, cfg.hd, cfg.cdtype())
    cache["shared_kv"] = jax.tree_util.tree_map(
        lambda a: jnp.stack([a] * n_app), one)
    return cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    grouped_p = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]),
        params["blocks"])
    regroup = lambda a: a.reshape((n_groups, period) + a.shape[1:])
    grouped_conv = jax.tree_util.tree_map(regroup, cache["conv"])
    grouped_ssm = regroup(cache["ssm"])
    ring = bool(cfg.sliding_window)

    def group_step(carry, inp):
        p_grp, conv_grp, ssm_grp, kv = inp

        def inner(c, pc):
            p, conv, ssm = pc
            y, (nc, ns) = S.mixer_decode(
                p["mixer"], L.norm_apply(c, p["ln"], cfg.norm, cfg.norm_eps),
                cfg, conv, ssm)
            return c + y, (nc, ns)

        y, (nconv, nssm) = jax.lax.scan(inner, carry,
                                        (p_grp, conv_grp, ssm_grp))
        y2, new_kv = _shared_decode(cfg, params["shared_attn"], kv, y, pos,
                                    ring)
        return y2, (nconv, nssm, new_kv)

    x, (nconv, nssm, nkv) = jax.lax.scan(
        group_step, x, (grouped_p, grouped_conv, grouped_ssm,
                        cache["shared_kv"]))
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    flat = lambda a: a.reshape((cfg.n_layers,) + a.shape[2:])
    new_cache = {"conv": jax.tree_util.tree_map(flat, nconv),
                 "ssm": flat(nssm),
                 "shared_kv": nkv}
    return L.lm_logits(x, head, cfg.tie_embeddings), new_cache


def _shared_decode(cfg, p, kv, x, pos, ring):
    spec = T.attn_spec(cfg)
    h, new_kv = L.mha(p["attn"],
                      L.norm_apply(x, p["ln1"], cfg.norm, cfg.norm_eps),
                      spec, cache=kv, cache_pos=pos, ring=ring)
    x = x + h
    y = L.mlp_apply(p["mlp"], L.norm_apply(x, p["ln2"], cfg.norm,
                                           cfg.norm_eps), cfg.mlp)
    return x + y, new_kv


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Chunked-SSD + shared-attn prefill; returns (logits, cache)."""
    B, Sq = tokens.shape
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    window = cfg.sliding_window or max_len
    kv_len = min(window, max_len)
    T_keep = min(Sq, kv_len)
    tail_pos = jnp.arange(Sq - T_keep, Sq)
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    grouped_p = jax.tree_util.tree_map(
        lambda a: a.reshape((n_groups, period) + a.shape[1:]),
        params["blocks"])

    def group_step(carry, p_grp):
        def inner(c, p):
            y, (conv, ssmst) = S.mixer_apply(
                p["mixer"], L.norm_apply(c, p["ln"], cfg.norm, cfg.norm_eps),
                cfg, return_state=True)
            return c + y, (conv, ssmst)

        y, (convs, ssms) = jax.lax.scan(inner, carry, p_grp)
        tail_in = y[:, Sq - T_keep:, :]
        y = T._block_fwd(cfg, params["shared_attn"], y, moe_layer=False)
        return y, (convs, ssms, tail_in)

    x, (convs, ssms, tails) = jax.lax.scan(group_step, x, grouped_p)
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(x[:, -1:, :], head, cfg.tie_embeddings)

    shared_kv = jax.vmap(
        lambda tx: T._tail_kv(cfg, params["shared_attn"]["attn"],
                              params["shared_attn"]["ln1"], tx, tail_pos,
                              kv_len))(tails)
    flat = lambda a: a.reshape((cfg.n_layers,) + a.shape[2:])
    cache = {
        "conv": jax.tree_util.tree_map(flat, convs),
        "ssm": flat(ssms),
        "shared_kv": shared_kv,
    }
    return logits, cache