"""Shared neural building blocks (pure JAX pytrees; no framework).

Conventions
-----------
- Params are nested dicts of jnp arrays; per-layer blocks are STACKED along a
  leading L axis and consumed with ``jax.lax.scan`` (keeps HLO size O(1) in
  depth — essential for 126-layer dry-run compiles).
- Dtype policy: params in ``cfg.param_dtype``, activations in
  ``cfg.compute_dtype`` (bf16 on TPU), softmax/loss accumulation in f32.
- Sharding is applied from outside via pjit in_shardings on the param pytree
  plus a few ``shard_hint`` constraints on activations; layers themselves are
  mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import shard_hint


def truncated_normal(key, shape, dtype, scale):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, eps):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, w, b, eps):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_apply(x, p, kind, eps):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"], eps)
    return layernorm(x, p["w"], p["b"], eps)


def norm_init(d, kind, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta, style):
    """style 'full': rotate all dims; 'half': rotate first half (ChatGLM 2d)."""
    rot = head_dim if style == "full" else head_dim // 2
    inv = 1.0 / (theta ** (np.arange(0, rot, 2, dtype=np.float32) / rot))
    return jnp.asarray(inv)  # (rot/2,)


def apply_rope(x, positions, inv_freq, style):
    """x: (..., S, H, hd); positions: broadcastable int (..., S)."""
    hd = x.shape[-1]
    rot = inv_freq.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * inv_freq  # (...,S,rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # (...,S,1,rot/2)
    sin = sin[..., None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    yr = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    if rot == hd:
        return yr.astype(x.dtype)
    return jnp.concatenate([yr.astype(x.dtype), x[..., rot:]], axis=-1)


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / sliding window)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qk_norm: bool = False
    rope_style: str = "full"  # "full" | "half" | "none"
    rope_theta: float = 500000.0
    sliding_window: int = 0  # 0 = full causal
    causal: bool = True


def attn_init(key, spec: AttnSpec, dtype):
    d, h, kv, hd = spec.d_model, spec.n_heads, spec.n_kv_heads, spec.head_dim
    ks = jax.random.split(key, 4)
    sc = 1.0 / math.sqrt(d)
    p = {
        "wq": truncated_normal(ks[0], (d, h * hd), dtype, sc),
        "wk": truncated_normal(ks[1], (d, kv * hd), dtype, sc),
        "wv": truncated_normal(ks[2], (d, kv * hd), dtype, sc),
        "wo": truncated_normal(ks[3], (h * hd, d), dtype, 1.0 / math.sqrt(h * hd)),
    }
    if spec.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def cache_init(batch, length, n_kv, head_dim, dtype):
    """KV cache with a true-position array (supports ring buffers for SWA).

    ``pos[s]`` is the absolute position stored in slot s (-1 = empty); masks
    are derived from it, so ring wraparound needs no special casing.
    """
    return {"k": jnp.zeros((batch, length, n_kv, head_dim), dtype),
            "v": jnp.zeros((batch, length, n_kv, head_dim), dtype),
            "pos": jnp.full((length,), -1, jnp.int32)}


def _mask_from_positions(q_pos, k_pos, causal, window):
    """(Sq, Sk) additive f32 bias. k_pos = -1 marks empty cache slots."""
    ok = k_pos[None, :] >= 0
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    return jnp.where(ok, 0.0, -1e9).astype(jnp.float32)


FLASH_THRESHOLD = 8192  # self-attention seqs beyond this use the chunked path


def flash_attention(q, k, v, q_pos, k_pos, *, causal, window,
                    q_chunk=1024, k_chunk=1024):
    """Chunked attention with online softmax (flash-style, pure JAX).

    Never materializes the (Sq, Sk) score matrix: double lax.scan over query
    and key chunks carrying (running max, denom, weighted accumulator).  This
    is the memory-correct formulation for 32k+ contexts; on TPU the inner
    body is exactly what a fused Pallas attention kernel computes per tile.

    q: (B,Sq,H,D); k,v: (B,Sk,H,D) (kv heads already repeated).
    q_pos: (Sq,), k_pos: (Sk,) absolute positions (-1 = empty slot).
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    qc = min(q_chunk, Sq)
    kc = min(k_chunk, Sk)
    assert Sq % qc == 0 and Sk % kc == 0, (Sq, qc, Sk, kc)
    nq, nk = Sq // qc, Sk // kc
    scale = 1.0 / math.sqrt(D)

    qs = jnp.moveaxis(q.reshape(B, nq, qc, H, D), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, kc, H, D), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kc, H, D), 1, 0)
    qps = q_pos.reshape(nq, qc)
    kps = k_pos.reshape(nk, kc)

    def one_q(q_blk, qp):
        def one_k(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, kp = inp
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32) * scale
            s = s + _mask_from_positions(qp, kp, causal, window)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        init = (jnp.full((B, H, qc), -1e30, jnp.float32),
                jnp.zeros((B, H, qc), jnp.float32),
                jnp.zeros((B, H, qc, D), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(one_k, init, (ks, vs, kps))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 1, 2)  # (B, qc, H, D)

    outs = jax.lax.map(lambda args: one_q(*args), (qs, qps))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, H, D)


def mha(p, x, spec: AttnSpec, *, kv_x=None, q_pos=None, cache=None,
        cache_pos=None, ring=False):
    """Multi-head attention with GQA + optional KV cache.

    x: (B, Sq, D). kv_x: cross-attention source (B, Sk, D) or None.
    cache: dict from cache_init, written at cache_pos (ring: modulo length).
    Ring writes require Sq == 1 (decode) or a non-wrapping span.
    Returns (out, new_cache).
    """
    B, Sq, _ = x.shape
    h, kv, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    p = cast_tree(p, x.dtype)
    src = x if kv_x is None else kv_x
    q = (x @ p["wq"]).reshape(B, Sq, h, hd)
    k = (src @ p["wk"]).reshape(B, src.shape[1], kv, hd)
    v = (src @ p["wv"]).reshape(B, src.shape[1], kv, hd)
    if spec.qk_norm:
        q = rmsnorm(q, p["q_norm"], 1e-6)
        k = rmsnorm(k, p["k_norm"], 1e-6)
    if q_pos is None:
        q_pos = (jnp.arange(Sq) if cache_pos is None
                 else cache_pos + jnp.arange(Sq))
    if spec.rope_style != "none" and kv_x is None:
        inv = rope_freqs(hd, spec.rope_theta, spec.rope_style)
        q = apply_rope(q, jnp.broadcast_to(q_pos, (B, Sq)), inv, spec.rope_style)
        k = apply_rope(k, jnp.broadcast_to(q_pos, (B, Sq)), inv, spec.rope_style)

    if cache is not None:
        length = cache["k"].shape[1]
        slot = (cache_pos % length) if ring else cache_pos
        ck = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
        cpos = jax.lax.dynamic_update_slice(cache["pos"], q_pos.astype(jnp.int32),
                                            (slot,))
        cache = {"k": ck, "v": cv, "pos": cpos}
        k, v, k_pos = ck, cv, cpos
    else:
        k_pos = jnp.arange(src.shape[1])

    # GQA: repeat kv heads to match q heads
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    if Sq > 1 and max(Sq, k.shape[1]) > FLASH_THRESHOLD and kv_x is None:
        # long-context path: chunked online-softmax attention (no S^2 scores)
        out = flash_attention(q, k, v, q_pos, k_pos, causal=spec.causal,
                              window=spec.sliding_window).astype(x.dtype)
        out = out.reshape(B, Sq, h * hd) @ p["wo"]
        return out, cache

    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if kv_x is None:  # self-attention mask
        scores = scores + _mask_from_positions(q_pos, k_pos, spec.causal,
                                               spec.sliding_window)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    out = out.reshape(B, Sq, h * hd) @ p["wo"]
    return out, cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d, f, kind, dtype):
    ks = jax.random.split(key, 3)
    sc_in, sc_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    if kind == "swiglu":
        return {"w1": truncated_normal(ks[0], (d, f), dtype, sc_in),
                "w3": truncated_normal(ks[1], (d, f), dtype, sc_in),
                "w2": truncated_normal(ks[2], (f, d), dtype, sc_out)}
    return {"wi": truncated_normal(ks[0], (d, f), dtype, sc_in),
            "bi": jnp.zeros((f,), dtype),
            "wo": truncated_normal(ks[1], (f, d), dtype, sc_out),
            "bo": jnp.zeros((d,), dtype)}


def cast_tree(p, dtype):
    return jax.tree_util.tree_map(lambda a: a.astype(dtype), p)


def cast_tree_except(p: dict, dtype, keep: tuple) -> dict:
    """Cast a flat param dict to dtype, leaving ``keep`` keys untouched
    (f32 master copies of scalar SSM params)."""
    return {k: (v if k in keep else
                jax.tree_util.tree_map(lambda a: a.astype(dtype), v))
            for k, v in p.items()}


def mlp_apply(p, x, kind):
    p = cast_tree(p, x.dtype)
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])) @ p["w2"]
    return (jax.nn.gelu(x @ p["wi"] + p["bi"])) @ p["wo"] + p["bo"]


# ---------------------------------------------------------------------------
# embeddings / heads
# ---------------------------------------------------------------------------

def embed_init(key, vocab, d, dtype):
    # 1/sqrt(d): keeps tied-head logits O(1) at init
    return truncated_normal(key, (vocab, d), dtype, d ** -0.5)


def embed_lookup(emb, tokens, compute_dtype):
    out = jnp.take(emb, tokens, axis=0).astype(compute_dtype)
    return shard_hint(out, ("data", None, None))


def lm_logits(x, emb_or_head, tied):
    if tied:
        return x @ emb_or_head.T.astype(x.dtype)
    return x @ emb_or_head.astype(x.dtype)


def cross_entropy(logits, labels, *, ignore_id: int = -100,
                  valid_vocab: int = 0):
    """Token-level CE in f32; mean over non-ignored positions.

    - The label pick uses a one-hot contraction rather than a gather: with
      the vocab dim sharded over the model axis, a gather would force GSPMD
      to all-gather the full logits; the masked sum keeps the reduction local
      + one small all-reduce.
    - ``valid_vocab``: when the embedding rows are padded for shardability,
      logits at ids >= valid_vocab are masked out of the softmax.
    """
    lf = logits.astype(jnp.float32)
    vocab_iota = jnp.arange(logits.shape[-1], dtype=labels.dtype)
    if valid_vocab and valid_vocab < logits.shape[-1]:
        lf = jnp.where(vocab_iota >= valid_vocab, -1e9, lf)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    onehot = (labels[..., None] == vocab_iota)
    ll = jnp.sum(jnp.where(onehot, lf, 0.0), axis=-1)
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def sinusoidal_positions(n, d):
    pos = np.arange(n)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    out = np.zeros((n, d), np.float32)
    out[:, 0::2] = np.sin(ang)
    out[:, 1::2] = np.cos(ang)
    return jnp.asarray(out)


def sinusoidal_at(positions, d):
    """Sinusoidal embedding at (traced) integer positions: (S,) -> (S, d)."""
    i = jnp.arange(d // 2, dtype=jnp.float32)
    ang = positions.astype(jnp.float32)[:, None] / jnp.power(10000.0,
                                                             2 * i / d)
    out = jnp.zeros((positions.shape[0], d), jnp.float32)
    out = out.at[:, 0::2].set(jnp.sin(ang))
    out = out.at[:, 1::2].set(jnp.cos(ang))
    return out
