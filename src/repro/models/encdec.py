"""Whisper-style encoder-decoder (arXiv:2212.04356).

The audio frontend (mel spectrogram + 2x conv) is a STUB per the task spec:
``input_specs`` provides precomputed frame embeddings (B, T_enc, D).  The
backbone is faithful: pre-LN transformer, GELU MLPs, sinusoidal positions on
the encoder, learned positions on the decoder, bidirectional encoder
self-attention, causal decoder self-attention + cross-attention, decoder
embedding tied to the output head.
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.models import layers as L


def enc_spec(cfg: ModelConfig) -> L.AttnSpec:
    return L.AttnSpec(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                      rope_style="none", causal=False)


def dec_spec(cfg: ModelConfig) -> L.AttnSpec:
    return L.AttnSpec(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                      rope_style="none", causal=True)


def cross_spec(cfg: ModelConfig) -> L.AttnSpec:
    return dataclasses.replace(dec_spec(cfg), causal=False)


def _enc_block_init(key, cfg, dt):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.norm_init(cfg.d_model, cfg.norm, dt),
            "ln2": L.norm_init(cfg.d_model, cfg.norm, dt),
            "attn": L.attn_init(k1, enc_spec(cfg), dt),
            "mlp": L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dt)}


def _dec_block_init(key, cfg, dt):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.norm_init(cfg.d_model, cfg.norm, dt),
            "ln_x": L.norm_init(cfg.d_model, cfg.norm, dt),
            "ln2": L.norm_init(cfg.d_model, cfg.norm, dt),
            "attn": L.attn_init(k1, dec_spec(cfg), dt),
            "xattn": L.attn_init(k2, cross_spec(cfg), dt),
            "mlp": L.mlp_init(k3, cfg.d_model, cfg.d_ff, cfg.mlp, dt)}


def init(key, cfg: ModelConfig):
    # NOTE deviation: whisper's learned decoder positions are replaced with
    # computed sinusoidal positions so one param shape serves every shape
    # cell (4k train .. 32k decode); see DESIGN.md §Hardware-adaptation.
    dt = cfg.pdtype()
    ks = jax.random.split(key, 4)
    return {
        "embed": L.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dt),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dt))(
            jax.random.split(ks[2], cfg.enc_layers)),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dt))(
            jax.random.split(ks[3], cfg.n_layers)),
        "enc_norm": L.norm_init(cfg.d_model, cfg.norm, dt),
        "final_norm": L.norm_init(cfg.d_model, cfg.norm, dt),
    }


def encode(params, frames, cfg: ModelConfig):
    """frames: (B, T_enc, D) precomputed frame embeddings (frontend stub)."""
    x = frames.astype(cfg.cdtype())
    x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    spec = enc_spec(cfg)

    def fwd(p, x):
        h, _ = L.mha(p["attn"], L.norm_apply(x, p["ln1"], cfg.norm,
                                             cfg.norm_eps), spec)
        x = x + h
        y = L.mlp_apply(p["mlp"], L.norm_apply(x, p["ln2"], cfg.norm,
                                               cfg.norm_eps), cfg.mlp)
        return shard_hint(x + y, ("data", None, None))

    if cfg.remat == "full":
        fwd = jax.checkpoint(fwd)
    x, _ = jax.lax.scan(lambda c, p: (fwd(p, c), None), x,
                        params["enc_blocks"])
    return L.norm_apply(x, params["enc_norm"], cfg.norm, cfg.norm_eps)


def _dec_block(cfg, p, x, enc_out, *, self_cache=None, cross_cache=None,
               pos=None):
    h, new_self = L.mha(p["attn"],
                        L.norm_apply(x, p["ln1"], cfg.norm, cfg.norm_eps),
                        dec_spec(cfg), cache=self_cache, cache_pos=pos)
    x = x + h
    if cross_cache is not None:
        # cross K/V precomputed from the encoder (cache = {"k","v","pos"})
        h, _ = _cross_from_cache(cfg, p["xattn"],
                                 L.norm_apply(x, p["ln_x"], cfg.norm,
                                              cfg.norm_eps), cross_cache)
    else:
        h, _ = L.mha(p["xattn"],
                     L.norm_apply(x, p["ln_x"], cfg.norm, cfg.norm_eps),
                     cross_spec(cfg), kv_x=enc_out)
    x = x + h
    y = L.mlp_apply(p["mlp"], L.norm_apply(x, p["ln2"], cfg.norm,
                                           cfg.norm_eps), cfg.mlp)
    return shard_hint(x + y, ("data", None, None)), new_self


def _cross_from_cache(cfg, p, x, cc):
    """Cross-attention against precomputed encoder K/V."""
    spec = cross_spec(cfg)
    B, Sq, _ = x.shape
    h, kv, hd = spec.n_heads, spec.n_kv_heads, spec.head_dim
    p = L.cast_tree(p, x.dtype)
    q = (x @ p["wq"]).reshape(B, Sq, h, hd)
    k, v = cc["k"], cc["v"]
    rep = h // kv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(B, Sq, h * hd)
    return out @ p["wo"], None


def decode_train(params, enc_out, tokens, cfg: ModelConfig):
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    x = x + L.sinusoidal_positions(tokens.shape[1], cfg.d_model).astype(x.dtype)
    fwd = functools.partial(_dec_block, cfg, enc_out=enc_out)
    fwd_block = lambda p, x: fwd(p, x)[0]
    if cfg.remat == "full":
        fwd_block = jax.checkpoint(fwd_block)
    x, _ = jax.lax.scan(lambda c, p: (fwd_block(p, c), None), x,
                        params["dec_blocks"])
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return L.lm_logits(x, params["embed"], True)  # tied head


def forward(params, batch, cfg: ModelConfig):
    enc_out = encode(params, batch["frames"], cfg)
    return decode_train(params, enc_out, batch["tokens"], cfg)


def loss_fn(params, batch, cfg: ModelConfig):
    return L.cross_entropy(forward(params, batch, cfg), batch["labels"],
                           valid_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, enc_len: int):
    one_self = L.cache_init(batch, max_len, cfg.n_kv_heads, cfg.hd,
                            cfg.cdtype())
    stack = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.stack([a] * cfg.n_layers), t)
    return {"self": stack(one_self),
            "cross": {"k": jnp.zeros((cfg.n_layers, batch, enc_len,
                                      cfg.n_kv_heads, cfg.hd), cfg.cdtype()),
                      "v": jnp.zeros((cfg.n_layers, batch, enc_len,
                                      cfg.n_kv_heads, cfg.hd), cfg.cdtype())}}


def prefill(params, frames, tokens, cfg: ModelConfig, max_len: int):
    """Encode audio; precompute cross K/V; run prompt tokens through decoder."""
    enc_out = encode(params, frames, cfg)
    kv, hd = cfg.n_kv_heads, cfg.hd
    B, Te, _ = enc_out.shape

    def one_cross(p):
        k = (enc_out @ p["xattn"]["wk"]).reshape(B, Te, kv, hd)
        v = (enc_out @ p["xattn"]["wv"]).reshape(B, Te, kv, hd)
        return {"k": k.astype(cfg.cdtype()), "v": v.astype(cfg.cdtype())}

    cross = jax.vmap(one_cross)(params["dec_blocks"])

    cache = init_cache(cfg, B, max_len, Te)
    cache["cross"] = cross

    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    x = x + L.sinusoidal_positions(tokens.shape[1], cfg.d_model).astype(x.dtype)

    def step(carry, pc):
        p, sc, cc = pc
        y, new_self = _dec_block(cfg, p, carry, None, self_cache=sc,
                                 cross_cache=cc, pos=0)
        return y, new_self

    x, new_self = jax.lax.scan(step, x, (params["dec_blocks"], cache["self"],
                                         cross))
    cache["self"] = new_self
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    return L.lm_logits(x[:, -1:, :], params["embed"], True), cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    x = x + L.sinusoidal_at(jnp.asarray(pos)[None], cfg.d_model).astype(
        x.dtype)

    def step(carry, pc):
        p, sc, cc = pc
        y, new_self = _dec_block(cfg, p, carry, None, self_cache=sc,
                                 cross_cache=cc, pos=pos)
        return y, new_self

    x, new_self = jax.lax.scan(step, x, (params["dec_blocks"], cache["self"],
                                         cache["cross"]))
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    new_cache = dict(cache)
    new_cache["self"] = new_self
    return L.lm_logits(x, params["embed"], True), new_cache