"""Decoder-only transformer LM (dense GQA / MoE / VLM-prefix variants).

scan-over-layers with stacked block params; remat policy from cfg.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_hint
from repro.models import layers as L
from repro.models import moe as moe_lib


def attn_spec(cfg: ModelConfig) -> L.AttnSpec:
    return L.AttnSpec(d_model=cfg.d_model, n_heads=cfg.n_heads,
                      n_kv_heads=cfg.n_kv_heads, head_dim=cfg.hd,
                      qk_norm=cfg.qk_norm, rope_style=cfg.rope_style,
                      rope_theta=cfg.rope_theta,
                      sliding_window=cfg.sliding_window, causal=True)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def block_init(key, cfg: ModelConfig, *, moe_layer: bool):
    dt = cfg.pdtype()
    k1, k2 = jax.random.split(key)
    p = {"ln1": L.norm_init(cfg.d_model, cfg.norm, dt),
         "ln2": L.norm_init(cfg.d_model, cfg.norm, dt),
         "attn": L.attn_init(k1, attn_spec(cfg), dt)}
    if moe_layer:
        p["moe"] = moe_lib.moe_init(k2, cfg, dt)
    else:
        p["mlp"] = L.mlp_init(k2, cfg.d_model, cfg.d_ff, cfg.mlp, dt)
    return p


def init(key, cfg: ModelConfig):
    dt = cfg.pdtype()
    keys = jax.random.split(key, 4)
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0

    params = {"embed": L.embed_init(keys[0], cfg.padded_vocab, cfg.d_model, dt),
              "final_norm": L.norm_init(cfg.d_model, cfg.norm, dt)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L.truncated_normal(
            keys[1], (cfg.d_model, cfg.padded_vocab), dt,
            1.0 / (cfg.d_model ** 0.5))

    def stacked(key, n, moe_layer):
        ks = jax.random.split(key, n)
        return jax.vmap(lambda k: block_init(k, cfg, moe_layer=moe_layer))(ks)

    if n_dense:
        params["blocks"] = stacked(keys[2], n_dense, False)
    if n_moe:
        params["moe_blocks"] = stacked(keys[3], n_moe, True)
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def act_spec(cfg: ModelConfig):
    return ("data", "model", None) if cfg.seq_parallel else ("data", None, None)


def _block_fwd(cfg: ModelConfig, p, x, *, moe_layer: bool):
    """Megatron-SP boundaries when cfg.seq_parallel: residuals live
    sequence-sharded; the normed activations are explicitly re-gathered to
    full sequence before the TP matmuls (otherwise GSPMD resolves the SP<->TP
    axis conflict by all-gathering the much larger WEIGHTS), and the residual
    add reduce-scatters back."""
    spec = attn_spec(cfg)
    full = ("data", None, None)
    xn = L.norm_apply(x, p["ln1"], cfg.norm, cfg.norm_eps)
    if cfg.seq_parallel:
        xn = shard_hint(xn, full)
    h, _ = L.mha(p["attn"], xn, spec)
    x = x + h
    y = L.norm_apply(x, p["ln2"], cfg.norm, cfg.norm_eps)
    if cfg.seq_parallel:
        y = shard_hint(y, full)
    if moe_layer:
        y = moe_lib.moe_apply(p["moe"], y, cfg)
    else:
        y = L.mlp_apply(p["mlp"], y, cfg.mlp)
    x = x + y
    return shard_hint(x, act_spec(cfg))


def _remat(cfg, fwd):
    if cfg.remat == "full":
        return jax.checkpoint(fwd)
    if cfg.remat == "dots":
        # §Perf L2: save (sharded) matmul outputs — backward reuses them
        # instead of re-deriving through the SP boundary (avoids GSPMD
        # last-resort replication of weight-gradient dots)
        return jax.checkpoint(
            fwd, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fwd


def _scan_blocks(cfg, stacked_params, x, *, moe_layer: bool):
    fwd = _remat(cfg, functools.partial(_block_fwd, cfg, moe_layer=moe_layer))

    def step(carry, p):
        return fwd(p, carry), None

    x, _ = jax.lax.scan(step, x, stacked_params)
    return x


def hidden_states(params, tokens, cfg: ModelConfig,
                  prefix_embeds: Optional[jax.Array] = None):
    """tokens: (B, S) int32 [; prefix_embeds: (B, P, D) for VLM]."""
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    x = shard_hint(x, act_spec(cfg))
    if "blocks" in params:
        x = _scan_blocks(cfg, params["blocks"], x, moe_layer=False)
    if "moe_blocks" in params:
        x = _scan_blocks(cfg, params["moe_blocks"], x, moe_layer=True)
    return L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)


def forward(params, tokens, cfg: ModelConfig, prefix_embeds=None):
    x = hidden_states(params, tokens, cfg, prefix_embeds)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(x, head, cfg.tie_embeddings)
    # SP: keep logits token-sharded (CE is then fully local over tokens);
    # otherwise shard the vocab dim over the model axis
    sp = ("data", "model", None) if cfg.seq_parallel else ("data", None, "model")
    return shard_hint(logits, sp)


def loss_fn(params, batch, cfg: ModelConfig):
    prefix = batch.get("patch_embeds") if isinstance(batch, dict) else None
    logits = forward(params, batch["tokens"], cfg, prefix_embeds=prefix)
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]  # loss only on text positions
    return L.cross_entropy(logits, batch["labels"],
                           valid_vocab=cfg.vocab_size)


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with stacked per-layer KV caches
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    one = lambda: L.cache_init(batch, length, cfg.n_kv_heads, cfg.hd,
                               cfg.cdtype())
    cache = {}
    n_dense = cfg.moe.first_dense_layers if cfg.moe else cfg.n_layers
    n_moe = cfg.n_layers - n_dense if cfg.moe else 0
    if n_dense:
        cache["blocks"] = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * n_dense), one())
    if n_moe:
        cache["moe_blocks"] = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * n_moe), one())
    return cache


def _block_decode(cfg, p, cache, x, pos, *, moe_layer: bool):
    spec = attn_spec(cfg)
    ring = bool(cfg.sliding_window)
    h, new_cache = L.mha(p["attn"],
                         L.norm_apply(x, p["ln1"], cfg.norm, cfg.norm_eps),
                         spec, cache=cache, cache_pos=pos, ring=ring)
    x = x + h
    y = L.norm_apply(x, p["ln2"], cfg.norm, cfg.norm_eps)
    if moe_layer:
        y = moe_lib.moe_apply(p["moe"], y, cfg)
    else:
        y = L.mlp_apply(p["mlp"], y, cfg.mlp)
    return x + y, new_cache


def _scan_decode(cfg, stacked_params, stacked_cache, x, pos, *, moe_layer):
    fwd = functools.partial(_block_decode, cfg, moe_layer=moe_layer)

    def step(carry, pc):
        p, c = pc
        y, nc = fwd(p, c, carry, pos)
        return y, nc

    x, new_cache = jax.lax.scan(step, x, (stacked_params, stacked_cache))
    return x, new_cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """tokens: (B, 1) int32; pos: scalar int32 position. Returns (logits, cache)."""
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())
    new_cache = dict(cache)
    if "blocks" in params:
        x, new_cache["blocks"] = _scan_decode(
            cfg, params["blocks"], cache["blocks"], x, pos, moe_layer=False)
    if "moe_blocks" in params:
        x, new_cache["moe_blocks"] = _scan_decode(
            cfg, params["moe_blocks"], cache["moe_blocks"], x, pos,
            moe_layer=True)
    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = L.lm_logits(x, head, cfg.tie_embeddings)
    return logits, new_cache


def _tail_kv(cfg, attn_p, ln1_p, tail_x, tail_pos, cache_len):
    """Recompute the K/V the cache must hold from saved layer-input tails.

    tail_x: (B, T, D) layer inputs at absolute positions tail_pos (T = number
    of kept tail tokens, T <= cache_len).  Returns cache-layout (k, v, pos)
    with ring rotation applied, padded to cache_len with empty (-1) slots.
    """
    spec = attn_spec(cfg)
    B, T, _ = tail_x.shape
    kv, hd = spec.n_kv_heads, spec.head_dim
    attn_p = L.cast_tree(attn_p, tail_x.dtype)
    y = L.norm_apply(tail_x, ln1_p, cfg.norm, cfg.norm_eps)
    k = (y @ attn_p["wk"]).reshape(B, T, kv, hd)
    v = (y @ attn_p["wv"]).reshape(B, T, kv, hd)
    # GQA kv-head counts usually can't split over the model axis; keep the
    # cache sequence-sharded instead (matches shd.cache_specs fallback)
    from repro.distributed.sharding import get_active_mesh
    mesh = get_active_mesh()
    msize = mesh.shape.get("model", 1) if mesh is not None else 1
    kv_spec = ((None, None, "model", None) if kv % max(msize, 1) == 0
               else (None, "model", None, None))
    k = shard_hint(k, kv_spec)
    v = shard_hint(v, kv_spec)
    if spec.qk_norm:
        k = L.rmsnorm(k, attn_p["k_norm"], 1e-6)
    if spec.rope_style != "none":
        inv = L.rope_freqs(hd, spec.rope_theta, spec.rope_style)
        k = L.apply_rope(k, jnp.broadcast_to(tail_pos, (B, T)), inv,
                         spec.rope_style)
    pad = cache_len - T
    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pos = jnp.pad(tail_pos.astype(jnp.int32), (0, pad), constant_values=-1)
    # ring: token at absolute position p lives in slot p % cache_len; the
    # contiguous tail maps to a cyclic rotation of the slot axis.
    shift = tail_pos[0] % cache_len
    k = jnp.roll(k, shift, axis=1)
    v = jnp.roll(v, shift, axis=1)
    pos = jnp.roll(pos, shift, axis=0)
    return {"k": k.astype(cfg.cdtype()), "v": v.astype(cfg.cdtype()),
            "pos": pos}


def prefill(params, tokens, cfg: ModelConfig, max_len: int):
    """Process a whole prompt; returns (logits, cache).

    Full-attention logits come from the cache-free forward (with the SWA mask
    where configured).  The cache is then reconstructed from saved per-layer
    input tails — for sliding-window models only the last ``window`` tokens
    are kept (ring layout), so a 32k prompt needs only a 4k cache.
    """
    B, S = tokens.shape
    cache_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    T = min(S, cache_len)
    tail_pos = jnp.arange(S - T, S)
    x = L.embed_lookup(params["embed"], tokens, cfg.cdtype())

    new_cache = {}
    for group, is_moe in (("blocks", False), ("moe_blocks", True)):
        if group not in params:
            continue
        fwd = _remat(cfg, functools.partial(_block_fwd, cfg, moe_layer=is_moe))

        def step(carry, p, fwd=fwd):
            # build this layer's cache K/V inside the scan (one layer's
            # intermediates live at a time; outputs stack seq-sharded)
            kv = _tail_kv(cfg, p["attn"], p["ln1"], carry[:, S - T:, :],
                          tail_pos, cache_len)
            return fwd(p, carry), kv

        x, new_cache[group] = jax.lax.scan(step, x, params[group])

    x = L.norm_apply(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    return L.lm_logits(x[:, -1:, :], head, cfg.tie_embeddings), new_cache
