"""Lookahead embedding prefetch with a device-resident hot-row cache.

The ETL side is end-to-end streaming, so the dominant remaining hot path is
the trainer-side sparse embedding gather that consumes the ETL output — the
bottleneck BagPipe attacks with lookahead-driven caching and Hotline with a
popular/rare split (PAPERS.md).  The executor sees batches several steps
before ``jit_train_step`` does; at recommender scale the skewed hot set of
embedding rows is small, so peeking ahead, deduping indices, and keeping hot
rows in a device-resident cache converts most of the irregular HBM gather
into a dense cache lookup.

Three pieces, split host/device exactly like the rest of the runtime:

- ``LookaheadPlanner`` — pure host-side policy.  It maintains per-table row
  frequency over a window of W upcoming batches and, when the oldest batch
  is released, emits a ``PrefetchPlan``: a per-table index remap (hot row →
  cache slot, cold row → original id), the rows to stage for this batch, and
  a cache-update plan (admit/evict chosen by window frequency).  Everything
  is planned once on the host so device work stays dense.
- ``LookaheadStage`` — the executor stage (after **place**, before deliver).
  It buffers W in-flight envelopes, feeds the planner, and annotates each
  released payload with the plan arrays under ``PLAN_KEYS``.
- ``EmbedCache`` — the device-side consumer.  ``advance(tables, batch)``
  applies the batch's plan to the stacked ``[T, rows + stage_max, dim]``
  cache tensor (admits + per-batch staging, one dense scatter each) and
  returns kernel-ready inputs; ``cached_embedding_lookup`` is the
  differentiable wrapper over ``kernels.embedding_bag_cached`` (backward is
  the standard scatter-add to the table through the ORIGINAL row ids, so
  training gradients are exact).

Slot layout: slots ``[0, rows)`` are the resident hot set (persist across
batches, admit/evict managed by the planner), slots ``[rows, rows +
stage_max)`` are the per-batch staging region — cold rows of the released
batch prefetched just-in-time, the BagPipe "prefetch upcoming rows" move.
A cold row that overflows the staging region keeps ``slot == -1`` and falls
through ``embedding_bag_cached``'s partitioned table pass, so the remap is
total and bit-exact regardless of cache pressure.

Coherence: with a static table (ETL benches, serving) rows are copied on
admit only.  Under training the table changes every step, so
``EmbedCacheConfig(refresh=True)`` re-admits every *referenced* resident row
from the current table each batch — the HBM gather still touches only the
deduped unique rows (the win BagPipe measures) and cached training stays
bit-exact.  Vocab-state versions do not invalidate the cache: it is keyed on
post-VocabMap row ids of the trainer's table, not on raw values.
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from dataclasses import replace
from typing import Callable, Optional

import numpy as np

# Keys the lookahead stage adds to each released payload (host numpy arrays).
PLAN_KEYS = ("emb_slot", "emb_cold", "emb_stage_rows",
             "emb_admit_slots", "emb_admit_rows")


@dataclasses.dataclass(frozen=True)
class EmbedCacheConfig:
    """Knobs for the lookahead prefetch + embedding cache layer.

    rows : resident cache slots per table (the device hot set).
    window : lookahead window W in batches; frequency (and therefore the
        hot set) is computed over the W in-flight envelopes.
    stage_max : per-batch staging slots appended after the resident region
        (0 -> ``rows``).  Cold rows beyond this fall through the kernel's
        partitioned table pass.
    tables : feature columns of the index matrix that get a cache (per-table
        on/off); None = every column.
    key : payload key holding the int32 ``[B, F]`` index matrix.
    min_admit_freq : window occurrences before a row may displace a resident.
    refresh : re-admit referenced resident rows from the current table every
        batch (exactness under training updates; leave False for static
        tables).
    row_bytes : bytes per embedding row, for gather-bytes-saved accounting.
    """

    rows: int
    window: int = 4
    stage_max: int = 0
    tables: Optional[tuple] = None
    key: str = "sparse"
    min_admit_freq: int = 2
    refresh: bool = False
    row_bytes: int = 0

    def stage_slots(self) -> int:
        return self.stage_max if self.stage_max > 0 else self.rows

    def admit_slots(self) -> int:
        # admits are bounded by the cache size; refresh adds at most one
        # entry per resident slot on top
        return self.rows * (2 if self.refresh else 1)


@dataclasses.dataclass
class CacheStats:
    """Lookahead/cache accounting (exported by ``etl_runtime.metrics``)."""

    lookups: int = 0        # index entries planned (excl. -1 padding)
    hits: int = 0           # served by a row already resident before the plan
    misses: int = 0         # lookups whose row was not resident
    admitted: int = 0       # rows copied table -> resident slots (incl. refresh)
    evicted: int = 0        # resident rows displaced by admission
    staged: int = 0         # unique cold rows staged per batch
    overflow_cold: int = 0  # lookups left to the partitioned fall-through
    row_bytes: int = 0

    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def gather_bytes_saved(self) -> float:
        """HBM gather traffic avoided vs the uncached kernel: every lookup
        would have been one table-row fetch; the cached path fetches only
        admitted + staged + fall-through rows."""
        fetched = self.admitted + self.staged + self.overflow_cold
        return max(0, self.lookups - fetched) * self.row_bytes

    def as_dict(self) -> dict:
        return {"lookups": self.lookups, "hits": self.hits,
                "misses": self.misses, "admitted": self.admitted,
                "evicted": self.evicted, "staged": self.staged,
                "overflow_cold": self.overflow_cold,
                "hit_rate": self.hit_rate(),
                "gather_bytes_saved": self.gather_bytes_saved()}


@dataclasses.dataclass
class PrefetchPlan:
    """Per-batch annotation, all host numpy, shapes static per config:

    slot  : int32[B, T]  ext-cache slot per lookup (-1 = fall through)
    cold  : int32[B, T]  original row where slot == -1 (-1 = padding lane)
    stage_rows  : int32[T, E]  rows staged into slots [rows, rows+E) (-1 pad)
    admit_slots : int32[T, A]  resident slots to overwrite before the batch
    admit_rows  : int32[T, A]  table rows to copy into those slots (-1 pad)
    """

    slot: np.ndarray
    cold: np.ndarray
    stage_rows: np.ndarray
    admit_slots: np.ndarray
    admit_rows: np.ndarray

    def as_payload(self) -> dict:
        return dict(zip(PLAN_KEYS, (self.slot, self.cold, self.stage_rows,
                                    self.admit_slots, self.admit_rows)))


class LookaheadPlanner:
    """Host-side window frequency + hot set + remap planner.

    Drive it with ``push(idx)`` as batches enter the window and
    ``pop_plan(idx)`` as the oldest batch is released (idx is that batch's
    int32 ``[B, T]`` column-selected index matrix).  The plan for a batch is
    computed while the batch itself and its W-1 successors are in the window.
    """

    def __init__(self, cfg: EmbedCacheConfig, n_tables: int,
                 stats: Optional[CacheStats] = None):
        self.cfg = cfg
        self.n_tables = n_tables
        self.stats = stats if stats is not None \
            else CacheStats(row_bytes=cfg.row_bytes)
        self._window: collections.deque = collections.deque()
        self._freq = [collections.Counter() for _ in range(n_tables)]
        self._slot_of = [dict() for _ in range(n_tables)]   # row -> slot
        self._row_of = [np.full(cfg.rows, -1, np.int64)
                        for _ in range(n_tables)]           # slot -> row
        self._free = [list(range(cfg.rows - 1, -1, -1))
                      for _ in range(n_tables)]

    # -- window maintenance ------------------------------------------------

    def push(self, idx: np.ndarray) -> None:
        """A batch entered the window: count its rows (padding -1 ignored)."""
        idx = np.asarray(idx)
        self._window.append(idx)
        for t in range(self.n_tables):
            col = idx[:, t]
            u, c = np.unique(col[col >= 0], return_counts=True)
            self._freq[t].update(dict(zip(u.tolist(), c.tolist())))

    def window_depth(self) -> int:
        return len(self._window)

    def resident_rows(self, t: int) -> np.ndarray:
        return self._row_of[t][self._row_of[t] >= 0]

    # -- planning ----------------------------------------------------------

    def pop_plan(self) -> tuple[np.ndarray, PrefetchPlan]:
        """Release the oldest window batch: plan it, retire its counts."""
        if not self._window:
            raise ValueError("pop_plan on an empty window")
        idx = self._window[0]
        plan = self._plan(idx)
        self._retire(self._window.popleft())
        return idx, plan

    def _retire(self, idx: np.ndarray) -> None:
        for t in range(self.n_tables):
            col = idx[:, t]
            u, c = np.unique(col[col >= 0], return_counts=True)
            freq = self._freq[t]
            freq.subtract(dict(zip(u.tolist(), c.tolist())))
            for r in u.tolist():
                if freq[r] <= 0:
                    del freq[r]

    def _plan(self, idx: np.ndarray) -> PrefetchPlan:
        cfg = self.cfg
        B, T = idx.shape
        E, A = cfg.stage_slots(), cfg.admit_slots()
        slot = np.full((B, T), -1, np.int32)
        cold = np.full((B, T), -1, np.int32)
        stage_rows = np.full((T, E), -1, np.int32)
        admit_slots = np.full((T, A), -1, np.int32)
        admit_rows = np.full((T, A), -1, np.int32)
        for t in range(self.n_tables):
            self._plan_table(t, idx[:, t], slot[:, t], cold[:, t],
                             stage_rows[t], admit_slots[t], admit_rows[t])
        return PrefetchPlan(slot, cold, stage_rows, admit_slots, admit_rows)

    def _plan_table(self, t: int, col, slot_out, cold_out, stage_out,
                    admit_slot_out, admit_row_out) -> None:
        cfg, st = self.cfg, self.stats
        freq, slot_of, row_of = self._freq[t], self._slot_of[t], self._row_of[t]
        valid = col >= 0
        u, inv = np.unique(col[valid], return_inverse=True)
        resident_before = np.fromiter(
            (slot_of.get(int(r), -1) for r in u), np.int32, len(u))

        # admission: window-frequent rows displace the coldest residents
        desired = [r for r, c in freq.most_common(cfg.rows)
                   if c >= cfg.min_admit_freq]
        admits = [r for r in desired if r not in slot_of]
        n_admit = 0
        if admits:
            desired_set = set(desired)
            victims = sorted((r for r in row_of[row_of >= 0].tolist()
                              if r not in desired_set),
                             key=lambda r: freq[r] if r in freq else 0)
            for row in admits:
                if self._free[t]:
                    s = self._free[t].pop()
                elif victims:
                    old = victims.pop(0)
                    s = slot_of.pop(old)
                    st.evicted += 1
                else:
                    break  # cache full of desired rows: stop admitting
                slot_of[row] = s
                row_of[s] = row
                admit_slot_out[n_admit] = s
                admit_row_out[n_admit] = row
                n_admit += 1
        st.admitted += n_admit

        # remap against the post-admission resident set
        resident_after = np.fromiter(
            (slot_of.get(int(r), -1) for r in u), np.int32, len(u))
        hit_u = (resident_before >= 0) & (resident_after >= 0)
        counts = np.bincount(inv, minlength=len(u))
        st.lookups += int(valid.sum())
        st.hits += int(counts[hit_u].sum())
        st.misses += int(valid.sum()) - int(counts[hit_u].sum())

        # stage this batch's cold rows just-in-time (dedup'd); overflow
        # falls through the kernel's partitioned pass
        cold_u = np.flatnonzero(resident_after < 0)
        staged_u = cold_u[: len(stage_out)]
        stage_out[: len(staged_u)] = u[staged_u]
        ext_slot = resident_after.copy()
        ext_slot[staged_u] = cfg.rows + np.arange(len(staged_u), dtype=np.int32)
        st.staged += len(staged_u)
        overflow_u = np.zeros(len(u), bool)
        overflow_u[cold_u[len(stage_out):]] = True
        st.overflow_cold += int(counts[overflow_u].sum())

        if cfg.refresh:
            # exactness under training: re-copy every referenced resident
            # row from the current table (HBM still touched once per unique
            # row — the dedup win — never once per lookup)
            ref_u = np.flatnonzero(hit_u)
            n_ref = min(len(ref_u), len(admit_slot_out) - n_admit)
            admit_slot_out[n_admit:n_admit + n_ref] = resident_after[ref_u[:n_ref]]
            admit_row_out[n_admit:n_admit + n_ref] = u[ref_u[:n_ref]]
            st.admitted += n_ref

        slot_out[valid] = ext_slot[inv]
        cold_full = np.where(ext_slot < 0, u, -1).astype(np.int32)
        cold_out[valid] = cold_full[inv]


class LookaheadStage(threading.Thread):
    """Executor stage: window W envelopes after place, annotate with plans.

    Mirrors ``_SortStage``'s shape: bounded buffering, EOS drains the
    partial window, stop aborts promptly, errors surface via ``on_error``.
    Reading the index matrix synchronizes that payload's device future —
    acceptable here because the stage sits behind the transform dispatch and
    its host work is the point (plans ride the envelope, device work at the
    consumer stays dense).
    """

    def __init__(self, stats, in_q, out_q, cfg: EmbedCacheConfig, *,
                 cache_stats: Optional[CacheStats] = None,
                 drop_oldest: bool = False,
                 on_put: Optional[Callable[[int], None]] = None,
                 on_error: Optional[Callable[[BaseException], None]] = None,
                 clock=None):
        super().__init__(name=f"etl-{stats.name}", daemon=True)
        from repro.etl_runtime.clock import SYSTEM_CLOCK
        self.stats = stats
        self.in_q = in_q
        self.out_q = out_q
        self.cfg = cfg
        self.cache_stats = cache_stats
        # the planner is built on the first batch: with cfg.tables=None the
        # index-matrix width is only known once a payload arrives
        self.planner: Optional[LookaheadPlanner] = None
        self.drop_oldest = drop_oldest
        self.on_put = on_put
        self.on_error = on_error
        self._clock = clock or SYSTEM_CLOCK
        self._buf: collections.deque = collections.deque()
        # live window knob (the controller's lookahead_window actuator);
        # frequency counts always cover the in-flight buffer whatever the
        # current target, so shrinking mid-run just drains the excess
        self._window = max(1, cfg.window)

    def set_window(self, window: int) -> None:
        """Retarget the lookahead depth W; takes effect on the next batch
        (a shrink releases the now-excess envelopes then)."""
        self._window = max(1, int(window))

    def _indices(self, payload) -> np.ndarray:
        idx = np.asarray(payload[self.cfg.key])
        if idx.ndim != 2:
            raise ValueError(
                f"lookahead key {self.cfg.key!r} must be a [batch, tables] "
                f"index matrix, got shape {idx.shape}")
        if self.cfg.tables is not None:
            idx = idx[:, list(self.cfg.tables)]
        return idx.astype(np.int64, copy=False)

    def _release(self) -> bool:
        env = self._buf.popleft()
        _, plan = self.planner.pop_plan()
        payload = dict(env.payload)
        payload.update(plan.as_payload())
        mono = self._clock.monotonic
        t0 = mono()
        r = self.out_q.put(replace(env, payload=payload),
                           drop_oldest=self.drop_oldest)
        self.stats.wait_out_s += mono() - t0
        from repro.etl_runtime.runtime import _STOPPED
        if r is _STOPPED:
            return False
        self.stats.items += 1
        self.stats.drop_oldest += r
        if self.on_put:
            self.on_put(r)
        return True

    def run(self):
        from repro.etl_runtime.runtime import _EOS, _STOPPED
        mono = self._clock.monotonic
        while True:
            t0 = mono()
            item = self.in_q.get()
            self.stats.wait_in_s += mono() - t0
            if item is _STOPPED:
                return
            if item is _EOS:
                while self._buf:
                    t1 = mono()
                    ok = self._release()
                    self.stats.busy_s += mono() - t1
                    if not ok:
                        return
                self.out_q.put(_EOS)
                return
            t1 = mono()
            try:
                idx = self._indices(item.payload)
                if self.planner is None:
                    self.planner = LookaheadPlanner(
                        self.cfg, idx.shape[1], stats=self.cache_stats)
                self.planner.push(idx)
                self._buf.append(item)
                ok = True
                # drain to the live window target (shrunk knobs release the
                # excess; at steady state this pops exactly one per push)
                while ok and len(self._buf) >= self._window:
                    ok = self._release()
            except Exception as e:
                if self.on_error:
                    self.on_error(e)
                return
            self.stats.busy_s += mono() - t1
            if not ok:
                return


# ---------------------------------------------------------------------------
# device side: cache tensor lifecycle + differentiable cached lookup
# ---------------------------------------------------------------------------

class EmbedCache:
    """Device-resident stacked cache ``[T, rows + stage_max, dim]`` plus the
    per-batch ``advance`` that consumes ``PLAN_KEYS`` annotations.

    ``advance(tables, batch)`` pops the plan arrays from the payload dict,
    applies the admit plan and the per-batch staging from the CURRENT
    ``tables`` (``[T, vocab, dim]``) with two dense vmapped scatters (planned
    once on the host, so the device work has static shapes), and returns the
    batch with ``emb_cache`` / ``emb_slot`` / ``emb_cold`` kernel inputs.
    Batches carrying plans must be advanced in delivery order — the planner's
    host mirror assumes every admit executes.
    """

    def __init__(self, cfg: EmbedCacheConfig, n_tables: int, dim: int,
                 dtype=np.float32):
        import jax
        import jax.numpy as jnp

        self.cfg = cfg
        self.n_tables = n_tables
        self.dim = dim
        rows, stage = cfg.rows, cfg.stage_slots()
        self.ext = jnp.zeros((n_tables, rows + stage, dim), dtype)
        self.generation = 0  # bumped by invalidate() on state-version swaps

        def _apply(ext, tables, admit_slots, admit_rows, stage_rows):
            ce = rows + stage
            gather = jax.vmap(lambda tb, r: tb[jnp.clip(r, 0)])
            adm_vals = gather(tables, admit_rows)
            safe_slots = jnp.where(admit_slots < 0, ce, admit_slots)
            ext = jax.vmap(
                lambda c, s, v: c.at[s].set(v, mode="drop"))(
                    ext, safe_slots, adm_vals)
            stage_vals = gather(tables, stage_rows)
            return ext.at[:, rows:, :].set(stage_vals)

        self._apply = jax.jit(_apply, donate_argnums=(0,))

    def invalidate(self) -> None:
        """Zero every cache row on a vocabulary state-version swap.

        An incremental refit (``CompiledPipeline.fit_incremental``) keeps
        existing value→rank assignments, so the planner's slot→row mapping
        stays valid across the swap — but cached row *contents* may belong
        to the pre-swap embedding landscape, so the trainer drops them all.
        Requires ``cfg.refresh=True`` to be bit-exact afterwards: refresh
        re-admits every referenced resident from the current tables before
        its next use, so no lookup ever reads an invalidated (zeroed) row.
        ``generation`` counts swaps for observability.
        """
        import jax.numpy as jnp

        self.ext = jnp.zeros_like(self.ext)
        self.generation += 1

    def advance(self, tables, batch: dict) -> dict:
        import jax.numpy as jnp

        if PLAN_KEYS[0] not in batch:
            return batch  # un-planned batch (e.g. warmup before the window)
        batch = dict(batch)
        slot, cold, stage_rows, admit_slots, admit_rows = (
            batch.pop(k) for k in PLAN_KEYS)
        self.ext = self._apply(self.ext, tables,
                               jnp.asarray(admit_slots),
                               jnp.asarray(admit_rows),
                               jnp.asarray(stage_rows))
        batch["emb_cache"] = self.ext
        batch["emb_slot"] = jnp.asarray(slot)
        batch["emb_cold"] = jnp.asarray(cold)
        return batch


def cached_embedding_lookup(tables, cache, slot, cold, orig, *,
                            partitions: int = 1,
                            interpret: "bool | None" = None):
    """Differentiable per-feature cached lookup: ``(B, T)`` single-hot
    indices against stacked ``tables [T, V, d]`` and ``cache [T, C, d]``,
    returning ``(B, T, d)``.

    Forward resolves each feature through ``kernels.embedding_bag_cached``
    (hot slots from the cache tile, cold rows through the partitioned table
    pass).  Backward scatter-adds the cotangent into the TABLE at the
    original row ids ``orig`` — the exact uncached gradient — and sends a
    zero to the cache (its rows mirror table rows, so all sensitivity
    belongs to the table).
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels import embedding_bag as bag

    n_tables = tables.shape[0]
    vocab = tables.shape[1]

    @jax.custom_vjp
    def lookup(tables, cache):
        outs = [bag.embedding_bag_cached(
            tables[t], cache[t], slot[:, t:t + 1], cold[:, t:t + 1],
            partitions=partitions, interpret=interpret)
            for t in range(n_tables)]
        return jnp.stack(outs, axis=1)  # (B, T, d)

    def fwd(tables, cache):
        return lookup(tables, cache), ()

    def bwd(_, g):  # g: (B, T, d)
        safe = jnp.where(orig < 0, vocab, orig)  # -1 lanes drop
        d_tables = jax.vmap(
            lambda o, gt: jnp.zeros(tables.shape[1:], g.dtype)
            .at[o].add(gt, mode="drop"))(safe.T, g.transpose(1, 0, 2))
        return d_tables.astype(tables.dtype), jnp.zeros_like(cache)

    lookup.defvjp(fwd, bwd)
    return lookup(tables, cache)
