"""Clock seam for the streaming runtime (deterministic-time testing).

Every timing call in the staged executor — stage busy/wait accounting,
queue ``get`` deadlines, delivered-staleness stamps — goes through an
injected ``Clock`` instead of calling ``time.monotonic()`` directly.
Production code never notices (``SYSTEM_CLOCK`` delegates to ``time``),
but tests can inject a ``VirtualClock`` whose "now" only moves when the
test advances it, so timing-dependent behavior (overlap margins, adaptive
credits, the self-tuning controller's observation windows) is exercised
deterministically instead of through wall-clock sleeps.

``tests/simclock.py`` builds the full discrete-event pipeline simulation
on top of ``VirtualClock``; this module holds only the seam itself so the
runtime has no test-directory dependency.
"""

from __future__ import annotations

import threading
import time


class Clock:
    """Monotonic-time source. ``monotonic()`` returns seconds as a float
    (comparable only against the same clock); ``sleep(s)`` passes time."""

    def monotonic(self) -> float:
        raise NotImplementedError

    def sleep(self, seconds: float) -> None:
        raise NotImplementedError


class SystemClock(Clock):
    """Wall-clock implementation: ``time.monotonic`` / ``time.sleep``."""

    monotonic = staticmethod(time.monotonic)
    sleep = staticmethod(time.sleep)


#: process-wide default; components take ``clock=None`` and fall back here
SYSTEM_CLOCK = SystemClock()


class VirtualClock(Clock):
    """Logical clock for deterministic tests: ``monotonic()`` returns the
    current logical time, which only moves via ``advance`` (or ``sleep``,
    which advances instead of blocking).  Thread-safe, so runtime threads
    reading timestamps while a test advances time never tear a read."""

    def __init__(self, start: float = 0.0):
        self._now = float(start)
        self._lock = threading.Lock()

    def monotonic(self) -> float:
        with self._lock:
            return self._now

    def advance(self, seconds: float) -> float:
        """Move logical time forward by ``seconds`` (never backward)."""
        with self._lock:
            self._now += max(0.0, float(seconds))
            return self._now

    def sleep(self, seconds: float) -> None:
        self.advance(seconds)
