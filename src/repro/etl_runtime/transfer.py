"""Zero-copy handoff from the ETL engine to the trainer (paper's P2P DMA).

On a real TPU pod the ETL apply-program runs on the same mesh as the trainer,
and its outputs are produced *already laid out* with the exact NamedSharding
``train_step`` declares in ``in_shardings``.  The handoff is then a device-
resident buffer passed by reference (and donated by the trainer) — no host
staging, no reshard, no copy: the TPU statement of "the FPGA writes training-
ready batches directly into GPU HBM".

This module provides the placement helpers plus a host-fallback path
(jax.device_put) used when the raw source lives in host memory.
"""

from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def batch_sharding(mesh: Optional[Mesh], data_axes=("pod", "data")) -> Optional[NamedSharding]:
    """Row-sharded (batch-dim) placement over the data axes of the mesh."""
    if mesh is None:
        return None
    axes = tuple(a for a in data_axes if a in mesh.axis_names)
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))


def donation_ready(batch: dict) -> bool:
    """True when every value is a jax.Array the trainer can donate.

    ``put_packed`` output always satisfies this; host numpy batches do not
    (XLA copies them on dispatch, so donation would be meaningless).  Pair
    with ``jit_train_step(..., donate_batch=True)`` to complete the
    zero-copy handoff.
    """
    return all(isinstance(v, jax.Array) for v in batch.values())


def put_packed(batch: dict, sharding: Optional[NamedSharding]) -> dict:
    """Place a packed batch onto the mesh, sharded along rows (batch dim).

    The returned arrays are committed device buffers in the trainer's
    declared layout — donation-ready: a ``donate_argnums`` train step can
    alias their HBM instead of copying.
    """
    if sharding is None:
        return {k: jax.device_put(v) for k, v in batch.items()}
    out = {}
    for k, v in batch.items():
        spec = sharding.spec
        nd = np.ndim(v)
        row_spec = P(*( (spec[0],) + (None,) * (nd - 1) ))
        out[k] = jax.device_put(v, NamedSharding(sharding.mesh, row_spec))
    return out


def transfer_stats(batch: dict) -> dict:
    """Bytes moved for the Fig-11 style transfer micro-benchmark."""
    total = 0
    for v in batch.values():
        total += np.dtype(v.dtype).itemsize * int(np.prod(np.shape(v)))
    return {"bytes": total}
