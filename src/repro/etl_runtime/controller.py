"""Self-tuning pipeline controller: one owner for every runtime knob.

The paper's end-to-end win depends on the ETL stages being balanced
against the training consumer; before this module that balance was spread
across hand-tuned knobs (staging credits, prefetch depth, the planner's
row tile, per-output fuse decisions, the lookahead window) plus one
ad-hoc actuator (the executor's adaptive-credits rule).  The
``PipelineController`` unifies them behind a declared-knob interface and
a single sensor → decision → actuator loop:

- **sensor**: per-delivery observations (trainer wait, ready-queue
  fullness) aggregated into epoch-aligned observation windows, each
  yielding one measured throughput sample (batches/sec on the injected
  ``Clock``).
- **decision**: per window, in priority order —

  1. *memory-pressure guard*: when the host-memory-pressure callable
     crosses the threshold, the optimizer is preempted (any in-flight
     probe is reverted) and queue-bytes knobs shrink first, largest
     estimated footprint first; compute knobs shrink only once every
     queue knob sits at its floor.
  2. *occupancy rule* (``mode="occupancy"``, the adaptive-credits
     successor): grow credits when the trainer starved on at least half
     the window's deliveries, shrink when the window saw zero starvation
     and every pop found the queue full — with hysteresis: reversing
     direction within ``hysteresis`` windows of the last resize is
     suppressed, so adjacent grow/shrink thresholds cannot oscillate.
  3. *hill climber* (``mode="throughput"``): seeded coordinate search
     over the declared knobs.  One knob moves one candidate step per
     window; the next window's measured throughput accepts the move
     (improvement beyond ``tolerance``) or reverts it.  An accepted move
     keeps climbing the same direction; a revert flips direction, and a
     knob dead in both directions is retired until a regime change
     (throughput drifting >10% off the converged baseline) reopens the
     search.

- **actuator**: each ``Knob`` carries its own apply callback (executor
  ``set_credits``/``set_prefetch_depth``/``set_lookahead_window``,
  ``EtlJob``'s recompile-and-swap for ``row_tile``/fuse, or a plain dict
  write in simulation).

Every decision is recorded (``decisions`` / ``decision_counts()``) and
every knob's live value is exported (``knob_values()``) — surfaced as
Prometheus gauges by ``etl_runtime.metrics``.  The loop is deterministic
under a fixed seed; ``tests/simclock.py`` drives it against a simulated
pipeline so convergence tests run in milliseconds.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Callable, Optional

from repro.etl_runtime.clock import SYSTEM_CLOCK, Clock

#: deliveries per occupancy-mode decision window (the legacy
#: adaptive-credits cadence; kept so pinned resize counters are exact)
OCCUPANCY_WINDOW = 4

#: a delivery that waited longer than this counts as trainer starvation
STARVED_EPS_S = 1e-3


@dataclasses.dataclass
class Knob:
    """One declared tunable: ordered candidate values + an actuator.

    ``candidates`` is the knob's legal domain in search order (ascending
    for numeric knobs); bounds are ``candidates[0]`` / ``candidates[-1]``
    and the controller never applies a value outside them.  ``kind`` is
    ``"queue"`` for knobs whose value holds batches in host/device memory
    (credits, prefetch depth, lookahead window) — the memory-pressure
    guard shrinks those first — and ``"compute"`` otherwise (row tile,
    fuse).  ``bytes_per_unit`` estimates queued bytes per unit of a
    numeric queue knob's value.
    """

    name: str
    candidates: tuple
    value: object = None
    apply: Optional[Callable] = None   # actuator: apply(value) -> None
    get: Optional[Callable] = None     # live read-back (defaults to .value)
    kind: str = "compute"              # "queue" | "compute"
    bytes_per_unit: int = 0

    def __post_init__(self):
        self.candidates = tuple(self.candidates)
        if not self.candidates:
            raise ValueError(f"knob {self.name!r} has no candidates")
        if self.value is None:
            self.value = self.candidates[0]
        if self.value not in self.candidates:
            raise ValueError(f"knob {self.name!r} initial value "
                             f"{self.value!r} not in candidates")

    def read(self):
        """Current live value (via ``get`` when bound, else the tracked
        one); clamped into the candidate domain."""
        v = self.get() if self.get is not None else self.value
        return v if v in self.candidates else min(
            self.candidates, key=lambda c: abs(_num(c) - _num(v)))

    def set(self, value) -> None:
        if value not in self.candidates:
            raise ValueError(f"knob {self.name!r}: {value!r} out of bounds")
        self.value = value
        if self.apply is not None:
            self.apply(value)

    def index(self) -> int:
        return self.candidates.index(self.read())

    def queued_bytes(self) -> int:
        """Estimated host/device bytes this knob's current value pins."""
        if self.kind != "queue":
            return 0
        v = self.read()
        return int(self.bytes_per_unit * (_num(v)))


def _num(v) -> float:
    """Numeric view of a knob value (bools/ints/floats pass through;
    anything else ranks by identity-ish hash — only used for clamping)."""
    if isinstance(v, (bool, int, float)):
        return float(v)
    return float(abs(hash(v)) % (1 << 16))


@dataclasses.dataclass
class Decision:
    """One controller action, for tests/metrics: what moved, when, why."""

    window: int
    knob: str
    action: str  # probe | accept | revert | grow | shrink | pressure-shrink
    value: object

    def as_tuple(self) -> tuple:
        return (self.window, self.knob, self.action, self.value)


class PipelineController:
    """Measured-throughput knob search with a memory-pressure guard.

    Parameters
    ----------
    knobs : declared ``Knob`` list (may be empty and bound later via
        ``bind_executor`` — the ``autotune=`` path).
    mode : ``"throughput"`` (hill climber over windowed throughput) or
        ``"occupancy"`` (the adaptive-credits successor: starvation/
        fullness rule over the first — usually only — knob).
    clock : timing source for window throughput; defaults to the system
        clock and adopts the executor's clock on ``bind_executor``.
    seed : RNG seed; the search is bit-deterministic under a fixed seed.
    window_deliveries : deliveries per observation window in
        ``on_delivery``-driven (real-runtime) operation.
    tolerance : relative throughput gain a probe must show to be accepted.
    hysteresis : minimum windows between direction-reversing resizes
        (occupancy mode's oscillation damper).
    memory_pressure : optional callable -> [0, 1] host-memory pressure,
        polled every window; ``pressure_threshold`` arms the guard.
    """

    def __init__(self, knobs: Optional[list] = None, *,
                 mode: str = "throughput",
                 clock: Optional[Clock] = None, seed: int = 0,
                 window_deliveries: int = 8, tolerance: float = 0.02,
                 hysteresis: int = 2,
                 memory_pressure: Optional[Callable[[], float]] = None,
                 pressure_threshold: float = 0.9,
                 starved_eps_s: float = STARVED_EPS_S):
        if mode not in ("throughput", "occupancy"):
            raise ValueError(f"unknown controller mode {mode!r}")
        self.knobs: list[Knob] = list(knobs or [])
        self.mode = mode
        self.clock = clock or SYSTEM_CLOCK
        self.seed = seed
        self.rng = random.Random(seed)
        self.window_deliveries = max(1, window_deliveries)
        self.tolerance = tolerance
        self.hysteresis = max(0, hysteresis)
        self.memory_pressure = memory_pressure
        self.pressure_threshold = pressure_threshold
        self.starved_eps_s = starved_eps_s
        self.decisions: list[Decision] = []
        self.suppressed_flips = 0      # hysteresis-suppressed reversals
        # per-delivery accumulation (real-runtime sensor)
        self._deliveries: list[tuple] = []   # (wait_s, ready_full)
        self._window_t0: Optional[float] = None
        # window counter + hill-climber state
        self._window = 0
        self._baseline: Optional[float] = None
        self._probe: Optional[tuple] = None       # (Knob, old_value)
        self._dir: dict[str, int] = {}
        self._flipped: dict[str, bool] = {}
        self._exhausted: set[str] = set()
        self._cursor = 0
        self._cursor_init = False
        self._best: Optional[tuple] = None        # (tput, {name: value})
        # occupancy-mode resize bookkeeping (hysteresis)
        self._last_resize_window: Optional[int] = None
        self._last_resize_dir = 0

    # ---- construction helpers -------------------------------------------

    @classmethod
    def for_executor(cls, executor, *, seed: int = 0,
                     window_deliveries: int = 8,
                     memory_pressure: Optional[Callable[[], float]] = None,
                     batch_bytes: int = 1 << 20,
                     **kw) -> "PipelineController":
        """Throughput-mode controller over an executor's runtime knobs."""
        ctrl = cls([], mode="throughput", clock=executor.clock, seed=seed,
                   window_deliveries=window_deliveries,
                   memory_pressure=memory_pressure, **kw)
        ctrl.bind_executor(executor, batch_bytes=batch_bytes)
        return ctrl

    @classmethod
    def adaptive_credits(cls, executor, *, hysteresis: int = 2,
                         memory_pressure: Optional[Callable[[], float]] = None
                         ) -> "PipelineController":
        """The ``adaptive_credits=True`` compatibility controller: the
        legacy occupancy rule (same thresholds, same 4-delivery window)
        on the credits knob only, plus hysteresis against grow/shrink
        oscillation.  Floor = the configured ``credits``, ceiling =
        ``max_credits`` — resize counters land in the executor's stats
        exactly as before."""
        lo, hi = executor.credits, executor.max_credits
        knob = Knob("credits", tuple(range(lo, hi + 1)),
                    value=min(max(executor.current_credits, lo), hi),
                    apply=executor.set_credits,
                    get=lambda: executor.current_credits,
                    kind="queue")
        return cls([knob], mode="occupancy", clock=executor.clock,
                   window_deliveries=OCCUPANCY_WINDOW,
                   hysteresis=hysteresis, memory_pressure=memory_pressure)

    def bind_executor(self, executor, *, batch_bytes: int = 1 << 20) -> None:
        """Attach executor-owned knobs (credits, prefetch depth, lookahead
        window) unless the caller already declared knobs with those names;
        adopts the executor's clock.  Called by ``StreamingExecutor`` when
        a controller instance is passed as ``autotune=``."""
        self.clock = executor.clock
        have = {k.name for k in self.knobs}
        n_queues = len(executor.stage_queues())
        if "credits" not in have:
            self.knobs.append(Knob(
                "credits", tuple(range(1, executor.max_credits + 1)),
                value=executor.current_credits,
                apply=executor.set_credits,
                get=lambda: executor.current_credits,
                kind="queue", bytes_per_unit=batch_bytes * n_queues))
        if "prefetch_depth" not in have:
            cands = tuple(sorted({1, 2, 4, executor.max_credits}))
            depth = min(cands, key=lambda c: abs(c - executor.credits))
            self.knobs.append(Knob(
                "prefetch_depth", cands, value=depth,
                apply=executor.set_prefetch_depth,
                kind="queue", bytes_per_unit=batch_bytes))
        if executor.lookahead is not None and "lookahead_window" not in have:
            w = max(1, executor.lookahead.window)
            cands = tuple(sorted({w, 2, 4, 8, 16}))
            self.knobs.append(Knob(
                "lookahead_window", cands, value=w,
                apply=executor.set_lookahead_window,
                kind="queue", bytes_per_unit=batch_bytes))

    # ---- sensors ---------------------------------------------------------

    def on_delivery(self, *, wait_s: float, ready_full: bool,
                    now: Optional[float] = None) -> list:
        """Per-delivery hook (the executor calls this from the consumer
        side).  Aggregates ``window_deliveries`` deliveries into one
        observation window and runs the decision step at each boundary.
        Returns the decisions taken (usually empty)."""
        now = self.clock.monotonic() if now is None else now
        if self._window_t0 is None:
            self._window_t0 = now - wait_s  # window opens at first wait
        self._deliveries.append((wait_s, ready_full))
        if len(self._deliveries) < self.window_deliveries:
            return []
        span = max(now - self._window_t0, 1e-9)
        throughput = len(self._deliveries) / span
        starved = sum(1 for w, _ in self._deliveries
                      if w > self.starved_eps_s)
        always_full = all(f for _, f in self._deliveries)
        self._deliveries.clear()
        self._window_t0 = now
        return self.observe_window(throughput, starved=starved,
                                   always_full=always_full)

    # ---- decision loop ---------------------------------------------------

    def observe_window(self, throughput: float, *, starved: int = 0,
                       always_full: bool = False) -> list:
        """One observation window: run the guard + the mode's policy.

        ``throughput`` is the window's measured delivery rate;
        ``starved``/``always_full`` feed the occupancy rule.  Returns the
        decisions taken this window (also appended to ``decisions``)."""
        self._window += 1
        out: list[Decision] = []
        if self._pressure_step(out):
            self.decisions.extend(out)
            return out
        if self.mode == "occupancy":
            self._occupancy_step(out, starved=starved,
                                 always_full=always_full)
        else:
            self._climb_step(out, throughput)
        self.decisions.extend(out)
        return out

    # -- memory-pressure guard --------------------------------------------

    def _pressure_step(self, out: list) -> bool:
        if self.memory_pressure is None:
            return False
        if self.memory_pressure() < self.pressure_threshold:
            return False
        # preempt the optimizer: an in-flight probe is reverted first so
        # the shrink below starts from known-good settings
        if self._probe is not None:
            knob, old = self._probe
            knob.set(old)
            out.append(Decision(self._window, knob.name, "revert", old))
            self._probe = None
            self._baseline = None  # re-measure once pressure clears
        # queue-bytes knobs first, largest estimated footprint first
        qknobs = [k for k in self.knobs
                  if k.kind == "queue" and k.index() > 0]
        qknobs.sort(key=lambda k: (-k.queued_bytes(), k.name))
        targets = qknobs or [k for k in self.knobs
                             if k.kind != "queue" and k.index() > 0]
        for k in targets:
            k.set(k.candidates[k.index() - 1])
            out.append(Decision(self._window, k.name, "pressure-shrink",
                                k.value))
        return True

    # -- occupancy rule (adaptive-credits successor) -----------------------

    def _occupancy_step(self, out: list, *, starved: int,
                        always_full: bool) -> None:
        knob = self.knobs[0]
        cur = knob.read()
        idx = knob.candidates.index(cur)
        want = 0
        if (starved >= self.window_deliveries // 2
                and idx < len(knob.candidates) - 1):
            want = 1
        elif starved == 0 and always_full and idx > 0:
            want = -1
        if want == 0:
            return
        # hysteresis: a direction reversal within the damper window is
        # suppressed — adjacent grow/shrink thresholds cannot ping-pong
        if (self._last_resize_dir and want != self._last_resize_dir
                and self._last_resize_window is not None
                and self._window - self._last_resize_window <= self.hysteresis):
            self.suppressed_flips += 1
            return
        knob.set(knob.candidates[idx + want])
        out.append(Decision(self._window, knob.name,
                            "grow" if want > 0 else "shrink", knob.value))
        self._last_resize_dir = want
        self._last_resize_window = self._window

    # -- throughput hill climber ------------------------------------------

    def _climb_step(self, out: list, throughput: float) -> None:
        if self._baseline is None:
            # settle window: measure before moving anything
            self._baseline = throughput
            self._note_best(throughput)
            self._begin_probe(out)
            return
        if self._probe is None:
            # converged (every knob retired): hold, but watch for a
            # regime change — >10% drift reopens the search
            self._note_best(throughput)
            if abs(throughput - self._baseline) > 0.10 * self._baseline:
                self._baseline = throughput
                self._exhausted.clear()
                self._flipped.clear()
            self._begin_probe(out)
            return
        knob, old = self._probe
        self._probe = None
        if throughput > self._baseline * (1.0 + self.tolerance):
            out.append(Decision(self._window, knob.name, "accept",
                                knob.value))
            self._baseline = throughput
            self._note_best(throughput)
            self._flipped[knob.name] = False  # keep climbing this way
        else:
            knob.set(old)
            out.append(Decision(self._window, knob.name, "revert", old))
            if self._flipped.get(knob.name):
                self._exhausted.add(knob.name)
                self._cursor += 1
            else:
                self._flipped[knob.name] = True
                self._dir[knob.name] = -self._dir.get(knob.name, 1)
        self._begin_probe(out)

    def _begin_probe(self, out: list) -> None:
        if not self.knobs:
            return
        if not self._cursor_init:
            # seeded start: which knob the search opens with is the RNG's
            # only job — every later step is order-deterministic
            self._cursor = self.rng.randrange(len(self.knobs))
            self._cursor_init = True
        for _ in range(len(self.knobs)):
            knob = self.knobs[self._cursor % len(self.knobs)]
            if (knob.name in self._exhausted
                    or len(knob.candidates) < 2):
                self._cursor += 1
                continue
            idx = knob.index()
            d = self._dir.setdefault(knob.name, 1)
            if not 0 <= idx + d < len(knob.candidates):
                if self._flipped.get(knob.name):
                    self._exhausted.add(knob.name)
                    self._cursor += 1
                    continue
                self._flipped[knob.name] = True
                d = self._dir[knob.name] = -d
                if not 0 <= idx + d < len(knob.candidates):
                    self._exhausted.add(knob.name)
                    self._cursor += 1
                    continue
            old = knob.candidates[idx]
            knob.set(knob.candidates[idx + d])
            self._probe = (knob, old)
            out.append(Decision(self._window, knob.name, "probe",
                                knob.value))
            return
        self._probe = None  # everything retired: converged

    def _note_best(self, throughput: float) -> None:
        if self._best is None or throughput > self._best[0]:
            self._best = (throughput, self.knob_values())

    # ---- observability / restore ----------------------------------------

    def knob_values(self) -> dict:
        return {k.name: k.read() for k in self.knobs}

    def decision_counts(self) -> dict:
        counts: dict[str, int] = {}
        for d in self.decisions:
            counts[d.action] = counts.get(d.action, 0) + 1
        return counts

    def total_queued_bytes(self) -> int:
        """Estimated bytes pinned by queue-kind knobs at current values."""
        return sum(k.queued_bytes() for k in self.knobs)

    def best_settings(self) -> Optional[dict]:
        """Knob values of the best window observed so far (None before
        the first measurement)."""
        return dict(self._best[1]) if self._best is not None else None

    def restore_best(self) -> dict:
        """Apply the best-known settings (reverting any in-flight probe)
        and return them — call at the end of a tuning run so the pipeline
        never finishes on a worse-than-start probe."""
        if self._probe is not None:
            knob, old = self._probe
            knob.set(old)
            self._probe = None
        best = self.best_settings()
        if best:
            for k in self.knobs:
                if k.name in best and k.read() != best[k.name]:
                    k.set(best[k.name])
        return best or self.knob_values()

    @property
    def window(self) -> int:
        return self._window

    def decision_log(self) -> list[tuple]:
        """The full decision history as plain tuples (determinism pin)."""
        return [d.as_tuple() for d in self.decisions]
