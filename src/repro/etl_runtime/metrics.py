"""Prometheus-style text exposition of runtime stats (ops satellite).

The staged executor already accounts every stage's items / busy / wait-in /
wait-out (``StageStats``, the paper's Fig-8 breakdown).  This module renders
those counters — plus any ad-hoc scalar map — in the Prometheus text format
so launchers can expose them via ``--metrics-file`` (scrape the file with
node_exporter's textfile collector) without taking a client-library
dependency.

Only ``counter``/``gauge`` text lines are emitted; values are cumulative
since executor start, which is exactly Prometheus counter semantics.
"""

from __future__ import annotations

import os
from typing import Mapping, Optional

from repro.etl_runtime.runtime import RuntimeStats


def _escape(value: str) -> str:
    """Escape a label value per the Prometheus exposition format."""
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt_labels(labels: Optional[Mapping[str, str]]) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{_escape(v)}"' for k, v in sorted(labels.items()))
    return "{" + inner + "}"


def counters_to_prometheus(values: Mapping[str, float], *,
                           prefix: str = "repro",
                           labels: Optional[Mapping[str, str]] = None) -> str:
    """Render a flat name -> value map as Prometheus counter lines."""
    lines = []
    for name in sorted(values):
        metric = f"{prefix}_{name}"
        lines.append(f"# TYPE {metric} counter")
        lines.append(f"{metric}{_fmt_labels(labels)} {values[name]:.9g}")
    return "\n".join(lines) + "\n"


def stats_to_prometheus(stats: RuntimeStats, *, prefix: str = "repro_etl",
                        labels: Optional[Mapping[str, str]] = None) -> str:
    """Render RuntimeStats (incl. per-stage StageStats) as Prometheus text.

    Per-stage series carry a ``stage`` label; top-level counters mirror the
    produced/consumed/drop accounting.
    """
    base = dict(labels or {})
    lines = []

    top = {"produced_total": stats.produced,
           "consumed_total": stats.consumed,
           "dropped_stale_total": stats.dropped_stale,
           "skipped_straggler_total": stats.skipped_straggler,
           "consumer_wait_seconds_total": stats.consumer_wait_s,
           "credit_grows_total": stats.credit_grows,
           "credit_shrinks_total": stats.credit_shrinks,
           "raw_queue_resizes_total": stats.raw_resizes}
    for name in sorted(top):
        metric = f"{prefix}_{name}"
        lines.append(f"# TYPE {metric} counter")
        lines.append(f"{metric}{_fmt_labels(base)} {top[name]:.9g}")

    stage_series = {"stage_items_total": lambda s: s.items,
                    "stage_busy_seconds_total": lambda s: s.busy_s,
                    "stage_wait_in_seconds_total": lambda s: s.wait_in_s,
                    "stage_wait_out_seconds_total": lambda s: s.wait_out_s,
                    "stage_drop_oldest_total": lambda s: s.drop_oldest}
    for name in sorted(stage_series):
        metric = f"{prefix}_{name}"
        lines.append(f"# TYPE {metric} counter")
        get = stage_series[name]
        for stage_name in stats.stages:
            lbl = _fmt_labels({**base, "stage": stage_name})
            lines.append(f"{metric}{lbl} {get(stats.stages[stage_name]):.9g}")

    # delivered-batch staleness (seconds since Source.arrival) as a real
    # Prometheus histogram, plus the ingest rate gauge — the online-training
    # freshness signals (repro.online)
    hist = getattr(stats, "staleness", None)
    if hist is not None:
        metric = f"{prefix}_delivered_staleness_seconds"
        lines.append(f"# TYPE {metric} histogram")
        cum = hist.cumulative()
        for le, c in zip(hist.buckets, cum):
            lbl = _fmt_labels({**base, "le": f"{le:g}"})
            lines.append(f"{metric}_bucket{lbl} {c}")
        lines.append(f'{metric}_bucket{_fmt_labels({**base, "le": "+Inf"})} '
                     f"{cum[-1]}")
        lines.append(f"{metric}_sum{_fmt_labels(base)} {hist.sum:.9g}")
        lines.append(f"{metric}_count{_fmt_labels(base)} {hist.count}")
    if hasattr(stats, "ingest_rate"):
        metric = f"{prefix}_ingest_events_per_second"
        lines.append(f"# TYPE {metric} gauge")
        lines.append(f"{metric}{_fmt_labels(base)} {stats.ingest_rate():.9g}")

    # lookahead embedding-cache accounting, present when the executor ran
    # with a lookahead config (etl_runtime.lookahead.CacheStats)
    cache = getattr(stats, "cache", None)
    if cache is not None:
        cache_counters = {
            "embed_cache_lookups_total": cache.lookups,
            "embed_cache_hits_total": cache.hits,
            "embed_cache_misses_total": cache.misses,
            "embed_cache_admitted_rows_total": cache.admitted,
            "embed_cache_evicted_rows_total": cache.evicted,
            "embed_cache_staged_rows_total": cache.staged,
            "embed_cache_overflow_cold_total": cache.overflow_cold,
            "embed_cache_gather_bytes_saved_total":
                cache.gather_bytes_saved()}
        for name in sorted(cache_counters):
            metric = f"{prefix}_{name}"
            lines.append(f"# TYPE {metric} counter")
            lines.append(f"{metric}{_fmt_labels(base)} "
                         f"{cache_counters[name]:.9g}")
        metric = f"{prefix}_embed_cache_hit_rate"
        lines.append(f"# TYPE {metric} gauge")
        lines.append(f"{metric}{_fmt_labels(base)} {cache.hit_rate():.9g}")

    # self-tuning controller: live knob values + decision counts (present
    # when the executor ran with autotune / adaptive credits)
    knobs = getattr(stats, "knobs", None)
    if knobs:
        num_knobs = {k: v for k, v in knobs.items()
                     if isinstance(v, (bool, int, float))}
        if num_knobs:
            metric = f"{prefix}_controller_knob"
            lines.append(f"# TYPE {metric} gauge")
            for k in sorted(num_knobs):
                lbl = _fmt_labels({**base, "knob": k})
                lines.append(f"{metric}{lbl} {float(num_knobs[k]):.9g}")
        str_knobs = {k: v for k, v in knobs.items() if k not in num_knobs}
        if str_knobs:
            metric = f"{prefix}_controller_knob_info"
            lines.append(f"# TYPE {metric} gauge")
            for k in sorted(str_knobs):
                lbl = _fmt_labels({**base, "knob": k,
                                   "value": str(str_knobs[k])})
                lines.append(f"{metric}{lbl} 1")
    controller = getattr(stats, "controller", None)
    if controller is not None:
        metric = f"{prefix}_controller_decisions_total"
        lines.append(f"# TYPE {metric} counter")
        for action, n in sorted(controller.decision_counts().items()):
            lbl = _fmt_labels({**base, "action": action})
            lines.append(f"{metric}{lbl} {n}")
        metric = f"{prefix}_controller_queued_bytes_estimate"
        lines.append(f"# TYPE {metric} gauge")
        lines.append(f"{metric}{_fmt_labels(base)} "
                     f"{controller.total_queued_bytes():.9g}")
    return "\n".join(lines) + "\n"


def write_metrics_file(path: str, text: str) -> None:
    """Atomically-enough write for textfile-collector scraping."""
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
