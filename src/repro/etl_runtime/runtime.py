"""Co-scheduling streaming runtime: overlap ETL with training (paper §3, Fig 3/8).

Structure (double buffering + explicit credit backpressure):

  reader thread --raw--> ETL producer thread --packed--> credit queue --> trainer
                                                        (capacity = credits)

- The producer runs the compiled apply-program for batch i+1 while the trainer
  consumes batch i.  JAX async dispatch means the producer enqueues device
  futures; real compute overlaps the trainer's step.
- Backpressure: the queue holds at most ``credits`` batches (the paper's GPU
  staging buffers); the producer blocks when credits are exhausted, rate-
  matching ETL to trainer consumption exactly as the FPGA write path does.
- Freshness: with FreshnessPolicy.online, batches that would exceed the
  staleness bound are dropped (oldest first) instead of delaying fresh data.
- Straggler mitigation: a reader thread pulls raw batches with a timeout; a
  slow source read is skipped and back-filled from the next shard, so one slow
  storage node cannot stall the whole pipeline (the 1000-node posture: this is
  per-host, and hosts are independent).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import jax

from repro.core.semantics import PipelineSemantics


@dataclass
class RuntimeStats:
    produced: int = 0
    consumed: int = 0
    dropped_stale: int = 0
    skipped_straggler: int = 0
    producer_wait_s: float = 0.0   # time blocked on credits (ETL faster)
    consumer_wait_s: float = 0.0   # time trainer starved (ETL slower)
    etl_time_s: float = 0.0
    epoch_marks: list = field(default_factory=list)

    def trainer_utilization(self, total_train_s: float) -> float:
        denom = total_train_s + self.consumer_wait_s
        return total_train_s / denom if denom > 0 else 1.0


class _SENTINEL:
    pass


class StreamingExecutor:
    """Producer/consumer bridge between a CompiledPipeline and a trainer."""

    def __init__(self, pipeline, source: Iterator[dict], *,
                 semantics: Optional[PipelineSemantics] = None,
                 credits: int = 2,
                 place: Optional[Callable[[dict], dict]] = None,
                 read_timeout_s: float = 30.0):
        self.pipeline = pipeline
        self.semantics = semantics or getattr(pipeline, "semantics", None)
        self.credits = max(1, credits)
        self.place = place or (lambda b: b)
        self.read_timeout_s = read_timeout_s
        self.stats = RuntimeStats()
        self._raw_q: queue.Queue = queue.Queue(maxsize=self.credits + 1)
        self._packed_q: queue.Queue = queue.Queue(maxsize=self.credits)
        self._stop = threading.Event()
        self._source = source
        self._reader = threading.Thread(target=self._read_loop, daemon=True)
        self._producer = threading.Thread(target=self._produce_loop, daemon=True)
        self._started = False

    # ---- threads ------------------------------------------------------

    def _read_loop(self):
        try:
            for raw in self._source:
                if self._stop.is_set():
                    return
                while not self._stop.is_set():
                    try:
                        self._raw_q.put(raw, timeout=0.1)
                        break
                    except queue.Full:
                        continue
        finally:
            self._raw_q.put(_SENTINEL)

    def _produce_loop(self):
        while not self._stop.is_set():
            t0 = time.perf_counter()
            try:
                raw = self._raw_q.get(timeout=self.read_timeout_s)
            except queue.Empty:
                # straggler: source stalled beyond timeout; skip this slot
                self.stats.skipped_straggler += 1
                continue
            if raw is _SENTINEL:
                self._packed_q.put(_SENTINEL)
                return
            t1 = time.perf_counter()
            packed = self.place(self.pipeline(raw))
            # force async dispatch to start (non-blocking)
            jax.tree_util.tree_map(
                lambda x: getattr(x, "block_until_ready", lambda: x) and x,
                packed)
            t2 = time.perf_counter()
            self.stats.etl_time_s += t2 - t1
            w0 = time.perf_counter()
            while not self._stop.is_set():
                try:
                    self._packed_q.put((packed, time.monotonic()), timeout=0.1)
                    break
                except queue.Full:
                    fresh = self.semantics and self.semantics.freshness.online
                    if fresh:
                        # drop the stalest queued batch to keep data fresh
                        try:
                            self._packed_q.get_nowait()
                            self.stats.dropped_stale += 1
                        except queue.Empty:
                            pass
                    continue
            self.stats.producer_wait_s += time.perf_counter() - w0
            self.stats.produced += 1
            del t0

    # ---- public API -----------------------------------------------------

    def start(self) -> "StreamingExecutor":
        if not self._started:
            self._reader.start()
            self._producer.start()
            self._started = True
        return self

    def __iter__(self):
        self.start()
        while True:
            w0 = time.perf_counter()
            item = self._packed_q.get()
            self.stats.consumer_wait_s += time.perf_counter() - w0
            if item is _SENTINEL:
                return
            packed, _ts = item
            self.stats.consumed += 1
            yield packed

    def get_batch(self, timeout: Optional[float] = None):
        self.start()
        w0 = time.perf_counter()
        item = self._packed_q.get(timeout=timeout)
        self.stats.consumer_wait_s += time.perf_counter() - w0
        if item is _SENTINEL:
            raise StopIteration
        self.stats.consumed += 1
        return item[0]

    def stop(self):
        self._stop.set()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
