"""Staged prefetching executor: overlap ETL with training (paper §3, Fig 3/8).

The pipeline is an explicit chain of stages connected by credit-bounded,
stop-aware queues (the paper's GPU staging buffers):

  read ──raw──▶ transform ──packed──▶ [order] ──▶ place ──ready──▶ deliver
       credits              credits               credits         (trainer)

The optional **order** stage appears when ``OrderingPolicy.bucket_by_length``
is selected: it buffers up to ``reorder_window`` packed batches and emits
them in ascending length-key order (LM efficiency mode — similar-length
batches train together), trading strict arrival order inside the bounded
window only.  FIFO pipelines skip the stage entirely.

The optional **lookahead** stage (``lookahead=EmbedCacheConfig(...)``)
appears after place: it windows W in-flight envelopes to plan the trainer's
embedding-cache updates and annotates each delivered batch with its index
remap + admit/evict plan (see ``etl_runtime/lookahead.py``).

- **read** pulls raw batches from the source — a first-class
  ``repro.data.source.Source`` (whose ``length_key`` / ``arrival`` specs are
  computed host-side here and ride each batch's envelope) or any iterator.
  A source stall beyond ``read_timeout_s`` is detected downstream and counted
  as a straggler skip, so one slow storage node cannot stall the whole
  pipeline (the 1000-node posture: this is per-host, and hosts are
  independent).  Most callers construct executors through
  ``repro.session.EtlJob`` rather than directly.
- **transform** dispatches the jitted apply-program.  JAX async dispatch means
  the stage enqueues *device futures* — no host materialization, no
  ``block_until_ready`` — so real ETL compute overlaps the trainer's step.
- **place** double-buffers the H2D/layout transfer: with a trainer
  ``NamedSharding`` (see ``etl_runtime/transfer.py``) batches are
  ``device_put`` with the exact layout ``train_step`` declares in
  ``in_shardings``, so delivered batches are donation-ready and H2D overlaps
  device compute.  The ready queue holds ``credits`` batches — one being
  consumed, the rest in flight (double buffering at credits=2).
- **deliver** is the consumer side (``__iter__`` / ``get_batch``); it records
  trainer starvation time.

Backpressure: each queue holds at most ``credits`` items and every stage
blocks when its output queue is full, rate-matching ETL to trainer
consumption exactly as the FPGA write path does.  Knob tuning lives in
``etl_runtime.controller``: ``autotune=`` runs the measured-throughput
``PipelineController`` over every declared knob, while the deprecated
``adaptive_credits=True`` constructs the compatibility occupancy
controller (same grow-on-starve / shrink-on-idle-full thresholds as the
old in-executor rule, plus hysteresis).  Either way resizes land in
``stats.credit_grows`` / ``stats.credit_shrinks`` via ``set_credits``.

Timing: every busy/wait/staleness timestamp goes through the injected
``Clock`` (``etl_runtime.clock``; defaults to the system clock), so
timing-dependent tests can substitute a ``VirtualClock`` instead of
depending on wall-clock sleeps.

Freshness: with ``FreshnessPolicy.online``, a full ready queue sheds its
*oldest* queued batch to admit the fresh one (time-to-freshness over
completeness); drops are counted in ``stats.dropped_stale``.

Shutdown: ``stop()`` is prompt — queues are stop-aware (no unconditional
blocking puts), so a full queue can never deadlock stage teardown.  A stage
function that raises never dies silently: the first error stops the
pipeline and re-raises at the consumer (``RuntimeError`` chained to the
stage exception), so one bad record fails the job loudly instead of
hanging it.

Every stage records busy / wait-in / wait-out time (``stats.stages``), giving
the paper's Fig-8-style per-stage breakdown consumed by
``benchmarks/bench_overlap.py``.

The read stage is also available standalone as ``SourcePrefetcher`` —
``EtlJob.fit`` uses it so the fit phase's (fused) chunk build overlaps
source ingest exactly like apply overlaps training.
"""

from __future__ import annotations

import collections
import queue
import threading
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import numpy as np

from repro.core.semantics import PipelineSemantics
from repro.data.source import Source
from repro.etl_runtime import transfer as transfer_lib
from repro.etl_runtime.clock import SYSTEM_CLOCK, Clock


class _EOS:
    """End-of-stream marker forwarded through every queue."""


class _STOPPED:
    """Returned by queue ops when the executor is stopping."""


class CreditQueue:
    """Bounded FIFO whose put/get respect a shared stop event.

    Unlike ``queue.Queue``, a producer can never deadlock on a full queue
    during shutdown: both ends poll the stop event and return ``_STOPPED``.
    ``put(drop_oldest=True)`` implements the freshness policy — a full queue
    sheds its oldest entry to admit the new one (oldest-first drop).
    """

    def __init__(self, capacity: int, stop: threading.Event, name: str = "",
                 clock: Optional[Clock] = None):
        self.capacity = max(1, capacity)
        self.name = name
        self.dropped = 0  # lifetime count of entries shed by drop_oldest
        self._dq: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._stop = stop
        self._clock = clock or SYSTEM_CLOCK

    def __len__(self) -> int:
        with self._cv:
            return len(self._dq)

    def wake(self) -> None:
        with self._cv:
            self._cv.notify_all()

    def set_capacity(self, capacity: int) -> None:
        """Resize the credit budget (adaptive credits). Growing unblocks
        credit-waiting producers; shrinking never evicts queued items —
        the queue drains down to the new bound."""
        with self._cv:
            self.capacity = max(1, capacity)
            self._cv.notify_all()

    def put(self, item, *, drop_oldest: bool = False):
        """Block until enqueued. Returns the number of entries dropped to
        make room (0 normally), or ``_STOPPED`` if the executor stopped."""
        dropped = 0
        with self._cv:
            while len(self._dq) >= self.capacity:
                if self._stop.is_set():
                    return _STOPPED
                if drop_oldest:
                    # keep shedding until under the bound so a shrunk
                    # capacity (adaptive credits) actually drains the queue
                    self._dq.popleft()
                    dropped += 1
                    self.dropped += 1
                    continue
                # every transition notifies under this lock and stop() wakes
                # all queues, so an untimed wait cannot miss a wakeup
                self._cv.wait()
            if self._stop.is_set():
                return _STOPPED
            self._dq.append(item)
            self._cv.notify_all()
        return dropped

    def peek_oldest_key(self, key_fn: Callable) -> Optional[float]:
        """Smallest non-``None`` ``key_fn(item)`` among queued items (the
        oldest arrival when keyed by envelope arrival), or ``None``.  Used
        by the global freshness shedder (``repro.online.shed``) to find the
        stalest in-flight event across all stage queues."""
        with self._cv:
            keys = [k for item in self._dq
                    if (k := key_fn(item)) is not None]
            return min(keys) if keys else None

    def drop_by_key(self, key_fn: Callable, key: float):
        """Remove and return the first queued item whose ``key_fn`` equals
        ``key`` (``None`` if it raced downstream since the peek).  Counted
        in ``dropped`` like every other freshness shed."""
        with self._cv:
            for i, item in enumerate(self._dq):
                if key_fn(item) == key:
                    del self._dq[i]
                    self.dropped += 1
                    self._cv.notify_all()
                    return item
            return None

    def get(self, timeout: Optional[float] = None):
        """Block until an item is available. Raises ``queue.Empty`` on
        timeout; returns ``_STOPPED`` if the executor stopped."""
        deadline = (None if timeout is None
                    else self._clock.monotonic() + timeout)
        with self._cv:
            while True:
                # stop takes precedence over draining: shutdown is prompt
                if self._stop.is_set():
                    return _STOPPED
                if self._dq:
                    break
                if deadline is not None:
                    rem = deadline - self._clock.monotonic()
                    if rem <= 0:
                        raise queue.Empty
                    self._cv.wait(rem)
                else:
                    self._cv.wait()
            item = self._dq.popleft()
            self._cv.notify_all()
            return item


@dataclass
class _Envelope:
    """Per-batch sidecar riding every queue: the payload plus host-side
    metadata the stages consult without touching the (possibly device-
    future) payload — the Source-provided ordering key and arrival time."""

    payload: object
    length_key: Optional[float] = None
    arrival: Optional[float] = None


@dataclass
class StageStats:
    """Per-stage occupancy accounting (paper Fig 8 breakdown)."""
    name: str
    items: int = 0
    busy_s: float = 0.0       # time spent doing the stage's own work
    wait_in_s: float = 0.0    # blocked waiting for upstream input
    wait_out_s: float = 0.0   # blocked on downstream credits (backpressure)
    drop_oldest: int = 0      # batches this stage's put shed (freshness)

    def occupancy(self) -> float:
        total = self.busy_s + self.wait_in_s + self.wait_out_s
        return self.busy_s / total if total > 0 else 0.0

    def as_dict(self) -> dict:
        return {"items": self.items, "busy_s": self.busy_s,
                "wait_in_s": self.wait_in_s, "wait_out_s": self.wait_out_s,
                "drop_oldest": self.drop_oldest,
                "occupancy": self.occupancy()}


#: delivered-staleness histogram buckets (seconds); Prometheus ``le`` bounds
STALENESS_BUCKETS = (0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
                     1.0, 2.5, 5.0, 10.0)


@dataclass
class StalenessHistogram:
    """Cumulative histogram of event age at delivery (seconds since the
    Source.arrival stamp).  Rendered in the Prometheus histogram text
    format by ``etl_runtime.metrics``."""

    buckets: tuple = STALENESS_BUCKETS
    counts: list = field(default_factory=lambda: [0] * (len(STALENESS_BUCKETS) + 1))
    sum: float = 0.0
    count: int = 0

    def observe(self, age_s: float) -> None:
        self.sum += age_s
        self.count += 1
        for i, le in enumerate(self.buckets):
            if age_s <= le:
                self.counts[i] += 1
                return
        self.counts[-1] += 1  # +Inf bucket

    def cumulative(self) -> list:
        """Per-``le`` cumulative counts (Prometheus bucket semantics),
        ending with the +Inf bucket == ``count``."""
        out, acc = [], 0
        for c in self.counts:
            acc += c
            out.append(acc)
        return out


@dataclass
class RuntimeStats:
    produced: int = 0
    consumed: int = 0
    dropped_stale: int = 0
    skipped_straggler: int = 0
    consumer_wait_s: float = 0.0   # time trainer starved (ETL slower)
    credit_grows: int = 0          # adaptive-credit budget increases
    credit_shrinks: int = 0        # adaptive-credit budget decreases
    raw_resizes: int = 0           # adaptive resizes applied to the raw queue
    epoch_marks: list = field(default_factory=list)
    stages: dict = field(default_factory=dict)  # name -> StageStats
    # lookahead embedding-cache accounting (etl_runtime.lookahead.CacheStats)
    # when the executor runs with a lookahead config; None otherwise
    cache: Optional[object] = None
    # arrival timestamps (Source.arrival) of delivered batches, in delivery
    # order — the freshness-experiment record of what actually trained;
    # bounded so a long-running online job never grows it without limit
    delivered_arrivals: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=4096))
    # event age at delivery (now - arrival): cumulative histogram for the
    # Prometheus export plus a bounded recent window for exact percentiles
    staleness: StalenessHistogram = field(default_factory=StalenessHistogram)
    delivered_ages: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=4096))
    # ingest-side accounting for the events/sec gauge
    ingest_events: int = 0
    t_start: Optional[float] = None          # monotonic, set at start()
    t_last_ingest: Optional[float] = None    # monotonic, last read item
    # live knob values ({name: value}) + the owning PipelineController
    # when the executor runs with autotune/adaptive credits; exported as
    # gauges by etl_runtime.metrics
    knobs: dict = field(default_factory=dict)
    controller: Optional[object] = None

    def note_delivered(self, arrival: float,
                       now: Optional[float] = None) -> None:
        self.delivered_arrivals.append(arrival)
        age = (time.monotonic() if now is None else now) - arrival
        self.delivered_ages.append(age)
        self.staleness.observe(max(0.0, age))

    def note_ingest(self, now: Optional[float] = None) -> None:
        self.ingest_events += 1
        self.t_last_ingest = time.monotonic() if now is None else now

    def ingest_rate(self) -> float:
        """Mean ingested events/sec over the active span (read-stage items
        per second between start and the last read)."""
        if not self.ingest_events or self.t_start is None:
            return 0.0
        span = (self.t_last_ingest or self.t_start) - self.t_start
        return self.ingest_events / span if span > 0 else 0.0

    def staleness_percentiles(self) -> dict:
        """p50/p95/p99 event-age-at-delivery (seconds) over the recent
        ``delivered_ages`` window; zeros before any stamped delivery."""
        if not self.delivered_ages:
            return {"p50": 0.0, "p95": 0.0, "p99": 0.0}
        ages = np.asarray(self.delivered_ages)
        return {f"p{p}": float(np.percentile(ages, p)) for p in (50, 95, 99)}

    # -- compatibility views over the per-stage accounting ----------------

    @property
    def etl_time_s(self) -> float:
        """Total ETL work time (transform dispatch + placement)."""
        return sum(s.busy_s for n, s in self.stages.items()
                   if n in ("transform", "place"))

    @property
    def producer_wait_s(self) -> float:
        """Time the producer side blocked on credits (ETL faster)."""
        return sum(s.wait_out_s for s in self.stages.values())

    @property
    def overlapped_etl_s(self) -> float:
        """ETL work hidden behind training: busy time the trainer did not
        pay for as starvation.  > 0 is the measured overlap win."""
        return max(0.0, self.etl_time_s - self.consumer_wait_s)

    def trainer_utilization(self, total_train_s: float) -> float:
        denom = total_train_s + self.consumer_wait_s
        return total_train_s / denom if denom > 0 else 1.0

    def stage_breakdown(self) -> dict:
        """Fig-8-style per-stage breakdown: {stage: {items, busy_s, ...}}."""
        return {name: s.as_dict() for name, s in self.stages.items()}


class _Stage(threading.Thread):
    """One pipeline stage: pull → work → push, with full time accounting.

    ``fn(item)`` returns the transformed item.  EOS is forwarded and the
    stage exits; a stop event aborts promptly even mid-put (CreditQueue is
    stop-aware, so a full downstream queue cannot deadlock teardown).
    """

    def __init__(self, stats: StageStats, fn: Callable, in_q: CreditQueue,
                 out_q: CreditQueue, *, drop_oldest: bool = False,
                 in_timeout_s: Optional[float] = None,
                 on_in_timeout: Optional[Callable[[], None]] = None,
                 on_put: Optional[Callable[[int], None]] = None,
                 on_error: Optional[Callable[[BaseException], None]] = None,
                 clock: Optional[Clock] = None):
        super().__init__(name=f"etl-{stats.name}", daemon=True)
        self.stats = stats
        self.fn = fn
        self.in_q = in_q
        self.out_q = out_q
        self.drop_oldest = drop_oldest
        self.in_timeout_s = in_timeout_s
        self.on_in_timeout = on_in_timeout
        self.on_put = on_put
        self.on_error = on_error
        self._clock = clock or SYSTEM_CLOCK

    def run(self):
        mono = self._clock.monotonic
        while True:
            t0 = mono()
            try:
                item = self.in_q.get(timeout=self.in_timeout_s)
            except queue.Empty:
                self.stats.wait_in_s += mono() - t0
                if self.on_in_timeout:
                    self.on_in_timeout()
                continue
            self.stats.wait_in_s += mono() - t0
            if item is _STOPPED:
                return
            if item is _EOS:
                self.out_q.put(_EOS)
                return
            t1 = mono()
            try:
                out = self.fn(item)
            except Exception as e:
                # never die silently: surface the error and stop the
                # pipeline so the consumer unblocks instead of hanging
                if self.on_error:
                    self.on_error(e)
                return
            self.stats.busy_s += mono() - t1
            t2 = mono()
            r = self.out_q.put(out, drop_oldest=self.drop_oldest)
            self.stats.wait_out_s += mono() - t2
            if r is _STOPPED:
                return
            self.stats.items += 1
            self.stats.drop_oldest += r
            if self.on_put:
                self.on_put(r)


def _pump_source(source, out_q: CreditQueue, stats: StageStats,
                 stop: threading.Event, *, wrap: Optional[Callable] = None,
                 on_error: Optional[Callable[[BaseException], None]] = None,
                 clock: Optional[Clock] = None) -> None:
    """The read stage's pump loop, shared by the executor's read thread and
    the standalone ``SourcePrefetcher``: drain ``source`` into ``out_q``
    with busy / wait-out accounting, then enqueue a stop-aware EOS (never a
    blocking put into a full queue).  ``wrap(raw, idx)`` transforms each
    item at read time (the executor stamps envelope metadata here);
    ``on_error`` sets the failure policy (the executor stops the whole
    pipeline, the prefetcher records and re-raises at the consumer)."""
    mono = (clock or SYSTEM_CLOCK).monotonic
    try:
        it = iter(source)
        idx = 0
        while not stop.is_set():
            t0 = mono()
            try:
                raw = next(it)
                item = raw if wrap is None else wrap(raw, idx)
            except StopIteration:
                break
            except Exception as e:
                if on_error is not None:
                    on_error(e)
                return
            stats.busy_s += mono() - t0
            idx += 1
            t1 = mono()
            r = out_q.put(item)
            stats.wait_out_s += mono() - t1
            if r is _STOPPED:
                return
            stats.items += 1
    finally:
        out_q.put(_EOS)


def default_length_key(batch) -> float:
    """Length proxy for bucket_by_length: nonzero entries of the first
    2-D integer tensor (token count for LM batches), else 0.

    Forces the batch onto the host, so the sort stage synchronizes device
    futures — acceptable because ordering buys its win at the trainer, after
    the transform dispatch already overlapped.
    """
    if isinstance(batch, dict):
        for v in batch.values():
            a = np.asarray(v)
            if a.ndim >= 2 and np.issubdtype(a.dtype, np.integer):
                return float(np.count_nonzero(a))
    return 0.0


class _SortStage(threading.Thread):
    """Bounded reorder window (OrderingPolicy.bucket_by_length).

    Buffers up to ``window`` packed batches, flushes them in ascending
    ``length_key`` order (stable: equal keys keep arrival order), then
    refills.  EOS flushes the partial window before forwarding, so no batch
    is lost; stop aborts promptly like every other stage.

    The key comes from the batch envelope when the Source supplied a
    host-side ``length_key`` (computed at read time — the transform stage's
    device futures are never synced); only keyless envelopes fall back to
    the ``length_key`` callable, which materializes the payload.
    """

    def __init__(self, stats: StageStats, in_q: CreditQueue,
                 out_q: CreditQueue, *, window: int,
                 length_key: Callable = default_length_key,
                 on_error: Optional[Callable[[BaseException], None]] = None,
                 clock: Optional[Clock] = None):
        super().__init__(name=f"etl-{stats.name}", daemon=True)
        self.stats = stats
        self.in_q = in_q
        self.out_q = out_q
        self.window = max(2, window)
        self.length_key = length_key
        self.on_error = on_error
        self._clock = clock or SYSTEM_CLOCK

    def _flush(self, buf: list) -> bool:
        mono = self._clock.monotonic
        t0 = mono()
        buf.sort(key=lambda kv: kv[0])
        self.stats.busy_s += mono() - t0
        for _, item in buf:
            t1 = mono()
            r = self.out_q.put(item)
            self.stats.wait_out_s += mono() - t1
            if r is _STOPPED:
                return False
            self.stats.items += 1
        buf.clear()
        return True

    def run(self):
        mono = self._clock.monotonic
        buf: list = []
        while True:
            t0 = mono()
            item = self.in_q.get()
            self.stats.wait_in_s += mono() - t0
            if item is _STOPPED:
                return
            if item is _EOS:
                if buf and not self._flush(buf):
                    return
                self.out_q.put(_EOS)
                return
            t1 = mono()
            try:
                key = item.length_key
                if key is None:
                    key = self.length_key(item.payload)
                buf.append((key, item))
            except Exception as e:
                if self.on_error:
                    self.on_error(e)
                return
            self.stats.busy_s += mono() - t1
            if len(buf) >= self.window and not self._flush(buf):
                return


class SourcePrefetcher:
    """The executor's read stage, standalone: prefetch raw batches from a
    Source through a credit-bounded, stop-aware queue on a background
    thread.

    ``EtlJob.fit`` wraps its (projected) fit Source in one of these so fit
    ingest overlaps the fused chunk build — the reader fills the queue while
    the device builds the previous chunk's first-occurrence tables — instead
    of blocking the build on every disk read.  Iterating yields raw batches;
    a reader error stops the stream and re-raises at the consumer (same
    loud-failure contract as the full executor).  ``close()`` is prompt and
    also closes a closeable Source.
    """

    def __init__(self, source, *, credits: int = 2, name: str = "fit-read",
                 clock: Optional[Clock] = None):
        self._source = source
        self._stop = threading.Event()
        self._clock = clock or SYSTEM_CLOCK
        self._q = CreditQueue(max(1, credits), self._stop, name,
                              clock=self._clock)
        self.stats = StageStats(name)
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._read_loop,
                                        name=f"etl-{name}", daemon=True)
        self._started = False

    def set_credits(self, credits: int) -> None:
        """Resize the prefetch depth (the controller's prefetch knob)."""
        self._q.set_capacity(max(1, int(credits)))

    def _read_loop(self):
        def record(e: BaseException) -> None:
            # end the stream but let already-queued batches deliver;
            # the consumer re-raises at EOS
            self._error = e

        _pump_source(self._source, self._q, self.stats, self._stop,
                     on_error=record, clock=self._clock)

    def start(self) -> "SourcePrefetcher":
        if not self._started:
            self._thread.start()
            self._started = True
        return self

    def __iter__(self):
        self.start()
        st = self.stats
        mono = self._clock.monotonic
        while True:
            t0 = mono()
            item = self._q.get()
            st.wait_in_s += mono() - t0
            if item is _EOS or item is _STOPPED:
                if item is _EOS:
                    self._q.put(_EOS)  # re-arm: a later iteration ends too
                if self._error is not None:
                    raise RuntimeError("fit read stage failed") from self._error
                return
            yield item

    def close(self) -> None:
        self._stop.set()
        if isinstance(self._source, Source):
            self._source.close()
        self._q.wake()
        if self._started:
            self._thread.join(timeout=5.0)

    def __enter__(self) -> "SourcePrefetcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()


class StreamingExecutor:
    """Staged prefetching bridge between a CompiledPipeline and a trainer.

    Parameters
    ----------
    pipeline : compiled apply-program, called as ``pipeline(raw) -> packed``.
    source : a ``repro.data.source.Source`` (preferred — its ``length_key``
        and ``arrival`` specs feed the order stage and freshness accounting)
        or any iterator of raw columnar batches.
    semantics : optional PipelineSemantics; ``freshness.online`` enables
        oldest-first shedding at the ready queue.
    credits : staging-buffer depth per queue (2 = double buffering).
    place : optional explicit placement hook ``packed -> ready``; overrides
        ``sharding``/``mesh``.
    sharding : optional ``NamedSharding`` for the place stage (the trainer's
        batch sharding — delivered batches are donation-ready; pair with
        ``jit_train_step(..., donate_batch=True)`` so the trainer actually
        donates them).
    mesh : optional ``Mesh``; shorthand for
        ``sharding=transfer.batch_sharding(mesh)``.
    read_timeout_s : straggler bound on the raw queue; a stall beyond this is
        skipped (counted), not fatal.
    adaptive_credits : deprecated spelling of the occupancy-rule credits
        controller (grow on starvation, shrink on idle-full, with
        hysteresis); prefer ``autotune=``.  Ignored when ``autotune`` is
        set.
    max_credits : upper bound for adaptive/autotuned credit growth.
    autotune : ``True`` builds the default measured-throughput
        ``PipelineController`` over this executor's knobs (credits,
        prefetch depth, lookahead window); a ``PipelineController``
        instance is bound as-is (its knob list is extended with the
        executor knobs it does not already declare).  The controller's
        decisions and live knob values land in ``stats.controller`` /
        ``stats.knobs`` and the Prometheus export.
    clock : timing source (``etl_runtime.clock.Clock``); defaults to the
        system clock.  Tests inject a ``VirtualClock`` so stage timers
        and controller windows are deterministic.
    length_key : *fallback* batch -> sortable length for bucket_by_length
        ordering (default: token count via ``default_length_key``); only
        consulted when the Source did not supply a host-side key.
    transform_service : optional acquire/release gate arbitrating transform-
        stage device time across tenants (see ``etl_runtime.multitenant``).
    lookahead : optional ``etl_runtime.lookahead.EmbedCacheConfig``; adds the
        lookahead prefetch stage after **place** — a window of W in-flight
        envelopes drives per-table hot-set planning and each delivered batch
        carries its embedding-cache plan (``lookahead.PLAN_KEYS``).  Cache
        accounting lands in ``stats.cache``.  With freshness shedding, the
        shed point moves to the placed queue (before planning) so a planned
        cache update is never dropped — the consumer must apply every
        delivered plan, in order, for the host mirror to stay coherent.
    """

    _ADAPT_EVERY = 4          # deliveries per resize decision (occupancy)
    _STARVED_EPS_S = 1e-3     # a delivery that waited longer counts starved

    def __init__(self, pipeline, source, *,
                 semantics: Optional[PipelineSemantics] = None,
                 credits: int = 2,
                 place: Optional[Callable[[dict], dict]] = None,
                 sharding=None, mesh=None,
                 read_timeout_s: float = 30.0,
                 adaptive_credits: bool = False, max_credits: int = 8,
                 autotune=None,
                 length_key: Callable = default_length_key,
                 transform_service=None, lookahead=None,
                 clock: Optional[Clock] = None):
        self.pipeline = pipeline
        self.semantics = semantics or getattr(pipeline, "semantics", None)
        self.credits = max(1, credits)
        self.read_timeout_s = read_timeout_s
        self.adaptive_credits = adaptive_credits
        self.max_credits = max(self.credits, max_credits)
        self.current_credits = self.credits
        self.clock = clock or SYSTEM_CLOCK
        if place is None:
            if sharding is None and mesh is not None:
                sharding = transfer_lib.batch_sharding(mesh)
            if sharding is not None:
                place = lambda b: transfer_lib.put_packed(b, sharding)
            else:
                place = lambda b: b
        self.place = place
        self._source = source
        self._host_key_fn = None
        self._arrival_fn = None
        if isinstance(source, Source):
            self._host_key_fn = source.spec.length_key
            self._arrival_fn = source.spec.arrival_fn()
        self._transform_service = transform_service
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self.stats = RuntimeStats()
        self.lookahead = lookahead
        ordering = self.semantics.ordering if self.semantics else None
        reorder = bool(ordering and ordering.kind == "bucket_by_length"
                       and ordering.reorder_window >= 2)
        names = ["read", "transform", "place", "deliver"]
        if reorder:
            names.insert(2, "order")
        if lookahead is not None:
            names.insert(names.index("deliver"), "lookahead")
        for name in names:
            self.stats.stages[name] = StageStats(name)

        fresh = bool(self.semantics and self.semantics.freshness.online)
        ck = self.clock
        self._raw_q = CreditQueue(self.credits, self._stop, "raw", clock=ck)
        self._packed_q = CreditQueue(self.credits, self._stop, "packed",
                                     clock=ck)
        self._ready_q = CreditQueue(self.credits, self._stop, "ready",
                                    clock=ck)
        self._placed_q = (CreditQueue(self.credits, self._stop, "placed",
                                      clock=ck)
                          if lookahead is not None else None)

        def _on_straggler():
            self.stats.skipped_straggler += 1

        def _on_delivered(dropped: int):
            self.stats.produced += 1
            self.stats.dropped_stale += dropped

        def _on_shed(dropped: int):
            # place -> placed under lookahead: shedding happens here (before
            # planning), production is counted at the final ready-queue put
            self.stats.dropped_stale += dropped

        def _on_error(exc: BaseException):
            # first error wins; stop() unblocks every stage and the consumer
            if self._error is None:
                self._error = exc
            self.stop()

        place_in_q = self._packed_q
        self._stages: list = []
        if reorder:
            # sorting stage between transform and place (ROADMAP item):
            # its window is additional bounded staging, not credit-counted
            self._sorted_q = CreditQueue(self.credits, self._stop, "sorted",
                                         clock=ck)
            self._stages.append(_SortStage(
                self.stats.stages["order"], self._packed_q, self._sorted_q,
                window=ordering.reorder_window, length_key=length_key,
                on_error=_on_error, clock=ck))
            place_in_q = self._sorted_q
        else:
            self._sorted_q = None

        def _env_fn(fn):
            """Lift a payload transform to the envelope the queues carry."""
            def run(env: _Envelope) -> _Envelope:
                return replace(env, payload=fn(env.payload))
            return run

        # the transform reads self.pipeline per batch (not a captured
        # reference) so swap_pipeline — the row-tile/fuse knob actuator —
        # takes effect on the next batch without restarting the stage
        def transform_fn(raw):
            return self.pipeline(raw)
        if self._transform_service is not None:
            def transform_fn(raw):
                # weighted round-robin *service*: device time, not just
                # staging credits, follows tenant weights
                granted = self._transform_service.acquire(stop=self._stop)
                try:
                    return self.pipeline(raw)
                finally:
                    if granted:
                        self._transform_service.release()
        place_out_q = self._placed_q if lookahead is not None else self._ready_q
        self._stages = [
            _Stage(self.stats.stages["transform"], _env_fn(transform_fn),
                   self._raw_q, self._packed_q,
                   in_timeout_s=self.read_timeout_s,
                   on_in_timeout=_on_straggler, on_error=_on_error,
                   clock=ck),
            *self._stages,
            _Stage(self.stats.stages["place"], _env_fn(self.place),
                   place_in_q, place_out_q,
                   drop_oldest=fresh,
                   on_put=_on_shed if lookahead is not None else _on_delivered,
                   on_error=_on_error, clock=ck),
        ]
        self._lookahead_stage = None
        if lookahead is not None:
            # imported here: lookahead.py reuses this module's queue/stats
            # machinery, so a module-level import would be circular
            from repro.etl_runtime.lookahead import CacheStats, LookaheadStage
            self.stats.cache = CacheStats(row_bytes=lookahead.row_bytes)
            self._lookahead_stage = LookaheadStage(
                self.stats.stages["lookahead"], self._placed_q, self._ready_q,
                lookahead, cache_stats=self.stats.cache,
                on_put=_on_delivered, on_error=_on_error, clock=ck)
            self._stages.append(self._lookahead_stage)
        self._on_error = _on_error
        self._reader = threading.Thread(target=self._read_loop,
                                        name="etl-read", daemon=True)
        self._started = False
        # ---- knob controller (autotune / deprecated adaptive_credits) ----
        self.stats.knobs["credits"] = self.current_credits
        self._controller = None
        if autotune:
            from repro.etl_runtime.controller import PipelineController
            if isinstance(autotune, PipelineController):
                autotune.bind_executor(self)
                self._controller = autotune
            else:
                self._controller = PipelineController.for_executor(self)
        elif adaptive_credits:
            from repro.etl_runtime.controller import PipelineController
            self._controller = PipelineController.adaptive_credits(self)
        if self._controller is not None:
            self.stats.controller = self._controller

    # ---- read stage (source iterators don't fit the queue-in shape) ------

    def _read_loop(self):
        def wrap(raw, idx):
            # envelope metadata is computed host-side at read time:
            # the ordering key never touches downstream device work
            key = (float(self._host_key_fn(raw))
                   if self._host_key_fn is not None else None)
            arrival = (self._arrival_fn(idx)
                       if self._arrival_fn is not None else None)
            self.stats.note_ingest(now=self.clock.monotonic())
            return _Envelope(raw, key, arrival)

        _pump_source(self._source, self._raw_q, self.stats.stages["read"],
                     self._stop, wrap=wrap, on_error=self._on_error,
                     clock=self.clock)

    # ---- knob actuators (PipelineController apply hooks) -----------------

    def set_credits(self, credits: int) -> None:
        """Resize the whole staging budget to ``credits``.

        Every stage queue — the raw (read→transform) queue included — gets
        the new capacity: a starving trainer deepens ingest prefetch too,
        and the shrink path reclaims that staging memory symmetrically.
        Grow/shrink counters land in stats exactly one per step, so the
        controller's one-step moves keep the legacy resize accounting.
        """
        credits = max(1, int(credits))
        if credits == self.current_credits:
            return
        if credits > self.current_credits:
            self.stats.credit_grows += 1
        else:
            self.stats.credit_shrinks += 1
        self.current_credits = credits
        for q in (self._raw_q, self._packed_q, self._ready_q, self._sorted_q,
                  self._placed_q):
            if q is not None:
                q.set_capacity(credits)
        self.stats.raw_resizes += 1
        self.stats.knobs["credits"] = credits

    def set_prefetch_depth(self, depth: int) -> None:
        """Resize only the raw (read→transform) queue — the prefetch-depth
        knob, independent of the downstream staging credits."""
        depth = max(1, int(depth))
        self._raw_q.set_capacity(depth)
        self.stats.knobs["prefetch_depth"] = depth

    def set_lookahead_window(self, window: int) -> None:
        """Resize the lookahead planning window (no-op without the
        lookahead stage)."""
        if self._lookahead_stage is not None:
            self._lookahead_stage.set_window(window)
            self.stats.knobs["lookahead_window"] = max(1, int(window))

    def swap_pipeline(self, pipeline) -> None:
        """Atomically swap the transform program (the row-tile / fuse knob
        actuator: ``EtlJob`` recompiles via ``CompiledPipeline.with_knobs``
        — sharing vocabulary state — and swaps it in here).  The transform
        stage reads ``self.pipeline`` per batch, so the next batch uses
        the new program; in-flight batches finish on the old one."""
        self.pipeline = pipeline

    # ---- controller sensor (deliver-side observation) --------------------

    def _adapt(self, wait_s: float) -> None:
        """One deliver-side observation, forwarded to the controller.

        Fullness is sampled at pop time — the item just taken plus the
        remaining depth — so the decision does not race the producer
        refilling the queue.  Decisions happen on deliveries: a fully
        paused trainer holds the grown budget until it consumes again.
        """
        if self._controller is None:
            return
        full_at_pop = len(self._ready_q) + 1 >= self._ready_q.capacity
        self._controller.on_delivery(wait_s=wait_s, ready_full=full_at_pop,
                                     now=self.clock.monotonic())

    # ---- public API ------------------------------------------------------

    def start(self) -> "StreamingExecutor":
        if not self._started:
            self.stats.t_start = self.clock.monotonic()
            self._reader.start()
            for s in self._stages:
                s.start()
            self._started = True
        return self

    def _raise_if_failed(self) -> None:
        if self._error is not None:
            raise RuntimeError("ETL pipeline stage failed") from self._error

    def __iter__(self):
        self.start()
        dst = self.stats.stages["deliver"]
        mono = self.clock.monotonic
        while True:
            w0 = mono()
            item = self._ready_q.get()
            wait = mono() - w0
            self.stats.consumer_wait_s += wait
            dst.wait_in_s += wait
            if item is _EOS or item is _STOPPED:
                self._raise_if_failed()
                return
            self.stats.consumed += 1
            dst.items += 1
            if item.arrival is not None:
                self.stats.note_delivered(item.arrival, now=mono())
            self._adapt(wait)
            yield item.payload

    def get_batch(self, timeout: Optional[float] = None):
        self.start()
        dst = self.stats.stages["deliver"]
        mono = self.clock.monotonic
        w0 = mono()
        item = self._ready_q.get(timeout=timeout)
        wait = mono() - w0
        self.stats.consumer_wait_s += wait
        dst.wait_in_s += wait
        if item is _EOS or item is _STOPPED:
            self._raise_if_failed()
            raise StopIteration
        self.stats.consumed += 1
        dst.items += 1
        if item.arrival is not None:
            self.stats.note_delivered(item.arrival, now=mono())
        self._adapt(wait)
        return item.payload

    def stop(self):
        """Prompt, non-blocking shutdown: stages unblock on the stop event
        even when their queues are full (no sentinel deadlock).  A closeable
        Source (queue streams) is closed so the read thread cannot stay
        parked on an empty feed."""
        self._stop.set()
        # Source.close() unblocks queue-stream readers; plain iterators are
        # left alone (a generator's close() raises if it is mid-next())
        if isinstance(self._source, Source):
            self._source.close()
        for q in (self._raw_q, self._packed_q, self._sorted_q, self._placed_q,
                  self._ready_q):
            if q is not None:
                q.wake()

    def join(self, timeout: Optional[float] = None) -> bool:
        """Wait for all stage threads to exit; True if they all did."""
        deadline = (time.monotonic() + timeout) if timeout is not None else None
        threads = ([self._reader] + self._stages) if self._started else []
        for t in threads:
            rem = None if deadline is None else max(0.0, deadline - time.monotonic())
            t.join(rem)
        return all(not t.is_alive() for t in threads)

    def stage_queues(self) -> dict:
        """Live stage queues in pipeline order (upstream → downstream) —
        the surface ``repro.online.shed`` sweeps for global oldest-first
        freshness shedding.  With a lookahead stage the ready queue holds
        *planned* batches (their cache admits must execute in order), so
        shedders must not drop from it — see ``FreshnessShedder``."""
        qs = {"raw": self._raw_q, "packed": self._packed_q}
        if self._sorted_q is not None:
            qs["sorted"] = self._sorted_q
        if self._placed_q is not None:
            qs["placed"] = self._placed_q
        qs["ready"] = self._ready_q
        return qs

    def queue_depths(self) -> dict:
        depths = {"raw": len(self._raw_q), "packed": len(self._packed_q),
                  "ready": len(self._ready_q)}
        if self._sorted_q is not None:
            depths["sorted"] = len(self._sorted_q)
        if self._placed_q is not None:
            depths["placed"] = len(self._placed_q)
        return depths

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
