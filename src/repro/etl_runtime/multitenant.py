"""Concurrent pipeline instances on one accelerator (paper §3.4 Q1/Q2, §4.8).

PipeRec hosts up to 7 heterogeneous pipelines in FPGA dynamic regions via
partial reconfiguration.  The TPU/JAX analogue: each tenant is an
independently compiled executable (jit cache entry); "reconfiguration within
milliseconds" is swapping which executables are active — no recompilation, the
lowered artifact is reused.  Tenants share the device; XLA serializes device
work per stream while host-side ETL assembly threads run concurrently, so
aggregate throughput scales until the device (or host ingest) saturates —
mirroring Fig 17 where scaling is linear until NIC/PCIe bandwidth binds.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator

import numpy as np


@dataclass
class TenantResult:
    name: str
    batches: int = 0
    rows: int = 0
    seconds: float = 0.0

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.seconds if self.seconds else 0.0


@dataclass
class PipelineManager:
    """Run N compiled pipelines concurrently; report per-tenant throughput."""

    tenants: dict = field(default_factory=dict)

    def add(self, name: str, pipeline, source_factory: Callable[[], Iterator[dict]]):
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        self.tenants[name] = (pipeline, source_factory)

    def swap(self, name: str, pipeline, source_factory) -> None:
        """Partial-reconfiguration analogue: replace a tenant's pipeline.

        The new pipeline must already be compiled; the swap itself is O(1).
        """
        if name not in self.tenants:
            raise KeyError(name)
        self.tenants[name] = (pipeline, source_factory)

    def run(self, n_batches: int) -> dict[str, TenantResult]:
        results = {n: TenantResult(n) for n in self.tenants}
        errors: list = []

        def worker(name, pipeline, source_factory):
            try:
                t0 = time.perf_counter()
                src = source_factory()
                for i, raw in enumerate(src):
                    if i >= n_batches:
                        break
                    out = pipeline(raw)
                    # block so throughput numbers are honest
                    for v in out.values():
                        if hasattr(v, "block_until_ready"):
                            v.block_until_ready()
                    results[name].batches += 1
                    results[name].rows += int(np.shape(next(iter(out.values())))[0])
                results[name].seconds = time.perf_counter() - t0
            except Exception as e:  # pragma: no cover
                errors.append((name, e))

        threads = [threading.Thread(target=worker, args=(n, p, s), daemon=True)
                   for n, (p, s) in self.tenants.items()]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise RuntimeError(f"tenant failures: {errors}")
        return results
