"""Concurrent pipeline instances on one accelerator (paper §3.4 Q1/Q2, §4.8).

PipeRec hosts up to 7 heterogeneous pipelines in FPGA dynamic regions via
partial reconfiguration.  The TPU/JAX analogue: each tenant is an
independently compiled executable (jit cache entry); "reconfiguration within
milliseconds" is swapping which executables are active — no recompilation, the
lowered artifact is reused.

Each tenant is an ``EtlJob`` (``repro.session``): the manager is a thin
composition layer that splits two shared budgets across the jobs:

- **staging credits** (``total_credits``): the shared staging-buffer budget
  is split proportionally to tenant weights, so a heavy tenant's in-flight
  batches cannot crowd a light tenant's staging memory — the FPGA dynamic-
  region partitioning, expressed as queue capacity.
- **transform service** (``service_weighted``): device *time* follows the
  same weights.  A smooth weighted round-robin arbiter grants the transform
  stage's dispatch slot among the tenants currently requesting one, so a
  3:1 weight split yields a deterministic a,a,b,a grant cycle rather than
  whoever's thread wakes first.  Credits bound memory; service bounds time.

Tenants share the device; XLA serializes device work per stream while
host-side stages run concurrently, so aggregate throughput scales until the
device (or host ingest) saturates — mirroring Fig 17 where scaling is linear
until NIC/PCIe bandwidth binds.
"""

from __future__ import annotations

import collections
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.data.source import Source
from repro.session import EtlJob


class WeightedRoundRobin:
    """Smooth weighted round-robin (nginx-style): each pick adds every
    eligible tenant's weight to its running balance, grants the largest
    balance (ties break in registration order — fully deterministic), and
    charges the winner the eligible total.  Over any window the grant
    counts track the weight ratios as closely as integer grants allow.
    """

    def __init__(self, weights: dict):
        if not weights:
            raise ValueError("WeightedRoundRobin needs at least one tenant")
        if any(w <= 0 for w in weights.values()):
            raise ValueError("tenant weights must be positive")
        self.weights = {n: float(w) for n, w in weights.items()}
        self._order = list(weights)
        self._balance = {n: 0.0 for n in weights}

    def pick(self, eligible=None) -> str:
        names = [n for n in self._order
                 if eligible is None or n in eligible]
        if not names:
            raise ValueError("no eligible tenants")
        total = sum(self.weights[n] for n in names)
        best = None
        for n in names:
            self._balance[n] += self.weights[n]
            if best is None or self._balance[n] > self._balance[best]:
                best = n
        self._balance[best] -= total
        return best


class TransformService:
    """Arbitrates transform-stage dispatch slots across tenants.

    One slot exists; ``gate(name)`` hands a tenant its acquire/release
    handle.  Acquire blocks until the WRR arbiter grants ``name`` a turn
    among the tenants *currently requesting* (an idle tenant never blocks
    the others); release frees the slot and re-arbitrates.
    """

    _GRANT_TRACE = 1024  # bounded: observability, not a full history

    def __init__(self, weights: dict):
        self._wrr = WeightedRoundRobin(weights)
        self._cv = threading.Condition()
        self._waiting: dict = {}
        self._grant: Optional[str] = None
        # most recent grant order (observability / tests); bounded so a
        # long-running job never grows it past _GRANT_TRACE entries
        self.grants: collections.deque = collections.deque(
            maxlen=self._GRANT_TRACE)

    def gate(self, name: str) -> "_TenantGate":
        if name not in self._wrr.weights:
            raise KeyError(name)
        return _TenantGate(self, name)

    def _acquire(self, name: str, stop=None) -> bool:
        with self._cv:
            self._waiting[name] = self._waiting.get(name, 0) + 1
            try:
                while True:
                    if self._grant is None:
                        self._grant = self._wrr.pick(set(self._waiting))
                        self.grants.append(self._grant)
                        self._cv.notify_all()
                    if self._grant == name:
                        return True
                    if stop is not None and stop.is_set():
                        return False  # teardown: run unarbitrated
                    self._cv.wait(timeout=0.1)
            finally:
                self._waiting[name] -= 1
                if not self._waiting[name]:
                    del self._waiting[name]

    def _release(self, name: str) -> None:
        with self._cv:
            if self._grant == name:
                self._grant = None
                self._cv.notify_all()


@dataclass
class _TenantGate:
    service: TransformService
    name: str

    def acquire(self, stop=None) -> bool:
        return self.service._acquire(self.name, stop=stop)

    def release(self) -> None:
        self.service._release(self.name)


@dataclass
class TenantResult:
    name: str
    batches: int = 0
    rows: int = 0
    seconds: float = 0.0
    weight: float = 1.0
    credits: int = 1
    stage_breakdown: dict = field(default_factory=dict)

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.seconds if self.seconds else 0.0


@dataclass
class PipelineManager:
    """Run N compiled pipelines concurrently as weighted ``EtlJob``s."""

    tenants: dict = field(default_factory=dict)
    weights: dict = field(default_factory=dict)
    total_credits: int = 8
    service_weighted: bool = True  # WRR arbitration of transform dispatch

    def add(self, name: str, pipeline, source, *, weight: float = 1.0):
        """Register a tenant.  ``source`` is a ``Source``, or (legacy) a
        zero-arg factory returning a fresh raw-batch iterator per run."""
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        self.tenants[name] = (pipeline, source)
        self.weights[name] = float(weight)

    def swap(self, name: str, pipeline, source) -> None:
        """Partial-reconfiguration analogue: replace a tenant's pipeline.

        The new pipeline must already be compiled; the swap itself is O(1)
        and keeps the tenant's weight.
        """
        if name not in self.tenants:
            raise KeyError(name)
        self.tenants[name] = (pipeline, source)

    def credit_allocation(self) -> dict:
        """Weighted split of the staging-credit budget (each tenant ≥ 1).

        Largest-remainder apportionment so the shares actually sum to
        ``total_credits`` (never oversubscribing the staging budget), except
        when there are more tenants than credits — then the ≥ 1 floor wins.
        """
        if not self.tenants:
            return {}
        total_w = sum(self.weights[n] for n in self.tenants)
        exact = {n: self.total_credits * self.weights[n] / total_w
                 for n in self.tenants}
        alloc = {n: max(1, int(exact[n])) for n in self.tenants}
        leftover = self.total_credits - sum(alloc.values())
        for n in sorted(self.tenants, key=lambda n: exact[n] - int(exact[n]),
                        reverse=True):
            if leftover <= 0:
                break
            alloc[n] += 1
            leftover -= 1
        return alloc

    def jobs(self) -> dict:
        """One EtlJob per tenant under the shared budgets (the manager is
        composition, not a parallel code path)."""
        alloc = self.credit_allocation()
        svc = (TransformService(self.weights)
               if self.service_weighted and len(self.tenants) > 1 else None)
        out = {}
        for name, (pipeline, source) in self.tenants.items():
            src = (source if isinstance(source, Source)
                   else Source.stream(source))
            out[name] = EtlJob(
                pipeline, src, credits=alloc[name],
                transform_service=svc.gate(name) if svc else None,
                name=name)
        return out

    def run(self, n_batches: int) -> dict:
        alloc = self.credit_allocation()
        results = {n: TenantResult(n, weight=self.weights[n],
                                   credits=alloc[n])
                   for n in self.tenants}
        errors: list = []

        def worker(name: str, job: EtlJob):
            try:
                with job.batches() as ex:
                    t0 = time.perf_counter()
                    for out in itertools.islice(ex, n_batches):
                        # block so throughput numbers are honest
                        for v in out.values():
                            if hasattr(v, "block_until_ready"):
                                v.block_until_ready()
                        results[name].batches += 1
                        results[name].rows += int(
                            np.shape(next(iter(out.values())))[0])
                    results[name].seconds = time.perf_counter() - t0
                results[name].stage_breakdown = (
                    job.stats().stage_breakdown())
            except Exception as e:  # pragma: no cover
                errors.append((name, e))
            finally:
                job.close()

        threads = [threading.Thread(target=worker, args=(n, j), daemon=True)
                   for n, j in self.jobs().items()]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise RuntimeError(f"tenant failures: {errors}")
        return results
