"""Concurrent pipeline instances on one accelerator (paper §3.4 Q1/Q2, §4.8).

PipeRec hosts up to 7 heterogeneous pipelines in FPGA dynamic regions via
partial reconfiguration.  The TPU/JAX analogue: each tenant is an
independently compiled executable (jit cache entry); "reconfiguration within
milliseconds" is swapping which executables are active — no recompilation, the
lowered artifact is reused.

Scheduling is a **weighted-credit policy over the staged executor** (not a
parallel code path): every tenant runs the same read → transform → place →
deliver machinery from ``etl_runtime.runtime``, and the shared staging-buffer
budget (``total_credits``) is split between tenants proportionally to their
weights.  A tenant's credit share bounds its in-flight batches, so a heavy
tenant cannot crowd the staging memory of a light one — the FPGA dynamic-
region partitioning, expressed as queue capacity.  Tenants share the device;
XLA serializes device work per stream while host-side stages run
concurrently, so aggregate throughput scales until the device (or host
ingest) saturates — mirroring Fig 17 where scaling is linear until NIC/PCIe
bandwidth binds.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Iterator

import numpy as np

from repro.etl_runtime.runtime import StreamingExecutor


@dataclass
class TenantResult:
    name: str
    batches: int = 0
    rows: int = 0
    seconds: float = 0.0
    weight: float = 1.0
    credits: int = 1
    stage_breakdown: dict = field(default_factory=dict)

    @property
    def rows_per_s(self) -> float:
        return self.rows / self.seconds if self.seconds else 0.0


@dataclass
class PipelineManager:
    """Run N compiled pipelines concurrently under a shared credit budget."""

    tenants: dict = field(default_factory=dict)
    weights: dict = field(default_factory=dict)
    total_credits: int = 8

    def add(self, name: str, pipeline,
            source_factory: Callable[[], Iterator[dict]], *,
            weight: float = 1.0):
        if name in self.tenants:
            raise ValueError(f"tenant {name!r} already registered")
        if weight <= 0:
            raise ValueError("tenant weight must be positive")
        self.tenants[name] = (pipeline, source_factory)
        self.weights[name] = float(weight)

    def swap(self, name: str, pipeline, source_factory) -> None:
        """Partial-reconfiguration analogue: replace a tenant's pipeline.

        The new pipeline must already be compiled; the swap itself is O(1)
        and keeps the tenant's weight.
        """
        if name not in self.tenants:
            raise KeyError(name)
        self.tenants[name] = (pipeline, source_factory)

    def credit_allocation(self) -> dict[str, int]:
        """Weighted split of the staging-credit budget (each tenant ≥ 1).

        Largest-remainder apportionment so the shares actually sum to
        ``total_credits`` (never oversubscribing the staging budget), except
        when there are more tenants than credits — then the ≥ 1 floor wins.
        """
        if not self.tenants:
            return {}
        total_w = sum(self.weights[n] for n in self.tenants)
        exact = {n: self.total_credits * self.weights[n] / total_w
                 for n in self.tenants}
        alloc = {n: max(1, int(exact[n])) for n in self.tenants}
        leftover = self.total_credits - sum(alloc.values())
        for n in sorted(self.tenants, key=lambda n: exact[n] - int(exact[n]),
                        reverse=True):
            if leftover <= 0:
                break
            alloc[n] += 1
            leftover -= 1
        return alloc

    def run(self, n_batches: int) -> dict[str, TenantResult]:
        alloc = self.credit_allocation()
        results = {n: TenantResult(n, weight=self.weights[n],
                                   credits=alloc[n])
                   for n in self.tenants}
        errors: list = []

        def worker(name, pipeline, source_factory):
            ex = StreamingExecutor(pipeline, source_factory(),
                                   credits=alloc[name])
            try:
                t0 = time.perf_counter()
                for out in itertools.islice(ex, n_batches):
                    # block so throughput numbers are honest
                    for v in out.values():
                        if hasattr(v, "block_until_ready"):
                            v.block_until_ready()
                    results[name].batches += 1
                    results[name].rows += int(
                        np.shape(next(iter(out.values())))[0])
                results[name].seconds = time.perf_counter() - t0
                results[name].stage_breakdown = ex.stats.stage_breakdown()
            except Exception as e:  # pragma: no cover
                errors.append((name, e))
            finally:
                ex.stop()

        threads = [threading.Thread(target=worker, args=(n, p, s), daemon=True)
                   for n, (p, s) in self.tenants.items()]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        if errors:
            raise RuntimeError(f"tenant failures: {errors}")
        return results
