"""Sharding rules: map param/activation pytrees onto the production mesh.

Rules are path-based and *adaptive*: a dimension is only sharded over an axis
when divisible by it (e.g. whisper's 8 heads cannot split 16-way; the rule
falls back to replication for that tensor while the big matmul dims still
shard).  Data-parallel axes are ("pod", "data"); tensor/expert-parallel is
"model".

FSDP (ZeRO-3) mode additionally shards every parameter's largest non-model
dim over the data axes — required for the 405B/1T configs where replicated
optimizer state cannot fit HBM.
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# thread-local-ish global mesh used by shard_hint (set by the launcher)
_ACTIVE_MESH: Optional[Mesh] = None


def set_active_mesh(mesh: Optional[Mesh]):
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def get_active_mesh() -> Optional[Mesh]:
    return _ACTIVE_MESH


def data_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def shard_hint(x, spec_tuple):
    """with_sharding_constraint if a mesh is active; no-op otherwise.

    spec_tuple entries: "data" -> the (pod,data) superaxis, "model", or None.
    Dims that do not divide evenly fall back to None.
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    resolved = []
    for dim, a in zip(x.shape, spec_tuple):
        if a is None:
            resolved.append(None)
            continue
        axes = data_axes(mesh) if a == "data" else (a,)
        axes = tuple(ax for ax in axes if ax in mesh.axis_names)
        size = int(np.prod([mesh.shape[ax] for ax in axes])) if axes else 1
        if axes and size and dim % size == 0:
            resolved.append(axes if len(axes) > 1 else axes[0])
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved)))


# ---------------------------------------------------------------------------
# parameter sharding rules
# ---------------------------------------------------------------------------

# (regex on param path, spec builder). Specs name logical roles; `_resolve`
# turns them into mesh axes with divisibility fallback.
_RULES = [
    (r"embed$", ("model", None)),
    (r"(lm_head|head)$", (None, "model")),
    (r"(wq|w1|w3|wi)$", (None, "model")),
    (r"(wk|wv)$", (None, "model")),
    (r"(wo|w2)$", ("model", None)),
    (r"(bi)$", ("model",)),
    (r"(bo)$", (None,)),
    (r"router$", (None, None)),
    # MoE experts: (E, D, F) / (E, F, D) — expert-parallel on E
    (r"experts/.*(w1|w3)$", ("expert", None, "model_in_expert")),
    (r"experts/.*w2$", ("expert", "model_in_expert", None)),
    # Mamba/SSM (per-stream projections; see ssm.mixer_init)
    (r"(z_proj|x_proj|b_proj|c_proj|dt_proj)$", (None, "model")),
    (r"out_proj$", ("model", None)),
    (r"conv_w[xbc]$", (None, "model")),
    (r"conv_b[xbc]$", ("model",)),
    (r"norm_w$", ("model",)),
    # DLRM
    (r"tables$", (None, "model", None)),
    (r"(bot_mlp|top_mlp)/.*w$", (None, "model")),
]


def _resolve(spec, shape, mesh: Mesh, *, fsdp: bool, n_experts: int = 0):
    model = mesh.shape.get("model", 1)
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    out = []
    for dim, role in zip(shape, spec):
        if role is None:
            out.append(None)
        elif role == "model":
            out.append("model" if dim % model == 0 else None)
        elif role == "expert":
            out.append("model" if n_experts and dim % model == 0 else None)
        elif role == "model_in_expert":
            # used when experts themselves can't shard (E < model axis)
            out.append("model" if (n_experts % model != 0 and dim % model == 0)
                       else None)
        else:
            out.append(None)
    if fsdp and daxes:
        # shard the largest still-unsharded dim over the data axes (ZeRO-3).
        # (§Perf L3 tried extending the model-sharded dim instead —
        # same-dim "cheap" resharding — and REGRESSED wire 2x: the weight
        # all-gather then spans all 256 devices. Classic ZeRO-3 kept.)
        cands = [i for i, r in enumerate(out) if r is None]
        cands.sort(key=lambda i: -shape[i])
        for i in cands:
            if shape[i] % dsize == 0:
                out[i] = daxes if len(daxes) > 1 else daxes[0]
                break
    return P(*out)


def param_specs(params_shape, mesh: Mesh, *, fsdp: bool = False,
                n_experts: int = 0):
    """Pytree of PartitionSpec for a pytree of ShapeDtypeStruct/arrays."""

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        shape = np.shape(leaf) if not hasattr(leaf, "shape") else leaf.shape
        for pat, spec in _RULES:
            if re.search(pat, pstr):
                if len(spec) == len(shape):
                    return _resolve(spec, shape, mesh, fsdp=fsdp,
                                    n_experts=n_experts)
                if len(spec) == len(shape) - 1:
                    # stacked-layer leading dim (scan-over-layers params)
                    return _resolve((None,) + tuple(spec), shape, mesh,
                                    fsdp=fsdp, n_experts=n_experts)
                if len(spec) == len(shape) - 2:
                    # stacked under two axes (hybrid grouped layers)
                    return _resolve((None, None) + tuple(spec), shape, mesh,
                                    fsdp=fsdp, n_experts=n_experts)
                break
        # default: FSDP-shard biggest dim if requested, else replicate
        return _resolve((None,) * len(shape), shape, mesh, fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def named_shardings(params_shape, mesh: Mesh, **kw):
    specs = param_specs(params_shape, mesh, **kw)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


def cache_specs(cache_shape, mesh: Mesh):
    """Decode-cache sharding: batch over data; heads over model; for GQA
    caches whose kv-head count can't split, the sequence axis takes the model
    axis (flash-decoding style sharded-KV attention — GSPMD inserts the
    partial-softmax collectives)."""
    daxes = data_axes(mesh)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1
    msize = mesh.shape.get("model", 1)

    def one(path, leaf):
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in path)
        shape = leaf.shape
        spec = [None] * len(shape)
        if re.search(r"pos", pstr) or len(shape) < 3:
            return P(*spec)
        # layouts: kv (L,B,S,KV,hd) | ssm (L,B,H,N,P) | conv (L,B,K,C)
        if shape[1] % dsize == 0:
            spec[1] = dax
        if re.search(r"(^|/)(k|v)$", pstr) and len(shape) == 5:
            if shape[3] % msize == 0:
                spec[3] = "model"      # kv heads
            elif shape[2] % msize == 0:
                spec[2] = "model"      # sequence-parallel KV
        elif re.search(r"ssm", pstr) and len(shape) >= 4:
            if shape[2] % msize == 0:
                spec[2] = "model"      # ssm heads
        elif re.search(r"conv", pstr) and len(shape) == 4:
            if shape[3] % msize == 0:
                spec[3] = "model"      # conv channels
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)


def batch_specs(batch_shape, mesh: Mesh):
    """Row-shard every batch tensor over the data axes (dim 0)."""
    daxes = data_axes(mesh)
    ax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def one(leaf):
        shape = leaf.shape
        first = ax if shape and shape[0] % max(dsize, 1) == 0 else None
        return P(*((first,) + (None,) * (len(shape) - 1)))

    return jax.tree_util.tree_map(one, batch_shape)
