"""Post-SPMD HLO analysis: collective inventory + roofline terms.

``cost_analysis()`` gives FLOPs and bytes but NOT collective traffic; we parse
the compiled (post-partitioning) HLO text and sum the bytes of every
all-reduce / all-gather / reduce-scatter / all-to-all / collective-permute.

Two numbers are reported per run:
- ``collective_bytes``: plain sum of collective op output sizes (the task's
  prescribed metric);
- ``wire_bytes``: ring-algorithm wire traffic per device
  (all-reduce 2(S-1)/S, all-gather/all-to-all (S-1)/S of the full payload,
  reduce-scatter (S-1) x shard, permute 1x) — the physically-meaningful
  number used for the collective roofline term.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w\.\-]+\s*=\s*(?P<out>\(?[^=]*?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(", re.M)
_GROUPS_RE = re.compile(
    r"replica_groups=(?:\{(?P<explicit>[^}]*(?:\},\{[^}]*)*)\}\}|"
    r"\[(?P<iota>[\d,]+)\]<=\[\d+\])")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return 2
    if m.group("iota"):
        dims = [int(x) for x in m.group("iota").split(",")]
        return dims[-1] if len(dims) > 1 else dims[0]
    first = m.group("explicit").split("},{")[0].strip("{}")
    return max(len([t for t in first.split(",") if t.strip() != ""]), 1)


def collect_collectives(hlo_text: str) -> dict:
    """Inventory of collectives: per-op count, payload bytes, wire bytes."""
    stats = defaultdict(lambda: {"count": 0, "bytes": 0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        op = m.group("op")
        out_b = _shape_bytes(m.group("out"))
        s = _group_size(line)
        if op == "all-reduce":
            wire = 2.0 * (s - 1) / s * out_b
        elif op in ("all-gather", "all-to-all"):
            wire = (s - 1) / s * out_b
        elif op == "reduce-scatter":
            wire = float((s - 1)) * out_b  # out is the scattered shard
        else:  # collective-permute
            wire = float(out_b)
        st = stats[op]
        st["count"] += 1
        st["bytes"] += out_b
        st["wire_bytes"] += wire
    return dict(stats)


def summarize(hlo_text: str) -> dict:
    st = collect_collectives(hlo_text)
    return {
        "per_op": st,
        "collective_bytes": sum(v["bytes"] for v in st.values()),
        "wire_bytes": sum(v["wire_bytes"] for v in st.values()),
        "n_collectives": sum(v["count"] for v in st.values()),
    }


# ---------------------------------------------------------------------------
# roofline terms (TPU v5e-class constants)
# ---------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link (~ per-device effective)


def roofline_terms(flops: float, hbm_bytes: float, collective_bytes: float,
                   wire_bytes: float, chips: int) -> dict:
    """Three terms in seconds.

    cost_analysis numbers come from the per-device (post-SPMD) module, so
    compute/memory terms divide by the single-chip peak; the task-prescribed
    collective term divides the plain byte sum by chips x link_bw, and we also
    report the ring-model wire time (wire_bytes / ICI_BW, per device).
    """
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = hbm_bytes / HBM_BW
    t_collective = collective_bytes / (chips * ICI_BW)
    t_wire = wire_bytes / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", max(t_collective, t_wire))),
                   key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_collective, "t_wire_s": t_wire,
            "dominant": dominant}