"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, regardless
of trip count (verified empirically) — with scan-over-layers models this
undercounts flops/bytes/collectives by 1-3 orders of magnitude.  This module
re-derives the costs from the post-optimization HLO text, recursively
expanding ``while`` bodies (x trip count), ``fusion``/``call`` computations,
and inventorying collectives with the correct multipliers.

Conventions (mirroring HloCostAnalysis):
- dot: 2 x elems(output) x prod(contracted dims)
- elementwise arithmetic: 1 flop / output element; transcendentals tracked
  separately
- bytes accessed: operands + outputs of top-level instructions (fusion
  internals stay in registers — only the fusion's own operands/outputs touch
  HBM); parameter/constant/tuple plumbing excluded
- while trip count: parsed from the loop condition's comparison constant
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# instruction: [ROOT] %name = <shape(s)> opcode(<operands...>)<attrs>
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not", "clamp",
    "remainder", "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "floor", "ceil", "round-nearest-afz", "round-nearest-even", "sign",
    "is-finite", "popcnt", "clz",
}
_TRANSCENDENTAL = {"exponential", "log", "log-plus-one", "exponential-minus-one",
                   "power", "tanh", "logistic", "rsqrt", "sqrt", "cbrt",
                   "sine", "cosine", "tan", "atan2", "erf"}
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id", "iota"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start", "reduce-scatter-start",
                "all-to-all-start"}


def _shape_elems_bytes(shape_str: str) -> tuple[int, int]:
    """Total elements and bytes across all array shapes in the string."""
    elems = 0
    bts = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


def _first_shape_dims(shape_str: str) -> list[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str  # operand list + attrs (raw tail of the line)

    def operands(self) -> list[str]:
        """Operand instruction names from the first paren group."""
        depth = 1
        out = []
        cur = ""
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            cur += ch
        for tok in re.findall(r"%([\w\.\-]+)", cur):
            out.append(tok)
        return out

    def attr(self, key: str):
        m = re.search(rf"{key}=%?([\w\.\-]+)", self.rest)
        return m.group(1) if m else None

    def attr_braced(self, key: str):
        m = re.search(rf"{key}=\{{([^}}]*)\}}", self.rest)
        return m.group(1) if m else None


@dataclasses.dataclass
class CostTotals:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes_accessed: float = 0.0
    collectives: dict = dataclasses.field(
        default_factory=lambda: defaultdict(
            lambda: {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0}))

    def add(self, other: "CostTotals", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes_accessed += other.bytes_accessed * mult
        for k, v in other.collectives.items():
            st = self.collectives[k]
            for f in ("count", "bytes", "wire_bytes"):
                st[f] += v[f] * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps: dict[str, list[Instr]] = {}
        self.entry: str = ""
        self._parse(hlo_text)
        self._cache: dict[str, CostTotals] = {}
        # instruction names are unique module-wide in HLO text
        self._producers: dict[str, Instr] = {
            i.name: i for instrs in self.comps.values() for i in instrs}

    # ---------------- parsing ----------------

    def _parse(self, text: str):
        cur = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line or line.startswith("//"):
                continue
            if not line.startswith(" ") and line.endswith("{"):
                m = _COMP_HDR_RE.match(line.strip(" {"))
                if m:
                    cur = m.group(1)
                    self.comps[cur] = []
                    if line.lstrip().startswith("ENTRY"):
                        self.entry = cur
                    continue
            if line.strip() == "}":
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if m:
                self.comps[cur].append(
                    Instr(m.group(1), m.group(2), m.group(3), m.group(4)))

    def _symtab(self, comp: str) -> dict[str, str]:
        return {i.name: i.shape for i in self.comps.get(comp, [])}

    # ---------------- trip counts ----------------

    def _trip_count(self, cond_comp: str) -> int:
        """Best-effort: the largest integer constant in the loop condition."""
        best = 1
        for i in self.comps.get(cond_comp, []):
            if i.opcode == "constant":
                m = re.search(r"constant\((-?\d+)\)", i.opcode + "(" + i.rest)
                if m:
                    best = max(best, int(m.group(1)))
        return best

    # ---------------- per-instruction costs ----------------

    @staticmethod
    def _group_size(rest: str) -> int:
        m = re.search(r"replica_groups=\[([\d,]+)\]<=\[\d+\]", rest)
        if m:
            dims = [int(x) for x in m.group(1).split(",")]
            return dims[-1] if len(dims) > 1 else dims[0]
        m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
        if m:
            return max(len([t for t in m.group(1).split(",") if t.strip()]), 1)
        return 2

    def _dot_flops(self, ins: Instr, symtab: dict) -> float:
        out_elems, _ = _shape_elems_bytes(ins.shape)
        ops = ins.operands()
        lhs_shape = symtab.get(ops[0], "") if ops else ""
        lhs_dims = _first_shape_dims(lhs_shape)
        contract = ins.attr_braced("lhs_contracting_dims")
        k = 1
        if contract and lhs_dims:
            for idx in contract.split(","):
                idx = idx.strip()
                if idx:
                    k *= lhs_dims[int(idx)]
        return 2.0 * out_elems * k

    def _upcast_factor(self, ins: Instr) -> float:
        """1.0, or <1 when the (first) operand is a pure dtype upcast."""
        ops_ = ins.operands()
        if not ops_:
            return 1.0
        producer = self._producers.get(ops_[0])
        if producer is None:
            return 1.0
        if producer.opcode == "convert" or (
                producer.opcode == "fusion" and "convert" in producer.name):
            pin = producer.operands()
            if pin:
                src_ins = self._producers.get(pin[0])
                src = src_ins.shape if src_ins is not None else ""
                _, src_b = _shape_elems_bytes(src)
                _, dst_b = _shape_elems_bytes(producer.shape)
                if src_b and dst_b and src_b < dst_b:
                    return src_b / dst_b
        return 1.0

    def _fused_param_bytes(self, comp: str, param_idx: int):
        """If parameter(param_idx) of a fused computation is consumed ONLY by
        slicing ops, return the summed slice-output bytes; else None."""
        instrs = self.comps.get(comp)
        if not instrs:
            return None
        pname = None
        for i in instrs:
            if i.opcode == "parameter" and i.rest.startswith(f"{param_idx})"):
                pname = i.name
                break
        if pname is None:
            return None
        sliced = 0
        for i in instrs:
            if pname in i.operands():
                if i.opcode in ("dynamic-slice", "slice", "gather"):
                    _, b = _shape_elems_bytes(i.shape)
                    sliced += b
                elif i.opcode in ("bitcast", "copy", "reshape", "transpose"):
                    return None  # consumed wholesale via a reshape chain
                else:
                    return None
        return sliced if sliced else None

    # ---------------- computation walk ----------------

    def cost(self, comp: str) -> CostTotals:
        if comp in self._cache:
            return self._cache[comp]
        total = CostTotals()
        self._cache[comp] = total  # break cycles defensively
        symtab = self._symtab(comp)
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            out_elems, out_bytes = _shape_elems_bytes(ins.shape)
            if op == "while":
                body = ins.attr("body")
                cond = ins.attr("condition")
                trip = self._trip_count(cond) if cond else 1
                if body:
                    total.add(self.cost(body), trip)
                if cond:
                    total.add(self.cost(cond), trip)
                continue
            if op in ("fusion", "call", "custom-call", "map", "reduce",
                      "reduce-window", "sort", "scatter", "select-and-scatter"):
                called = ins.attr("calls") or ins.attr("to_apply")
                if called and op in ("fusion", "call", "map"):
                    sub = self.cost(called)
                    total.flops += sub.flops
                    total.transcendentals += sub.transcendentals
                    # fusion internals don't touch HBM; bytes from this line
                    for k, v in sub.collectives.items():
                        st = total.collectives[k]
                        for f in ("count", "bytes", "wire_bytes"):
                            st[f] += v[f]
                elif op == "reduce":
                    total.flops += out_elems  # ~1 op per output elem per input
                op_bytes = out_bytes
                for i, o in enumerate(ins.operands()):
                    _, b = _shape_elems_bytes(symtab.get(o, ""))
                    if op == "fusion" and called:
                        # utilization: a parameter consumed only through
                        # slice/gather ops reads just the slices (the operand
                        # is often the full stacked-layers array)
                        sb = self._fused_param_bytes(called, i)
                        if sb is not None:
                            b = min(b, sb)
                    op_bytes += b
                total.bytes_accessed += op_bytes
                continue
            if op in _COLLECTIVES:
                base = op.replace("-start", "")
                s = self._group_size(ins.rest)
                # XLA:CPU float-normalization upcasts bf16 values to f32
                # before dots/collectives (host-platform artifact — on TPU
                # the payload stays bf16).  When the operand is a pure
                # upcast, count the original dtype's bytes.
                payload = out_bytes * self._upcast_factor(ins)
                if base == "all-reduce":
                    wire = 2.0 * (s - 1) / s * payload
                elif base in ("all-gather", "all-to-all"):
                    wire = (s - 1) / s * payload
                elif base == "reduce-scatter":
                    wire = float(s - 1) * payload
                else:
                    wire = float(payload)
                st = total.collectives[base]
                st["count"] += 1
                st["bytes"] += payload
                st["wire_bytes"] += wire
                total.bytes_accessed += payload
                continue
            if op in _SKIP_BYTES or op.endswith("-done"):
                continue
            # slicing ops touch only the slice, not the full operand (matches
            # HloCostAnalysis; critical inside scan bodies where the operand
            # is the full stacked-layers array)
            if op in ("dynamic-slice", "slice", "gather"):
                total.bytes_accessed += 2.0 * out_bytes
                continue
            if op in ("dynamic-update-slice", "scatter"):
                ops_ = ins.operands()
                upd = symtab.get(ops_[1], "") if len(ops_) > 1 else ""
                _, ub = _shape_elems_bytes(upd)
                total.bytes_accessed += 2.0 * ub + (ub if op == "scatter" else 0)
                continue
            # generic op: bytes = operands + output
            op_bytes = out_bytes
            for o in ins.operands():
                _, b = _shape_elems_bytes(symtab.get(o, ""))
                op_bytes += b
            total.bytes_accessed += op_bytes
            if op == "dot":
                total.flops += self._dot_flops(ins, symtab)
            elif op == "convolution":
                # approx: 2 x out x kernel elems (rare in this code base)
                total.flops += 2.0 * out_elems
            elif op in _TRANSCENDENTAL:
                total.transcendentals += out_elems
            elif op in _ELEMENTWISE:
                total.flops += out_elems
        return total

    def entry_cost(self) -> CostTotals:
        return self.cost(self.entry)


def top_instructions(hlo_text: str, n: int = 12) -> list[tuple]:
    """Largest trip-weighted byte consumers (debugging/perf-iteration aid).

    Returns [(bytes_total, 'loc: opcode name shape'), ...] descending.
    """
    model = HloCostModel(hlo_text)
    rows = []

    def walk(comp, mult):
        symtab = model._symtab(comp)
        for ins in model.comps.get(comp, []):
            op = ins.opcode
            if op == "while":
                body, cond = ins.attr("body"), ins.attr("condition")
                trip = model._trip_count(cond) if cond else 1
                if body:
                    walk(body, mult * trip)
                continue
            if op in _SKIP_BYTES or op.endswith("-done"):
                continue
            _, ob = _shape_elems_bytes(ins.shape)
            b = ob
            if op in ("dynamic-slice", "slice", "gather"):
                b = 2 * ob
            else:
                for i, o in enumerate(ins.operands()):
                    _, x = _shape_elems_bytes(symtab.get(o, ""))
                    if op == "fusion":
                        called = ins.attr("calls")
                        sb = model._fused_param_bytes(called, i) if called else None
                        if sb is not None:
                            x = min(x, sb)
                    b += x
            rows.append((b * mult,
                         f"{comp[:24]}: {op} {ins.name[:32]} {ins.shape[:48]} x{mult}"))

    walk(model.entry, 1)
    rows.sort(key=lambda r: -r[0])
    return rows[:n]


def xla_cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``.

    Depending on JAX version this returns a dict or a list with one dict per
    device/partition; normalize to a single flat dict (summing numeric
    entries across list elements so multi-device results stay meaningful).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, dict):
        return ca
    out: dict = {}
    for entry in ca or []:
        for k, v in entry.items():
            if isinstance(v, (int, float)) and k in out:
                out[k] += v
            else:
                out[k] = v
    return out


def analyze(hlo_text: str) -> dict:
    """Full trip-count-aware summary of a post-SPMD module (per device)."""
    model = HloCostModel(hlo_text)
    t = model.entry_cost()
    coll = {k: dict(v) for k, v in t.collectives.items()}
    return {
        "flops": t.flops,
        "transcendentals": t.transcendentals,
        "bytes_accessed": t.bytes_accessed,
        "per_op": coll,
        "collective_bytes": sum(v["bytes"] for v in coll.values()),
        "wire_bytes": sum(v["wire_bytes"] for v in coll.values()),
        "n_collectives": sum(v["count"] for v in coll.values()),
    }