import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and extract the roofline inputs.

The two lines above MUST precede every other import: jax locks the device
count at first initialization, and the dry-run needs 512 placeholder host
devices to build the 2x16x16 multi-pod mesh.  (Do not set this globally —
smoke tests and benchmarks run on 1 device.)

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_405b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, the collective inventory parsed from the
post-SPMD HLO, and the three roofline terms.  Results are cached: finished
cells are skipped unless --force.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import ALL_SHAPES  # noqa: E402
from repro.configs.registry import ARCH_IDS, canonical  # noqa: E402
from repro.distributed import hlo_analysis, hlo_cost  # noqa: E402
from repro.distributed.sharding import set_active_mesh  # noqa: E402
from repro.launch.cells import iter_cells, plan_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

SHAPES = {s.name: s for s in ALL_SHAPES}


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    # peak live bytes per device (arguments alias outputs via donation)
    out["per_device_bytes"] = (out.get("argument_size_in_bytes", 0)
                               + out.get("temp_size_in_bytes", 0)
                               + out.get("output_size_in_bytes", 0)
                               - out.get("alias_size_in_bytes", 0))
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "experiments/dryrun", force: bool = False,
             tcfg=None, tag: str = "", verbose: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{canonical(arch)}__{shape_name}__{mesh_name}" + (
        f"__{tag}" if tag else "")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_id + ".json")
    if os.path.exists(path) and not force:
        with open(path) as fh:
            return json.load(fh)

    mesh = make_production_mesh(multi_pod=multi_pod)
    set_active_mesh(mesh)
    rec = {"cell": cell_id, "arch": canonical(arch), "shape": shape_name,
           "mesh": list(mesh.devices.shape), "chips": int(mesh.devices.size),
           "ok": False}
    try:
        shape = SHAPES[shape_name]
        t0 = time.perf_counter()
        plan = plan_cell(arch, shape, mesh, tcfg=tcfg)
        with mesh:
            lowered = plan.jitted.lower(*plan.abstract_args)
            rec["lower_s"] = round(time.perf_counter() - t0, 2)
            t1 = time.perf_counter()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.perf_counter() - t1, 2)

            mem = compiled.memory_analysis()
            rec["memory"] = _mem_dict(mem)
            xla_cost = hlo_cost.xla_cost_analysis(compiled)
            rec["xla_cost_analysis"] = {
                k: float(v) for k, v in xla_cost.items()
                if isinstance(v, (int, float)) and
                k in ("flops", "bytes accessed", "transcendentals")}
            # XLA's cost_analysis counts while bodies ONCE (verified); use the
            # trip-count-aware analyzer for the real roofline inputs.
            cost = hlo_cost.analyze(compiled.as_text())
            rec["cost"] = {"flops": cost["flops"],
                           "transcendentals": cost["transcendentals"],
                           "bytes_accessed": cost["bytes_accessed"]}
            flops = cost["flops"]
            hbm_bytes = cost["bytes_accessed"]
            rec["collectives"] = {
                "per_op": cost["per_op"],
                "collective_bytes": cost["collective_bytes"],
                "wire_bytes": cost["wire_bytes"],
                "n_collectives": cost["n_collectives"]}
            rec["model_flops"] = plan.model_flops
            # the analyzed module is per-device post-SPMD: model_flops is
            # global — normalize for the useful-compute ratio
            per_dev_model_flops = plan.model_flops / rec["chips"]
            rec["hlo_vs_model_flops"] = (
                flops / per_dev_model_flops if per_dev_model_flops else None)
            rec["roofline"] = hlo_analysis.roofline_terms(
                flops, hbm_bytes, cost["collective_bytes"],
                cost["wire_bytes"], rec["chips"])
            rec["ok"] = True
    except Exception as e:  # record failures — they are bugs to fix
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    finally:
        set_active_mesh(None)

    with open(path, "w") as fh:
        json.dump(rec, fh, indent=1)
    if verbose:
        if rec["ok"]:
            r = rec["roofline"]
            print(f"[dryrun] {cell_id}: OK compile={rec['compile_s']}s "
                  f"mem/dev={rec['memory']['per_device_bytes']/2**30:.2f}GiB "
                  f"compute={r['t_compute_s']:.4f}s memory={r['t_memory_s']:.4f}s "
                  f"wire={r['t_wire_s']:.4f}s dominant={r['dominant']}",
                  flush=True)
        else:
            print(f"[dryrun] {cell_id}: FAIL {rec['error']}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    todo = []
    if args.all:
        for arch, shape, skip in iter_cells():
            if skip:
                print(f"[dryrun] SKIP {arch}__{shape.name}: {skip}")
                continue
            todo.append((arch, shape.name))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        todo.append((args.arch, args.shape))

    failures = 0
    for mp in meshes:
        for arch, shape in todo:
            rec = run_cell(arch, shape, multi_pod=mp, out_dir=args.out,
                           force=args.force)
            failures += 0 if rec["ok"] else 1
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()