"""Per-architecture training presets: how each model fits the production mesh.

The memory strategy column is what makes the big configs fit 16 GB/chip on
256 chips (v5e):
- fsdp      : params + optimizer state sharded over the data axes (ZeRO-3)
- adafactor : factored second moments (1T-param Kimi-K2)
- bf16 state: moments stored bf16
- microbatch: grad-accumulation chunks for train_4k (activation memory)
"""

from __future__ import annotations

from repro.configs.base import TrainConfig

_PRESETS = {
    "whisper_base": TrainConfig(microbatch=1),
    "llama3_2_3b": TrainConfig(microbatch=2),
    "llama3_405b": TrainConfig(fsdp=True, optimizer="adafactor",
                               opt_state_dtype="bfloat16",
                               accum_dtype="bfloat16", microbatch=8),
    "chatglm3_6b": TrainConfig(microbatch=2, fsdp=True),
    "qwen3_32b": TrainConfig(fsdp=True, microbatch=8),
    "internvl2_2b": TrainConfig(microbatch=2),
    "mixtral_8x7b": TrainConfig(fsdp=True, microbatch=4),
    "kimi_k2": TrainConfig(fsdp=True, optimizer="adafactor",
                           opt_state_dtype="bfloat16",
                           accum_dtype="bfloat16", microbatch=16),
    "zamba2_2_7b": TrainConfig(microbatch=4),
    "mamba2_370m": TrainConfig(microbatch=4),
}


def train_preset(arch: str) -> TrainConfig:
    from repro.configs.registry import canonical
    return _PRESETS[canonical(arch)]