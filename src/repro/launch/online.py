"""Online-training launcher: event bus -> OnlineTrainer, continuously.

Local smoke run (CPU)::

    PYTHONPATH=src python -m repro.launch.online --duration 20 \
        --batch 256 --vocab 4096 --rate 40 --refit-every 25 \
        --shed-max-staleness 0.5 --checkpoint-every 50 --ckpt-dir /tmp/ockpt

A producer thread replays a synthetic Criteo-like event stream onto an
in-process ``EventBus`` (optionally fronted by the TCP transport with
``--port``); the ``OnlineTrainer`` consumes it through the staged ETL
executor, interleaving train steps with periodic incremental vocab
refits (rank-stable ``fit_incremental`` + atomic state swap), eval and
checkpoint rollover, while the ``FreshnessShedder`` keeps delivered
event age under ``--shed-max-staleness``.  ``--rate-mult`` > 1 makes the
producer deliberately outrun the trainer (the shedding acceptance
posture).
"""

from __future__ import annotations

import argparse
import threading
import time

import jax

from repro.configs.base import TrainConfig
from repro.core.pipeline import paper_pipeline
from repro.data.source import Source
from repro.models import dlrm
from repro.online import (BusServer, EventBus, OnlineConfig, OnlineTrainer,
                          replay)
from repro.session import EtlJob
from repro.training.train_loop import TrainState, make_train_step


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--duration", type=float, default=30.0,
                    help="wall-clock budget for the service loop (s)")
    ap.add_argument("--steps", type=int, default=0,
                    help="stop after this many steps (0 = duration only)")
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--vocab", type=int, default=4096,
                    help="per-feature vocab capacity (fixed table size; "
                         "incremental refits grow ranks within it)")
    ap.add_argument("--d-emb", type=int, default=32)
    ap.add_argument("--rate", type=float, default=40.0,
                    help="producer rate, events (batches) per second")
    ap.add_argument("--rate-mult", type=float, default=1.0,
                    help="multiply --rate (2.0 = bursty 2x-trainer posture)")
    ap.add_argument("--burst", type=int, default=1,
                    help="publish this many events back-to-back per tick")
    ap.add_argument("--bus-capacity", type=int, default=128,
                    help="per-subscription bus bound (drop-oldest beyond)")
    ap.add_argument("--port", type=int, default=-1,
                    help="serve the bus over TCP on this port (0 = ephemeral,"
                         " -1 = in-process only)")
    ap.add_argument("--topic", default="events")
    ap.add_argument("--refit-every", type=int, default=25,
                    help="steps between incremental vocab refits (0 = off)")
    ap.add_argument("--refit-window", type=int, default=64,
                    help="max event batches per refit window")
    ap.add_argument("--shed-max-staleness", type=float, default=0.0,
                    help="freshness bound on event age at delivery, seconds "
                         "(0 = shedding off)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="steps between async checkpoints (0 = off)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--keep-ckpts", type=int, default=3)
    ap.add_argument("--eval-every", type=int, default=0,
                    help="steps between holdout evals (0 = off)")
    ap.add_argument("--log-every", type=int, default=25)
    ap.add_argument("--etl-backend", default="jnp",
                    choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--metrics-file", default="",
                    help="write executor stats (incl. the staleness "
                         "histogram) as Prometheus text here")
    ap.add_argument("--autotune", action="store_true",
                    help="run the self-tuning PipelineController over the "
                         "executor knobs")
    ap.add_argument("--seed", type=int, default=11)
    return ap


def build_service(args):
    """Wire bus + job + model + trainer from parsed flags.

    Returns ``(trainer, bus, producer)`` where ``producer()`` runs the
    paced replay until the duration elapses, then closes the bus.
    """
    bus = EventBus(capacity=args.bus_capacity)
    server = BusServer(bus, port=args.port) if args.port >= 0 else None

    pipe = paper_pipeline("II", small_vocab=args.vocab,
                          batch_size=args.batch)
    job = EtlJob(pipe, Source.events(bus, args.topic),
                 backend=args.etl_backend,
                 autotune=getattr(args, "autotune", False) or None,
                 metrics_file=args.metrics_file,
                 metrics_labels={"service": "online"},
                 name="online")
    # initial vocab: fit on a short synthetic prefix so the service starts
    # with a live (small) vocabulary that refits then grow incrementally
    warm = list(Source.synth("I", rows=args.batch * 8,
                             batch_size=args.batch, seed=args.seed))
    job.compiled.fit(iter(warm))

    cfg = dlrm.DLRMConfig(vocab_size=args.vocab + 1, d_emb=args.d_emb,
                          bot_mlp=(128, 64, args.d_emb),
                          top_mlp=(128, 64, 1))
    tcfg = TrainConfig(lr=1e-3)
    state = TrainState.create(dlrm.init(jax.random.key(args.seed), cfg), tcfg)
    step = jax.jit(make_train_step(
        lambda p, b: dlrm.loss_fn(p, b, cfg), tcfg))

    eval_fn = None
    if args.eval_every:
        holdout = job.compiled(warm[0])

        def eval_fn(st):
            return {"holdout_loss": float(dlrm.loss_fn(
                st.params, holdout, cfg))}

    ocfg = OnlineConfig(
        refit_every=args.refit_every, window_batches=args.refit_window,
        shed_max_staleness_s=args.shed_max_staleness,
        checkpoint_every=args.checkpoint_every, ckpt_dir=args.ckpt_dir,
        keep_ckpts=args.keep_ckpts, eval_every=args.eval_every,
        log_every=args.log_every)
    trainer = OnlineTrainer(job, state, step, ocfg,
                            bus=bus if args.refit_every else None,
                            topic=args.topic, eval_fn=eval_fn)

    def producer():
        # endless stream: cycle fresh synthetic event batches at the paced
        # rate; a different seed per lap keeps new vocab values arriving
        # so refits have something to learn
        rate = args.rate * args.rate_mult
        deadline = threading.Event()
        timer = threading.Timer(args.duration, deadline.set)
        timer.daemon = True
        timer.start()
        lap = 0
        try:
            while not deadline.is_set():
                feed = Source.synth("I", rows=args.batch * 64,
                                    batch_size=args.batch,
                                    seed=args.seed + 1 + lap)
                replay(bus, args.topic, feed, rate_hz=rate,
                       burst=args.burst, stop=deadline)
                lap += 1
        finally:
            timer.cancel()
            bus.close()
            if server is not None:
                server.close()

    return trainer, bus, producer


def main(argv=None):
    args = build_parser().parse_args(argv)
    trainer, bus, producer = build_service(args)
    t = threading.Thread(target=producer, name="online-producer")
    t.start()
    t0 = time.perf_counter()
    trainer.run(max_steps=args.steps or None,
                deadline_s=args.duration + 5.0)
    t.join()
    wall = time.perf_counter() - t0

    st, pct = trainer.stats, trainer.staleness_percentiles()
    shed = trainer.shed_stats()
    counts = bus.counts()
    print(f"[online] {st.steps} steps in {wall:.1f}s "
          f"({st.steps / max(wall, 1e-9):.1f} steps/s)")
    print(f"[online] swaps={st.swaps} versions={st.versions} "
          f"refit_batches={st.refit_batches} "
          f"checkpoints={st.checkpoints} evals={st.evals}")
    print(f"[online] staleness p50={pct['p50']*1e3:.1f}ms "
          f"p95={pct['p95']*1e3:.1f}ms p99={pct['p99']*1e3:.1f}ms "
          f"(bound {args.shed_max_staleness*1e3:.0f}ms)")
    print(f"[online] shed dropped={shed.dropped} "
          f"bus={counts}")
    if st.last_eval:
        print(f"[online] last eval: {st.last_eval}")
    return trainer


if __name__ == "__main__":
    main()
