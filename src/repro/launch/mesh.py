"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model) — the pod axis is the
    DCN/ICI-superpod data-parallel dimension."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    model_axis = min(model_axis, n)
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))