"""Cell construction: (arch x shape x mesh) -> jitted step + abstract inputs.

Shared by the dry-run (lower/compile with ShapeDtypeStructs — no allocation)
and by tests (small meshes).  A "cell" follows the task matrix:

- train_4k     : train_step (loss + grads + optimizer update)
- prefill_32k  : serve prefill (prompt -> logits + cache)
- decode_32k   : serve_step (one token against a seq_len KV cache/state)
- long_500k    : serve_step, sub-quadratic families only
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ALL_SHAPES, ModelConfig, ShapeCfg, TrainConfig
from repro.configs.registry import ARCH_IDS, canonical, get_config
from repro.distributed import sharding as shd
from repro.launch.presets import train_preset
from repro.models.api import build_model, input_specs
from repro.training.train_loop import TrainState, make_train_step

# long_500k requires sub-quadratic attention (see DESIGN.md
# §Arch-applicability): SSM state, hybrid, or SWA ring caches qualify.
LONG_CONTEXT_OK = {"mamba2_370m", "zamba2_2_7b", "mixtral_8x7b"}


def iter_cells():
    """Yield (arch, shape, skip_reason|None) for the full 10x4 matrix."""
    for arch in ARCH_IDS:
        for shape in ALL_SHAPES:
            skip = None
            if shape.name == "long_500k" and arch not in LONG_CONTEXT_OK:
                skip = ("full quadratic attention at 524k context — shape "
                        "excluded for pure full-attention archs")
            yield arch, shape, skip


@dataclasses.dataclass
class CellPlan:
    arch: str
    shape: ShapeCfg
    cfg: ModelConfig
    kind: str
    jitted: Any           # jit-wrapped callable
    abstract_args: tuple  # ShapeDtypeStructs to .lower(*args)
    chips: int
    model_flops: float    # 6ND (train) / 2ND (prefill) / 2N_act*B (decode)


def _to_sharding(mesh, tree_of_specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree_of_specs,
        is_leaf=lambda x: isinstance(x, P))


def _abstract(tree_of_shapes, tree_of_shardings):
    return jax.tree_util.tree_map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        tree_of_shapes, tree_of_shardings)


def plan_cell(arch: str, shape: ShapeCfg, mesh: Mesh,
              tcfg: Optional[TrainConfig] = None) -> CellPlan:
    arch = canonical(arch)
    cfg = get_config(arch)
    model = build_model(cfg)
    tcfg = tcfg or train_preset(arch)
    # grad-accumulation chunks cannot exceed rows-per-replica
    dp = int(np.prod([mesh.shape[a] for a in ("pod", "data")
                      if a in mesh.axis_names]))
    if tcfg.microbatch > 1:
        tcfg = dataclasses.replace(
            tcfg, microbatch=max(1, min(tcfg.microbatch,
                                        shape.global_batch // max(dp, 1))))
    chips = mesh.devices.size
    n_experts = cfg.moe.n_experts if cfg.moe else 0
    nparams = cfg.param_count()
    nactive = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len

    batch_shapes = input_specs(cfg, shape)
    batch_spec = shd.batch_specs(batch_shapes, mesh)
    batch_abs = _abstract(batch_shapes, _to_sharding(mesh, batch_spec))

    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: TrainState.create(model.init(jax.random.key(0)), tcfg))
        pspec = shd.param_specs(state_shapes.params, mesh, fsdp=tcfg.fsdp,
                                n_experts=n_experts)
        ospec = shd.param_specs(state_shapes.opt, mesh, fsdp=tcfg.fsdp,
                                n_experts=n_experts)
        state_spec = TrainState(params=pspec, opt=ospec, step=P())
        state_sh = _to_sharding(mesh, state_spec)
        step = make_train_step(model.loss, tcfg, grad_specs=pspec)
        jitted = jax.jit(step, in_shardings=(state_sh, _to_sharding(mesh, batch_spec)),
                         out_shardings=(state_sh, None), donate_argnums=(0,))
        state_abs = _abstract(state_shapes, state_sh)
        return CellPlan(arch, shape, cfg, "train", jitted,
                        (state_abs, batch_abs), chips,
                        6.0 * nactive * tokens)

    # serving cells share param shardings (no optimizer state).  Models whose
    # TP-sharded weights still exceed ~12GB/chip (Kimi-K2 1T, llama-405B)
    # additionally shard over the data axes (weight-gathered serving — the
    # standard big-model serving layout when chips x HBM is the binding
    # constraint).
    msize = mesh.shape.get("model", 1)
    pbytes = nparams * (2 if cfg.param_dtype == "bfloat16" else 4)
    serve_fsdp = pbytes / msize > 12e9
    param_shapes = jax.eval_shape(lambda: model.init(jax.random.key(0)))
    pspec = shd.param_specs(param_shapes, mesh, fsdp=serve_fsdp,
                            n_experts=n_experts)
    p_sh = _to_sharding(mesh, pspec)
    p_abs = _abstract(param_shapes, p_sh)

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch, shape.seq_len)

        out_shapes = jax.eval_shape(prefill_fn, param_shapes, batch_shapes)
        cache_spec = shd.cache_specs(out_shapes[1], mesh)
        jitted = jax.jit(prefill_fn,
                         in_shardings=(p_sh, _to_sharding(mesh, batch_spec)),
                         out_shardings=(None, _to_sharding(mesh, cache_spec)))
        return CellPlan(arch, shape, cfg, "prefill", jitted,
                        (p_abs, batch_abs), chips, 2.0 * nactive * tokens)

    # decode: one new token against a seq_len-deep cache
    cache_shapes = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))
    cspec = shd.cache_specs(cache_shapes, mesh)
    c_sh = _to_sharding(mesh, cspec)
    c_abs = _abstract(cache_shapes, c_sh)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos)

    tok_sh = _to_sharding(mesh, batch_spec)["tokens"]
    jitted = jax.jit(serve_step,
                     in_shardings=(p_sh, c_sh, tok_sh, None),
                     out_shardings=(None, c_sh),
                     donate_argnums=(1,))
    tok_abs = _abstract(batch_shapes["tokens"], tok_sh)
    return CellPlan(arch, shape, cfg, "decode", jitted,
                    (p_abs, c_abs, tok_abs, pos_abs), chips,
                    2.0 * nactive * shape.global_batch)