"""Training launcher: ETL-fed, checkpointed, fault-tolerant.

Local smoke run (CPU)::

    PYTHONPATH=src python -m repro.launch.train --arch llama3_2_3b \
        --reduced --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Production posture: same entry point with --mesh pod runs under the
16x16 production mesh (requires a real pod or the dry-run device flags);
every run is restartable — on startup the launcher restores the newest
committed checkpoint if one exists (elastic: the mesh geometry may differ
from the one that wrote it).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import ShapeCfg
from repro.configs.registry import get_config, get_reduced
from repro.core.pipeline import lm_token_pipeline
from repro.data.source import Source
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.presets import train_preset
from repro.models.api import build_model, input_specs
from repro.session import EtlJob
from repro.training import checkpoint as ckpt_lib
from repro.training.fault import run_with_restarts
from repro.training.train_loop import (LoopConfig, TrainState, jit_train_step,
                                       make_train_step, train_loop)


def make_job(cfg, batch, seq, steps, *, backend="jnp", mesh=None,
             metrics_file="", embed_cache=None, autotune=None) -> EtlJob:
    """Declarative ingest session: raw event logs -> token batches.

    The ``Source`` names the stream; ``EtlJob`` owns compile + executor
    lifecycle.  With a mesh, the executor's place stage double-buffers
    ``device_put`` with the trainer's batch ``NamedSharding``, so delivered
    batches are already laid out for ``train_step``'s ``in_shardings``.
    ``embed_cache`` (an ``EmbedCacheConfig``) adds the lookahead embedding
    prefetch stage — recommender pipelines whose batches carry a sparse
    index matrix; LM pipelines have no such key and must leave it unset.
    """
    pipe = lm_token_pipeline(seq, cfg.vocab_size, batch_size=batch)
    src = Source.lm_events(seq, rows=batch * (steps + 4), batch_size=batch)
    return EtlJob(pipe, src, backend=backend, mesh=mesh, credits=2,
                  metrics_file=metrics_file, embed_cache=embed_cache,
                  autotune=autotune, metrics_labels={"arch": cfg.name})


def embed_cache_config(args):
    """CLI knobs -> EmbedCacheConfig (None when the cache is off)."""
    if args.embed_cache_rows <= 0:
        return None
    from repro.etl_runtime.lookahead import EmbedCacheConfig
    tables = (tuple(int(t) for t in args.embed_cache_tables.split(","))
              if args.embed_cache_tables else None)
    return EmbedCacheConfig(rows=args.embed_cache_rows,
                            window=args.embed_cache_window,
                            tables=tables, key=args.embed_cache_key)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", choices=["host", "pod", "multipod"],
                    default="host")
    ap.add_argument("--etl-backend", default="jnp",
                    choices=["numpy", "jnp", "pallas"])
    ap.add_argument("--watchdog-s", type=float, default=0.0)
    ap.add_argument("--max-restarts", type=int, default=2)
    ap.add_argument("--metrics-file", default="",
                    help="write executor StageStats as Prometheus text here")
    ap.add_argument("--embed-cache-rows", type=int, default=0,
                    help="device-resident embedding-cache rows per table "
                         "(0 = lookahead prefetch off)")
    ap.add_argument("--embed-cache-window", type=int, default=4,
                    help="lookahead window W (batches) for hot-set planning")
    ap.add_argument("--embed-cache-tables", default="",
                    help="comma-separated feature columns to cache "
                         "(default: all columns of the index matrix)")
    ap.add_argument("--embed-cache-key", default="sparse",
                    help="payload key holding the [batch, tables] indices")
    ap.add_argument("--autotune", action="store_true",
                    help="run the self-tuning PipelineController over the "
                         "executor knobs (credits, prefetch depth, "
                         "lookahead window; row tile/fuse on pallas)")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    tcfg = train_preset(args.arch)
    model = build_model(cfg)
    if args.mesh == "host":
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh(multi_pod=args.mesh == "multipod")
    shd.set_active_mesh(mesh)

    def make_run():
        def run():
            shape = ShapeCfg("cli", args.seq, args.batch, "train")
            state_shapes = jax.eval_shape(
                lambda: TrainState.create(model.init(jax.random.key(0)), tcfg))
            batch_shapes = input_specs(cfg, shape)
            # batches come from the streaming executor and are consumed
            # exactly once, already placed in the step's in_shardings layout
            # — donate them so the handoff is zero-copy end to end
            step_fn, state_spec = jit_train_step(
                make_train_step(model.loss, tcfg), mesh, state_shapes,
                batch_shapes, fsdp=tcfg.fsdp,
                n_experts=cfg.moe.n_experts if cfg.moe else 0,
                donate_batch=True)

            def make_state():
                return TrainState.create(model.init(jax.random.key(0)), tcfg)

            from jax.sharding import NamedSharding, PartitionSpec
            shardings = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, s), state_spec,
                is_leaf=lambda x: isinstance(x, PartitionSpec))
            latest = (ckpt_lib.latest_step(args.ckpt_dir)
                      if args.ckpt_dir else None)
            if latest is not None:
                print(f"[train] resuming from step {latest}")
                zeros = jax.tree_util.tree_map(
                    lambda s: np.zeros(s.shape, s.dtype), state_shapes)
                state = ckpt_lib.restore(args.ckpt_dir, zeros,
                                         shardings=shardings)
            else:
                state = make_state()

            job = make_job(cfg, args.batch, args.seq, args.steps,
                           backend=args.etl_backend, mesh=mesh,
                           metrics_file=args.metrics_file,
                           embed_cache=embed_cache_config(args),
                           autotune=args.autotune or None)
            loop_cfg = LoopConfig(total_steps=args.steps,
                                  ckpt_dir=args.ckpt_dir,
                                  ckpt_every=args.ckpt_every,
                                  log_every=10,
                                  watchdog_s=args.watchdog_s)
            t0 = time.perf_counter()
            with mesh, job.batches() as batches:
                final = train_loop(state, step_fn, batches, loop_cfg)
            dt = time.perf_counter() - t0
            toks = args.steps * args.batch * args.seq
            stats = job.stats()
            print(f"[train] done: {args.steps} steps, "
                  f"{toks/dt:,.0f} tok/s, etl_producer_wait="
                  f"{stats.producer_wait_s:.2f}s trainer_wait="
                  f"{stats.consumer_wait_s:.2f}s "
                  f"util={stats.trainer_utilization(dt - stats.consumer_wait_s):.2%}")
            for name, s in stats.stage_breakdown().items():
                print(f"[train]   stage {name:9s} items={s['items']:<5d} "
                      f"busy={s['busy_s']:.2f}s wait_in={s['wait_in_s']:.2f}s "
                      f"wait_out={s['wait_out_s']:.2f}s "
                      f"occ={s['occupancy']:.1%}")
            if args.metrics_file:
                print(f"[train] metrics written to {args.metrics_file}")
            return final

        return run

    run_with_restarts(make_run, max_restarts=args.max_restarts)


if __name__ == "__main__":
    main()