"""Serving launcher: batched prefill+decode against a selectable arch.

Local smoke run: PYTHONPATH=src python -m repro.launch.serve \
    --arch mamba2_370m --reduced --batch 4 --prompt-len 32 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs.registry import get_config, get_reduced
from repro.models.api import build_model
from repro.serving.decode import generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    toks, stats = generate(model, params, jax.numpy.asarray(prompts),
                           max_new=args.max_new,
                           max_len=args.prompt_len + args.max_new,
                           temperature=args.temperature,
                           rng=jax.random.key(1))
    print(f"[serve] arch={cfg.name} prefill={stats.prefill_s:.3f}s "
          f"decode={stats.decode_s:.3f}s ({stats.tokens_per_s:,.1f} tok/s)")
    print("[serve] first sequence:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()