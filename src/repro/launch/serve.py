"""Serving launcher: ETL-fed batched prefill+decode against a selectable arch.

Local smoke run: PYTHONPATH=src python -m repro.launch.serve \
    --arch mamba2_370m --reduced --batch 4 --prompt-len 32 --max-new 16

Prompt ingest runs through the same declarative session facade as training
(`repro.session.EtlJob` over a `Source`): raw event logs stream through the
compiled token pipeline (SigridHash bounds unbounded ids into the model's
vocab), so serving exercises the identical ETL contract — freshness,
batching, and packer layout — the trainer consumes.

``--metrics-file PATH`` exports the run's counters in Prometheus text
format for a node_exporter textfile collector (ETL-fed launchers export
their per-stage StageStats the same way; see ``launch/train.py``).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, get_reduced
from repro.core.pipeline import lm_token_pipeline
from repro.data.source import Source
from repro.etl_runtime import metrics as metrics_lib
from repro.models.api import build_model
from repro.serving.decode import generate
from repro.session import EtlJob


def export_metrics(path: str, *, counters: dict, arch: str) -> None:
    """Write serving counters to ``path`` in Prometheus text format."""
    text = metrics_lib.counters_to_prometheus(
        counters, prefix="repro_serve", labels={"arch": arch})
    metrics_lib.write_metrics_file(path, text)


def make_prompt_job(cfg, *, batch: int, prompt_len: int,
                    seed: int = 0) -> EtlJob:
    """Prompt-ingest session: raw event ids -> bounded (batch, len) tokens."""
    pipe = lm_token_pipeline(prompt_len, cfg.vocab_size, batch_size=batch)
    src = Source.lm_events(prompt_len, rows=batch, batch_size=batch,
                           seed=seed)
    return EtlJob(pipe, src, backend="jnp", credits=1, name="serve-prompts")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--metrics-file", default="",
                    help="write Prometheus-style text counters here")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    job = make_prompt_job(cfg, batch=args.batch, prompt_len=args.prompt_len)
    with job.batches() as batches:
        prompt_batch = next(iter(batches))
    prompts = jnp.asarray(prompt_batch["tokens"])
    toks, stats = generate(model, params, prompts,
                           max_new=args.max_new,
                           max_len=args.prompt_len + args.max_new,
                           temperature=args.temperature,
                           rng=jax.random.key(1))
    print(f"[serve] arch={cfg.name} prefill={stats.prefill_s:.3f}s "
          f"decode={stats.decode_s:.3f}s ({stats.tokens_per_s:,.1f} tok/s)")
    print("[serve] first sequence:", toks[0][:16].tolist())
    if args.metrics_file:
        etl = job.stats()
        export_metrics(args.metrics_file, arch=cfg.name, counters={
            "prefill_seconds_total": stats.prefill_s,
            "decode_seconds_total": stats.decode_s,
            "generated_tokens_total": args.batch * args.max_new,
            "sequences_total": args.batch,
            "etl_prompt_batches_total": etl.consumed if etl else 0,
        })
        print(f"[serve] metrics written to {args.metrics_file}")


if __name__ == "__main__":
    main()
