"""Pallas backend capability: which compiled target (if any) exists here.

``default_interpret`` is the ONE switch every kernel entry point resolves
against (``interpret=None`` in the public wrappers and the raw factories
alike): interpret mode runs the kernel body as traced JAX ops — the CPU
validation harness — while compiled mode lowers through the backend's real
Pallas pipeline.  Selection is by *capability*, not a TPU whitelist:

- ``tpu``  -> Mosaic lowering exists          -> compiled (interpret=False)
- ``gpu``  -> the Pallas Triton path exists   -> compiled (interpret=False)
- anything else (cpu, unknown plugins)        -> interpret (interpret=True)

The resolved mode is logged exactly once per process so a silent fall-back
to interpret mode (the bug this module fixes: GPU hosts used to interpret
every kernel and throw the Triton path away) is visible in any log.
"""

from __future__ import annotations

import logging
from typing import Optional

import jax

logger = logging.getLogger("repro.kernels")

# jax.default_backend() -> the Pallas compiled lowering it can drive
_COMPILED_TARGETS = {"tpu": "mosaic", "gpu": "triton"}

_logged_mode = False


def compiled_backend() -> Optional[str]:
    """Name of the compiled Pallas target for this process's default JAX
    backend ("mosaic" | "triton"), or None when only interpret mode can
    execute (CPU and unknown plugin backends)."""
    return _COMPILED_TARGETS.get(jax.default_backend())


def default_interpret() -> bool:
    """Resolved interpret flag for every kernel whose caller passed None.

    False whenever a compiled Pallas target exists for the default backend
    (TPU/Mosaic, GPU/Triton), True otherwise.  Logs the resolution once.
    """
    global _logged_mode
    target = compiled_backend()
    interpret = target is None
    if not _logged_mode:
        _logged_mode = True
        if interpret:
            logger.info(
                "pallas kernels default to interpret mode (backend=%s has "
                "no compiled Pallas target)", jax.default_backend())
        else:
            logger.info(
                "pallas kernels default to compiled mode (backend=%s -> %s)",
                jax.default_backend(), target)
    return interpret
