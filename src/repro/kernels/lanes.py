"""Lane-alignment helpers shared by every Pallas kernel module.

Mosaic (TPU) tiles vectors as (8 sublanes x 128 lanes); memory blocks whose
minor dimension is not a multiple of 128 — or constructs like 1-D iota,
lane-collapsing reshapes, and flat dynamic gathers — do not lower.  The
kernels therefore share one vocabulary of lane-safe building blocks:

- ``lane_pad`` / ``sublane_pad``: round widths up to the hardware tile.
- ``lane_gather``: gather ``tbl[0, idx]`` for a 2-D index tile without any
  1-D reshape: the table tile is broadcast across sublanes (bank by bank,
  so the broadcast operand stays VMEM-bounded) and gathered along lanes
  with ``take_along_axis`` — the shape Mosaic's dynamic-gather rule and
  Triton's vectorized loads both accept.  Interpret mode evaluates the same
  jnp ops, so both modes compute bit-identical values by construction.
- ``onehot_lanes``: the in-kernel one-hot. The operator-level expression
  (``operators.OneHot.jnp_expr``) collapses the depth axis with a reshape
  that merges into the lane dimension — illegal under Mosaic — so the tile
  codegen emits this per-column concat form instead: same values, lane
  concatenation only, iota only in its 2-D broadcasted form.
- ``gather_scratch_bytes``: the planner's VMEM account of one in-kernel
  ``lane_gather`` (bank broadcast + gathered bank), used by the
  compiled-mode legality pass (``mosaic-illegal`` fallback).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

LANE = 128      # minor-dim tile of a TPU vreg
SUBLANE = 8     # second-minor tile (float32/int32)

# lanes per bank of the in-kernel table gather: bounds the broadcast
# operand of lane_gather to (block_rows, GATHER_BANK) whatever the table
# capacity, at the cost of one masked pass per bank
GATHER_BANK = 2048


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def lane_pad(w: int) -> int:
    """Pad a width up to the lane tile (>= 1 lane group)."""
    return round_up(max(int(w), 1), LANE)


def sublane_pad(w: int) -> int:
    """Pad a second-minor width up to the sublane tile."""
    return round_up(max(int(w), 1), SUBLANE)


def lane_gather(tbl, idx):
    """``out[r, c] = tbl[0, idx[r, c]]`` with lane-aligned ops only.

    ``tbl``: (1, C); ``idx``: int (rows, w), every entry in [0, C).
    Each index hits exactly one bank, so the masked bank passes compose to
    the exact gather (no accumulation, last write wins per element).
    """
    rows = idx.shape[0]
    c = tbl.shape[-1]
    if c <= GATHER_BANK:
        bank = jnp.broadcast_to(tbl, (rows, c))
        return jnp.take_along_axis(bank, idx, axis=1)
    acc = jnp.zeros(idx.shape, tbl.dtype)
    for b in range(0, c, GATHER_BANK):
        bw = min(GATHER_BANK, c - b)
        local = idx - b
        inb = (local >= 0) & (local < bw)
        safe = jnp.where(inb, local, 0)
        bank = jnp.broadcast_to(tbl[:, b:b + bw], (rows, bw))
        got = jnp.take_along_axis(bank, safe, axis=1)
        acc = jnp.where(inb, got, acc)
    return acc


def gather_scratch_bytes(block_rows: int, capacity: int,
                         itemsize: int = 4) -> int:
    """VMEM bytes one in-kernel ``lane_gather`` holds live per tile: the
    broadcast bank plus the gathered bank (the accumulator is the output
    tile the working set already counts)."""
    bank = min(lane_pad(capacity), GATHER_BANK)
    return 2 * block_rows * bank * itemsize


def onehot_lanes(x, depth: int):
    """Lane-aligned one-hot of a 2-D int tile: (rows, w) -> (rows, w*depth).

    Column layout matches ``operators.OneHot`` exactly
    (``out[r, c*depth + j] = float(x[r, c] == j)``; out-of-range rows are
    all-zero), but the expansion is a lane concat of per-column indicator
    tiles instead of a trailing-axis reshape.
    """
    k = jax.lax.broadcasted_iota(jnp.int32, (1, depth), 1).astype(x.dtype)
    cols = [(x[:, c:c + 1] == k).astype(jnp.float32)
            for c in range(x.shape[1])]
    return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
