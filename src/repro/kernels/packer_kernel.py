"""Format-aware packer as a Pallas kernel (paper §3 "format-aware packer").

Takes the materialized ETL output blocks and writes ONE training-ready tensor:
column blocks are concatenated along lanes, cast to the trainer dtype, and the
total width padded to a 128-lane multiple — the exact layout ``train_step``
declares in its ``input_specs`` (zero-copy handoff: no reshape/copy on the
trainer side; the paper's "device-to-device placement + slicing/reshape" stage
disappears because the packer emits the final layout directly).

Grid is over row blocks; each input block is staged through VMEM once and
stored into its static lane offset of the output block.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def make_packer(col_widths, in_dtypes, out_dtype, *, pad_cols_to: int = 128,
                block_rows: int = 256, interpret: bool = True):
    """Build fn(blocks...) -> packed [rows, padded(sum(col_widths))]."""
    col_widths = [int(w) for w in col_widths]
    total = sum(col_widths)
    padded = _round_up(total, pad_cols_to)
    offsets = np.cumsum([0] + col_widths).tolist()

    def kernel(*refs):
        o_ref = refs[-1]
        o_ref[...] = jnp.zeros_like(o_ref)
        for k, x_ref in enumerate(refs[:-1]):
            o_ref[:, offsets[k]:offsets[k + 1]] = x_ref[...].astype(o_ref.dtype)

    def run(*blocks):
        assert len(blocks) == len(col_widths)
        rows = blocks[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_blocks = [jnp.pad(b, ((0, rp - rows), (0, 0))) for b in blocks]
        out = pl.pallas_call(
            kernel,
            grid=(rp // br,),
            in_specs=[pl.BlockSpec((br, w), lambda r: (r, 0))
                      for w in col_widths],
            out_specs=pl.BlockSpec((br, padded), lambda r: (r, 0)),
            out_shape=jax.ShapeDtypeStruct((rp, padded), out_dtype),
            interpret=interpret,
        )(*padded_blocks)
        return out[:rows]

    return run
