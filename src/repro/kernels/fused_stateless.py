"""Fused stateless ETL stage as a Pallas TPU kernel (PipeRec Stage-A).

The planner fuses a chain of stateless operators; the compiler code-generates a
single elementwise ``chain_fn`` and this factory wraps it in a streaming kernel:

  HBM --(one read)--> VMEM block --(fused chain, VPU)--> VMEM --(one write)--> HBM

which is the TPU statement of the paper's "II=1 deeply-pipelined dataflow with
no intermediate materialization": each byte crosses HBM exactly twice.

Tiling
------
- plain input : x[R, C]            block (block_rows, block_cols)
- hex input   : x[w, R, C] uint8   block (w, block_rows, block_cols)
  (digit-major layout keeps the trailing two dims = TPU sublane x lane tile;
  the fold over w runs in registers — the FPGA shift-register analogue)

Block columns are multiples of 128 (VPU lane width = the paper's W);
block rows are multiples of 8 (sublanes); grid = N parallel lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def make_fused_stage(chain_fn, *, in_dtype, out_dtype, hex_width: int = 0,
                     block_rows: int = 256, block_cols: int = 512,
                     interpret: bool = True):
    """Build a jit-compatible fn: x -> fused(x).

    chain_fn: elementwise block function. For hex inputs it receives the
    (w, br, bc) uint8 block and must fold the leading digit axis itself.
    """

    def kernel(x_ref, o_ref):
        o_ref[...] = chain_fn(x_ref[...]).astype(o_ref.dtype)

    @functools.partial(jax.jit, static_argnames=())
    def run(x):
        if hex_width:
            w, rows, cols = x.shape
            assert w == hex_width, (x.shape, hex_width)
        else:
            rows, cols = x.shape
        br = min(block_rows, _round_up(rows, 8))
        bc = min(block_cols, _round_up(cols, 128))
        rp, cp = _round_up(rows, br), _round_up(cols, bc)
        # pad to block multiples (padding lanes carry zeros; sliced off below)
        if hex_width:
            xp = jnp.pad(x, ((0, 0), (0, rp - rows), (0, cp - cols)))
            in_spec = pl.BlockSpec((hex_width, br, bc), lambda i, j: (0, i, j))
        else:
            xp = jnp.pad(x, ((0, rp - rows), (0, cp - cols)))
            in_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
        grid = (rp // br, cp // bc)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[in_spec],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((rp, cp), out_dtype),
            interpret=interpret,
        )(xp)
        return out[:rows, :cols]

    return run


def vmem_bytes_estimate(in_dtype, out_dtype, hex_width: int,
                        block_rows: int, block_cols: int) -> int:
    """Planner helper: VMEM working set claimed by one grid step."""
    in_b = np.dtype(in_dtype).itemsize * block_rows * block_cols * (hex_width or 1)
    out_b = np.dtype(out_dtype).itemsize * block_rows * block_cols
    return 2 * (in_b + out_b)  # x2 for double buffering
