"""Embedding-bag (sum-pooled sparse embedding lookup) Pallas kernels.

The trainer-side hot spot of DLRM: for each sample, gather ``nnz`` rows of an
embedding table and sum-pool them.  The ETL engine feeds bounded int32 indices
(VocabMap output), and these kernels are what consume them on the training
chip.

Two levels (the BagPipe/Hotline popular-rare split, PAPERS.md):

- ``embedding_bag`` — the uncached baseline.  The table is partitioned across
  the grid (same "HBM banks" pattern as vocab.py): each grid step loads one
  table partition into VMEM and resolves the in-partition indices.  This
  turns an irregular HBM gather into P dense VMEM passes — MXU/VPU friendly
  and deterministic, at the cost of a P-fold index scan (P is small: tables
  are partitioned only when they exceed the VMEM budget).
- ``embedding_bag_cached`` — the two-level cached form fed by the lookahead
  stage (``etl_runtime/lookahead.py``).  Hot indices arrive pre-remapped to
  slots of a small ``[cache_rows, dim]`` cache tensor that stays VMEM-resident
  for the whole grid (ONE dense pass, no table traffic); cold indices fall
  through the same partitioned table pass as the uncached kernel.  When the
  lookahead plan stages every cold row into the cache for the batch
  (``cold_idx=None``), the kernel is a single cache pass and never touches
  the table at all.

Both kernels share one structure so they are **bit-identical** on the same
logical indices: a gather phase materializes the per-(sample, k) rows tile —
each entry written by exactly one pass, so no float accumulation order is
involved — and one shared ``jnp`` sum pools over ``nnz``.  ``-1`` indices are
sentinels and contribute zero (packer padding / empty bag lanes).

Block shapes are hardware-tiled: the embedding ``dim`` is lane-padded to a
128-multiple (zero lanes, sliced off before pooling), index blocks carry
``nnz`` lane-padded with ``-1`` sentinels and the kernel slices them to the
sublane-padded ``nnz`` the 3-D rows tile uses, and partition row counts are
sublane-padded (padded rows are unreachable: indices are bounded by the
vocab and masked in-kernel).  The row gathers are reshape-free 2-D-indexed
``jnp.take`` along the row axis — the gather shape Mosaic's rule accepts.

``interpret=None`` resolves through ``kernels.backend.default_interpret``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import lanes
from repro.kernels.backend import default_interpret


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _pool(rows, batch: int, nnz: int, dim: int):
    """Shared pooling epilogue: slice off batch/nnz/dim padding, sum over nnz.

    Both kernels feed identical row tiles through this exact op, which is
    what makes cached-vs-uncached equality bit-level rather than allclose.
    """
    return rows[:batch, :nnz, :dim].sum(axis=1)


def _partitioned(table, partitions: int):
    """Split the vocab across ``partitions``, zero-padding the last partition
    (and rounding each partition up to the sublane tile) so arbitrary vocab
    sizes work; the dim axis is lane-padded.  Padded rows are unreachable:
    indices are bounded by the vocab and out-of-range values are masked
    in-kernel."""
    vocab, dim = table.shape
    p = max(partitions, 1)
    part = lanes.sublane_pad(-(-vocab // p))
    dim_pad = lanes.lane_pad(dim)
    table = jnp.pad(table, ((0, part * p - vocab), (0, dim_pad - dim)))
    return table, part, p, dim_pad


def _pad_batch(idx, block_batch: int):
    """Pad the batch axis to the block multiple and the nnz axis to the
    lane tile (with -1 sentinels, which every kernel masks out)."""
    batch, nnz = idx.shape
    bb = min(block_batch, _round_up(batch, 8))
    bp = _round_up(batch, bb)
    nnz_lane = lanes.lane_pad(nnz)
    idx = jnp.pad(idx, ((0, bp - batch), (0, nnz_lane - nnz)),
                  constant_values=-1)
    return idx, bb, bp, nnz_lane


def _gather_kernel(idx_ref, tbl_ref, rows_ref, *, part_rows: int,
                   nnz_sub: int):
    """One table-partition pass: write rows for in-partition indices."""
    p = pl.program_id(1)
    lo = p * part_rows

    @pl.when(p == 0)
    def _init():
        rows_ref[...] = jnp.zeros(rows_ref.shape, rows_ref.dtype)

    idx = idx_ref[...][:, :nnz_sub]  # (bb, nnz_sub)
    local = idx - lo
    inb = (local >= 0) & (local < part_rows) & (idx >= 0)
    safe = jnp.where(inb, local, 0)
    tbl = tbl_ref[...]  # (part_rows, dim_pad)
    got = jnp.take(tbl, safe, axis=0)  # (bb, nnz_sub, dim_pad), reshape-free
    rows_ref[...] = jnp.where(inb[..., None], got, rows_ref[...])


def embedding_bag(table, indices, *, partitions: int = 1, block_batch: int = 128,
                  interpret: Optional[bool] = None):
    """out[b] = sum_k table[indices[b, k]];  indices == -1 contribute zero.

    table: [vocab, dim] float; indices: int32[batch, nnz].  ``vocab`` need
    not divide ``partitions`` — the last partition is zero-padded inside the
    wrapper.
    """
    if interpret is None:
        interpret = default_interpret()
    vocab, dim = table.shape
    batch, nnz = indices.shape
    nnz_sub = lanes.sublane_pad(nnz)
    table, part, parts, dim_pad = _partitioned(table, partitions)
    idx, bb, bp, nnz_lane = _pad_batch(indices, block_batch)

    rows = pl.pallas_call(
        functools.partial(_gather_kernel, part_rows=part, nnz_sub=nnz_sub),
        grid=(bp // bb, parts),
        in_specs=[
            pl.BlockSpec((bb, nnz_lane), lambda b, p: (b, 0)),
            pl.BlockSpec((part, dim_pad), lambda b, p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((bb, nnz_sub, dim_pad), lambda b, p: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, nnz_sub, dim_pad), table.dtype),
        interpret=interpret,
    )(idx, table)
    return _pool(rows, batch, nnz, dim)


def _cache_gather_kernel(slot_ref, cache_ref, rows_ref, *, cache_rows: int,
                         nnz_sub: int):
    """Single dense pass over the (VMEM-resident) cache: the hot path."""
    slot = slot_ref[...][:, :nnz_sub]
    inb = (slot >= 0) & (slot < cache_rows)
    safe = jnp.where(inb, slot, 0)
    cache = cache_ref[...]
    got = jnp.take(cache, safe, axis=0)
    rows_ref[...] = jnp.where(inb[..., None], got, 0)


def _two_level_kernel(slot_ref, cold_ref, cache_ref, tbl_ref, rows_ref, *,
                      part_rows: int, cache_rows: int, nnz_sub: int):
    """Grid dim 1: step 0 = cache pass, steps 1..P = table partition passes.

    Hot entries (slot >= 0) resolve from the cache and shadow any cold id;
    cold entries fall through the partitioned pass exactly like the uncached
    kernel.  Entries with neither contribute zero.
    """
    p = pl.program_id(1)

    @pl.when(p == 0)
    def _cache_pass():
        slot = slot_ref[...][:, :nnz_sub]
        inb = (slot >= 0) & (slot < cache_rows)
        safe = jnp.where(inb, slot, 0)
        cache = cache_ref[...]
        got = jnp.take(cache, safe, axis=0)
        rows_ref[...] = jnp.where(inb[..., None], got, 0)

    @pl.when(p > 0)
    def _table_pass():
        lo = (p - 1) * part_rows
        cold = cold_ref[...][:, :nnz_sub]
        local = cold - lo
        # hot entries already resolved from the cache: slot wins over cold
        inb = ((local >= 0) & (local < part_rows) & (cold >= 0)
               & (slot_ref[...][:, :nnz_sub] < 0))
        safe = jnp.where(inb, local, 0)
        tbl = tbl_ref[...]
        got = jnp.take(tbl, safe, axis=0)
        rows_ref[...] = jnp.where(inb[..., None], got, rows_ref[...])


def embedding_bag_cached(table, cache, slot_idx, cold_idx=None, *,
                         partitions: int = 1, block_batch: int = 128,
                         interpret: Optional[bool] = None):
    """Two-level cached embedding bag.

    out[b] = sum_k rows[b, k] with rows resolved per entry:

    - ``slot_idx[b, k] >= 0``: ``cache[slot_idx[b, k]]`` — ONE dense VMEM
      pass over the ``[cache_rows, dim]`` cache, no table traffic.
    - else ``cold_idx[b, k] >= 0``: ``table[cold_idx[b, k]]`` through the
      uncached kernel's partitioned pass.
    - both ``-1``: contributes zero (padding lanes).

    ``cold_idx=None`` asserts the lookahead plan staged every cold row into
    the cache (the fast path): the call lowers to the single cache pass and
    the table is never read.  When ``cache`` rows mirror the table rows the
    plan assigned them (the lookahead stage's invariant), the result is
    bit-identical to ``embedding_bag(table, original_indices)``.
    """
    if interpret is None:
        interpret = default_interpret()
    cache_rows, dim = cache.shape
    batch, nnz = slot_idx.shape
    nnz_sub = lanes.sublane_pad(nnz)
    dim_pad = lanes.lane_pad(dim)
    rows_pad = lanes.sublane_pad(cache_rows)
    cache = jnp.pad(cache, ((0, rows_pad - cache_rows), (0, dim_pad - dim)))
    slot, bb, bp, nnz_lane = _pad_batch(slot_idx, block_batch)

    if cold_idx is None:
        rows = pl.pallas_call(
            functools.partial(_cache_gather_kernel, cache_rows=cache_rows,
                              nnz_sub=nnz_sub),
            grid=(bp // bb,),
            in_specs=[
                pl.BlockSpec((bb, nnz_lane), lambda b: (b, 0)),
                pl.BlockSpec((rows_pad, dim_pad), lambda b: (0, 0)),
            ],
            out_specs=pl.BlockSpec((bb, nnz_sub, dim_pad), lambda b: (b, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((bp, nnz_sub, dim_pad), cache.dtype),
            interpret=interpret,
        )(slot, cache)
        return _pool(rows, batch, nnz, dim)

    table, part, parts, _ = _partitioned(table, partitions)
    cold, _, _, _ = _pad_batch(cold_idx, block_batch)
    rows = pl.pallas_call(
        functools.partial(_two_level_kernel, part_rows=part,
                          cache_rows=cache_rows, nnz_sub=nnz_sub),
        grid=(bp // bb, parts + 1),
        in_specs=[
            pl.BlockSpec((bb, nnz_lane), lambda b, p: (b, 0)),
            pl.BlockSpec((bb, nnz_lane), lambda b, p: (b, 0)),
            pl.BlockSpec((rows_pad, dim_pad), lambda b, p: (0, 0)),
            pl.BlockSpec((part, dim_pad),
                         lambda b, p: (jnp.maximum(p - 1, 0), 0)),
        ],
        out_specs=pl.BlockSpec((bb, nnz_sub, dim_pad), lambda b, p: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, nnz_sub, dim_pad), cache.dtype),
        interpret=interpret,
    )(slot, cold, cache, table)
    return _pool(rows, batch, nnz, dim)
