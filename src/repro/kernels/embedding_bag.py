"""Embedding-bag (sum-pooled sparse embedding lookup) Pallas kernel.

The trainer-side hot spot of DLRM: for each sample, gather ``nnz`` rows of an
embedding table and sum-pool them.  The ETL engine feeds bounded int32 indices
(VocabMap output), and this kernel is what consumes them on the training chip.

TPU adaptation: the table is partitioned across the grid (same "HBM banks"
pattern as vocab.py).  Each grid step loads one table partition into VMEM and
accumulates partial pools for in-partition indices; misses contribute zero.
This turns an irregular HBM gather into P dense VMEM passes — MXU/VPU friendly
and deterministic, at the cost of a P-fold index scan (P is small: tables are
partitioned only when they exceed the VMEM budget).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _bag_kernel(idx_ref, tbl_ref, o_ref, *, part_rows: int):
    p = pl.program_id(1)
    lo = p * part_rows

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    idx = idx_ref[...]  # (bb, nnz)
    local = idx - lo
    inb = (local >= 0) & (local < part_rows)
    safe = jnp.where(inb, local, 0)
    tbl = tbl_ref[...]  # (part_rows, dim)
    rows = jnp.take(tbl, safe.reshape(-1), axis=0)
    rows = rows.reshape(idx.shape + (tbl.shape[-1],))
    rows = jnp.where(inb[..., None], rows, 0)
    o_ref[...] += rows.sum(axis=1).astype(o_ref.dtype)


def embedding_bag(table, indices, *, partitions: int = 1, block_batch: int = 128,
                  interpret: bool = True):
    """out[b] = sum_k table[indices[b, k]].

    table: [vocab, dim] float; indices: int32[batch, nnz].
    """
    vocab, dim = table.shape
    batch, nnz = indices.shape
    if vocab % max(partitions, 1):
        raise ValueError("vocab must divide evenly into partitions")
    part = vocab // partitions
    bb = min(block_batch, _round_up(batch, 8))
    bp = _round_up(batch, bb)
    idx = jnp.pad(indices, ((0, bp - batch), (0, 0)), constant_values=-1)

    out = pl.pallas_call(
        functools.partial(_bag_kernel, part_rows=part),
        grid=(bp // bb, partitions),
        in_specs=[
            pl.BlockSpec((bb, nnz), lambda b, p: (b, 0)),
            pl.BlockSpec((part, dim), lambda b, p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((bb, dim), lambda b, p: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, dim), table.dtype),
        interpret=interpret,
    )(idx, table)
    return out[:batch]
