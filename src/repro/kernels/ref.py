"""Pure-jnp oracles for every Pallas kernel in this package.

These define the ground-truth semantics; tests sweep shapes/dtypes and assert
allclose between each kernel (interpret=True on CPU) and these references.
"""

from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# fused stateless stage
# ---------------------------------------------------------------------------

def fused_chain(x, chain_fn):
    """Apply a code-generated elementwise chain to a whole block at once."""
    return chain_fn(x)


def hex2int_digit_major(x):
    """uint8[w, ...] ASCII-hex digit planes -> int32[...] (two's complement).

    All-zero strings (missing) map to operators.INT_MISSING.
    """
    w = x.shape[0]
    missing = jnp.all(x == 0, axis=0)
    c = jnp.where(x == 0, jnp.uint8(48), x).astype(jnp.int32)
    dig = jnp.where(c >= 97, c - 87, jnp.where(c >= 65, c - 55, c - 48))
    dig = dig.astype(jnp.uint32)
    val = jnp.zeros(x.shape[1:], jnp.uint32)
    for i in range(w):
        val = (val << jnp.uint32(4)) | dig[i]
    out = val.astype(jnp.int32)
    return jnp.where(missing, jnp.int32(-(2 ** 31)), out)


# ---------------------------------------------------------------------------
# vocabulary build / lookup
# ---------------------------------------------------------------------------

def vocab_build_chunk(values, capacity):
    """First-occurrence position of each value within one chunk.

    values: int32[n] in [0, capacity). Returns int32[capacity], with
    2**31 - 1 marking "absent in this chunk".
    """
    n = values.shape[0]
    init = jnp.full((capacity,), jnp.int32(2 ** 31 - 1))
    pos = jnp.arange(n, dtype=jnp.int32)
    return init.at[values].min(pos)


ABSENT32 = 2 ** 31 - 1


def vocab_state_init(capacity):
    """Global fit state: (first_chunk, pos_in_chunk, counts), all int32.

    Positions are 64-bit in spirit but TPU/Pallas has no int64; the stream is
    processed in monotonically increasing chunks, so (chunk_idx, pos32) orders
    identically to a global 64-bit position.  counts back the paper's
    frequency-based filtering (§3.2.2).
    """
    return (jnp.full((capacity,), ABSENT32, jnp.int32),
            jnp.full((capacity,), ABSENT32, jnp.int32),
            jnp.zeros((capacity,), jnp.int32))


def vocab_counts_chunk(values, capacity):
    """Occurrence counts of one chunk (int32[capacity])."""
    return jnp.bincount(values, length=capacity).astype(jnp.int32)


def vocab_merge(state, chunk_first_pos, chunk_idx, chunk_counts=None):
    """Merge one chunk's first-pos (+counts). Chunks MUST arrive in
    increasing order, so a value seen before keeps its record; only absent
    slots are filled."""
    first_chunk, pos, counts = state
    newly = (first_chunk == ABSENT32) & (chunk_first_pos != ABSENT32)
    first_chunk = jnp.where(newly, jnp.int32(chunk_idx), first_chunk)
    pos = jnp.where(newly, chunk_first_pos, pos)
    if chunk_counts is not None:
        counts = counts + chunk_counts
    return first_chunk, pos, counts


def vocab_finalize(state, min_count: int = 1):
    """(first_chunk, pos, counts) -> int32 rank table (-1 = absent/filtered).

    min_count > 1 drops rare values (frequency filter): they rank as absent
    and map to the OOV index at apply time."""
    first_chunk, pos, counts = state
    capacity = first_chunk.shape[0]
    present = first_chunk != ABSENT32
    if min_count > 1:  # frequency filter is opt-in; counts optional otherwise
        present = present & (counts >= min_count)
    key_chunk = jnp.where(present, first_chunk, ABSENT32)
    order = jnp.lexsort((pos, key_chunk))  # chunk major, pos minor
    rank = jnp.zeros(capacity, jnp.int32).at[order].set(
        jnp.arange(capacity, dtype=jnp.int32))
    return jnp.where(present, rank, -1).astype(jnp.int32)


def vocab_lookup(x, table, n_unique):
    """Map x through table; absent (-1) entries map to the OOV index n_unique."""
    hit = table[x]
    return jnp.where(hit >= 0, hit, n_unique).astype(jnp.int32)


# ---------------------------------------------------------------------------
# format-aware packer
# ---------------------------------------------------------------------------

def pack_blocks(blocks, out_dtype, pad_cols_to=1):
    """Concat column blocks along axis 1, cast, pad width to a multiple.

    blocks: list of [rows, c_i] arrays. Output [rows, padded(sum c_i)].
    """
    rows = blocks[0].shape[0]
    cat = jnp.concatenate([b.astype(out_dtype) for b in blocks], axis=1)
    total = cat.shape[1]
    padded = -(-total // pad_cols_to) * pad_cols_to
    if padded != total:
        cat = jnp.pad(cat, ((0, 0), (0, padded - total)))
    assert cat.shape == (rows, padded)
    return cat


# ---------------------------------------------------------------------------
# embedding bag (DLRM trainer-side hot spot)
# ---------------------------------------------------------------------------

def embedding_bag(table, indices, weights=None):
    """Sum-pool embedding rows: out[b] = sum_k w[b,k] * table[idx[b,k]].

    table: [vocab, dim]; indices: int32[batch, nnz]; weights: [batch, nnz] or
    None.  ``-1`` indices are sentinels (padding lanes) and contribute zero.
    """
    valid = indices >= 0
    rows = table[jnp.where(valid, indices, 0)]  # [batch, nnz, dim]
    rows = jnp.where(valid[..., None], rows, 0)
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    return rows.sum(axis=1)


def embedding_bag_cached(table, cache, slot_idx, cold_idx=None):
    """Two-level oracle: hot entries (slot >= 0) read ``cache[slot]``, cold
    entries read ``table[cold]``, double-blank entries contribute zero."""
    hot = slot_idx >= 0
    rows = jnp.where(hot[..., None], cache[jnp.where(hot, slot_idx, 0)], 0)
    if cold_idx is not None:
        cold_ok = (~hot) & (cold_idx >= 0)
        rows = jnp.where(cold_ok[..., None],
                         table[jnp.where(cold_ok, cold_idx, 0)], rows)
    return rows.sum(axis=1)


def embedding_bag_grad_table(table_shape, indices, grad_out, weights=None):
    """Gradient of embedding_bag wrt table (scatter-add)."""
    vocab, dim = table_shape
    batch, nnz = indices.shape
    g = jnp.broadcast_to(grad_out[:, None, :], (batch, nnz, dim))
    if weights is not None:
        g = g * weights[..., None].astype(g.dtype)
    flat_idx = indices.reshape(-1)
    flat_g = g.reshape(-1, dim)
    return jnp.zeros((vocab, dim), grad_out.dtype).at[flat_idx].add(flat_g)
