"""Streaming Pallas dataflow kernels (paper §3: the full FPGA pipeline).

This module is the kernel-side half of plan-level fusion.  It hosts the
factories, in increasing order of fusion:

``make_fused_stage``
    One chain of stateless operators as one streaming kernel (Stage-A).
    Used by the stage-at-a-time fallback path.

``make_packer``
    The format-aware packer as its own kernel (fallback epilogue): column
    blocks are concatenated along lanes, cast to the trainer dtype, and the
    width padded to the layout ``train_step`` declares.

``make_output_dataflow``
    The whole backward slice of one ``PackOutput`` as ONE row-tiled kernel —
    the TPU statement of the paper's streaming dataflow.  Per grid step, a
    row block of every raw source streams into VMEM, the fused elementwise
    chains / hex decode / vocab rank-lookup / one-hot expansion execute
    per-tile as ``TileStep``s of a single kernel body, and every terminal
    buffer is stored at its static lane offset of the packed output block.
    Intermediates live only in VMEM registers — no HBM tensor ever
    materializes between operators, and the separate packer pass disappears
    (packing is the kernel's epilogue).  Each byte of the stream crosses
    HBM exactly twice: raw in, packed out.

``make_group_dataflow``
    The merged backward slice of SEVERAL ``PackOutput``s (a planner
    ``DataflowGroup``) as ONE row-tiled kernel with one packed output block
    per member.  The shared ``TileStep`` program runs once per tile; each
    member's packer epilogue reads its terminals from the same VMEM tile
    environment — the optimizer's cross-output CSE, realized in-kernel.

``make_fit_dataflow``
    The fit-phase sibling: the backward slice of one ``VocabFit`` — decode,
    bounding chains, joins — plus the chunk first-occurrence + count build
    as ONE row-tiled kernel.  The two int32 accumulators are the kernel
    outputs, partitioned across grid dim 0 (the paper's "P HBM banks",
    same structure as ``kernels/vocab.py``) and revisited by every row
    tile of grid dim 1.  In interpret mode each partition builds with
    whole-tile masked scatters (``.at[].min`` / ``.at[].add``); in
    compiled mode — where scatter does not lower — the same masks guard a
    RAW-serialized per-row update loop mirroring the staged build kernel
    (dynamic scalar stores into the partition block, the paper's
    RAW-limited II).  Both forms fold identical (position, count)
    contributions with order-independent combiners (min / add), so the
    modes are bit-identical by construction and the compiled-parity suite
    pins it wherever a compiled backend exists.

Vocabulary tables enter the dataflow kernel pre-resolved: the compiler folds
the OOV rule (``miss -> n_unique``) into the table before the call, so the
in-kernel lookup is a pure banked lane gather (``kernels.lanes.lane_gather``
— no flat reshape, no whole-table broadcast).

Tiling: every memory block is lane-aligned — source, table, and packed
output blocks are padded up to multiples of 128 lanes host-side (padding
lanes carry zeros and are sliced off in-kernel / on return), block rows are
multiples of 8 sublanes, and the grid streams row blocks — the paper's
batch-of-rows FIFO granularity, in the shape Mosaic actually tiles.

``interpret=None`` on every factory resolves through
``kernels.backend.default_interpret`` (compiled wherever a Mosaic/Triton
target exists, interpret otherwise); passing an explicit bool pins the mode.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import lanes
from repro.kernels.backend import default_interpret


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _resolve_interpret(interpret) -> bool:
    return default_interpret() if interpret is None else bool(interpret)


# ---------------------------------------------------------------------------
# Stage-A: one fused stateless chain as one kernel (fallback path)
# ---------------------------------------------------------------------------

def make_fused_stage(chain_fn, *, in_dtype, out_dtype, hex_width: int = 0,
                     block_rows: int = 256, block_cols: int = 512,
                     interpret: Optional[bool] = None):
    """Build a jit-compatible fn: x -> fused(x).

    chain_fn: elementwise block function. For hex inputs it receives the
    (w, br, bc) uint8 block and must fold the leading digit axis itself.
    """
    interpret = _resolve_interpret(interpret)

    def kernel(x_ref, o_ref):
        o_ref[...] = chain_fn(x_ref[...]).astype(o_ref.dtype)

    @functools.partial(jax.jit, static_argnames=())
    def run(x):
        if hex_width:
            w, rows, cols = x.shape
            assert w == hex_width, (x.shape, hex_width)
        else:
            rows, cols = x.shape
        br = min(block_rows, _round_up(rows, 8))
        bc = min(_round_up(block_cols, 128), lanes.lane_pad(cols))
        rp, cp = _round_up(rows, br), _round_up(cols, bc)
        # pad to block multiples (padding lanes carry zeros; sliced off below)
        if hex_width:
            xp = jnp.pad(x, ((0, 0), (0, rp - rows), (0, cp - cols)))
            in_spec = pl.BlockSpec((hex_width, br, bc), lambda i, j: (0, i, j))
        else:
            xp = jnp.pad(x, ((0, rp - rows), (0, cp - cols)))
            in_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
        grid = (rp // br, cp // bc)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[in_spec],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((rp, cp), out_dtype),
            interpret=interpret,
        )(xp)
        return out[:rows, :cols]

    return run


def vmem_bytes_estimate(in_dtype, out_dtype, hex_width: int,
                        block_rows: int, block_cols: int) -> int:
    """Planner helper: VMEM working set claimed by one grid step."""
    in_b = np.dtype(in_dtype).itemsize * block_rows * block_cols * (hex_width or 1)
    out_b = np.dtype(out_dtype).itemsize * block_rows * block_cols
    return 2 * (in_b + out_b)  # x2 for double buffering


# ---------------------------------------------------------------------------
# Format-aware packer as its own kernel (fallback epilogue)
# ---------------------------------------------------------------------------

def make_packer(col_widths, in_dtypes, out_dtype, *, pad_cols_to: int = 128,
                block_rows: int = 256, interpret: Optional[bool] = None):
    """Build fn(blocks...) -> packed [rows, padded(sum(col_widths))].

    Column blocks and the packed block are lane-padded to 128-multiples for
    the kernel; the logical ``pad_cols_to`` layout width is sliced back out
    on return.
    """
    interpret = _resolve_interpret(interpret)
    col_widths = [int(w) for w in col_widths]
    total = sum(col_widths)
    padded = _round_up(total, pad_cols_to)
    lane_padded = lanes.lane_pad(padded)
    lane_widths = [lanes.lane_pad(w) for w in col_widths]
    offsets = np.cumsum([0] + col_widths).tolist()

    def kernel(*refs):
        o_ref = refs[-1]
        o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)
        for k, x_ref in enumerate(refs[:-1]):
            x = x_ref[...][:, :col_widths[k]]
            o_ref[:, offsets[k]:offsets[k + 1]] = x.astype(o_ref.dtype)

    def run(*blocks):
        assert len(blocks) == len(col_widths)
        rows = blocks[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_blocks = [
            jnp.pad(b, ((0, rp - rows), (0, lw - b.shape[1])))
            for b, lw in zip(blocks, lane_widths)]
        out = pl.pallas_call(
            kernel,
            grid=(rp // br,),
            in_specs=[pl.BlockSpec((br, lw), lambda r: (r, 0))
                      for lw in lane_widths],
            out_specs=pl.BlockSpec((br, lane_padded), lambda r: (r, 0)),
            out_shape=jax.ShapeDtypeStruct((rp, lane_padded), out_dtype),
            interpret=interpret,
        )(*padded_blocks)
        return out[:rows, :padded]

    return run


# ---------------------------------------------------------------------------
# The fused per-output streaming dataflow kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamInput:
    """One raw column block streamed through the kernel, row-tiled."""

    name: str
    width: int
    dtype: np.dtype
    hex_width: int = 0  # > 0: digit-major uint8[hex_width, rows, width]


@dataclasses.dataclass(frozen=True)
class TableInput:
    """One frozen, OOV-resolved vocab table staged whole per grid step."""

    vocab_id: str
    capacity: int


@dataclasses.dataclass(frozen=True)
class TileStep:
    """One operator application inside the kernel body.

    kind:
      "map"    — unary per-tile fn (fused elementwise chain, hex fold,
                 one-hot expansion); ``fn(tile) -> tile``.
      "join"   — binary per-tile fn (Cartesian cross); ``fn(a, b) -> tile``.
      "lookup" — gather through ``tables[table]`` (rank lookup; the OOV rule
                 is pre-folded into the table, so a miss gathers n_unique).
    """

    kind: str
    out: str
    args: tuple
    fn: Optional[Callable] = None
    table: int = -1


def _row_tile_sources(inputs, srcs, br: int, rp: int,
                      partitioned: bool = False):
    """Pad each raw source to the row-tile multiple and a lane-multiple
    width, and emit its BlockSpec (hex sources are digit-major 3-d; the
    digit axis is not tiled).  The kernel slices each tile back to its
    natural width, so padding lanes never enter the step program.

    ``partitioned`` emits index maps for the fit kernel's 2-d grid
    ``(partitions, row_tiles)``: every partition re-streams all row tiles.
    """
    rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
    padded_srcs, in_specs = [], []
    for inp, x in zip(inputs, srcs):
        wp = lanes.lane_pad(inp.width)
        if inp.hex_width:
            padded_srcs.append(
                jnp.pad(x, ((0, 0), (0, rp - rows), (0, wp - inp.width))))
            imap = ((lambda p, r: (0, r, 0)) if partitioned
                    else (lambda r: (0, r, 0)))
            in_specs.append(pl.BlockSpec((inp.hex_width, br, wp), imap))
        else:
            padded_srcs.append(
                jnp.pad(x, ((0, rp - rows), (0, wp - inp.width))))
            imap = ((lambda p, r: (r, 0)) if partitioned
                    else (lambda r: (r, 0)))
            in_specs.append(pl.BlockSpec((br, wp), imap))
    return padded_srcs, in_specs


def _load_source_env(inputs, src_refs) -> dict:
    """Read each lane-padded source tile and slice to its natural width."""
    env = {}
    for inp, r in zip(inputs, src_refs):
        env[inp.name] = r[...][..., :inp.width]
    return env


def _pad_tables(tables, tbls):
    """Lane-pad each (1, capacity) resolved table and emit its BlockSpec."""
    padded, specs = [], []
    for t, a in zip(tables, tbls):
        assert a.shape == (1, t.capacity), (a.shape, t.capacity)
        cp = lanes.lane_pad(t.capacity)
        padded.append(jnp.pad(a, ((0, 0), (0, cp - t.capacity))))
        specs.append(pl.BlockSpec((1, cp), lambda r: (0, 0)))
    return padded, specs


def _run_tile_steps(env: dict, steps, tbl_refs, capacities):
    """Execute the TileStep program over VMEM-resident tiles in ``env``."""
    for st in steps:
        if st.kind == "map":
            env[st.out] = st.fn(env[st.args[0]])
        elif st.kind == "join":
            env[st.out] = st.fn(env[st.args[0]], env[st.args[1]])
        elif st.kind == "lookup":
            tbl = tbl_refs[st.table][...]  # (1, lane_pad(capacity)), resolved
            x = env[st.args[0]]
            safe = jnp.clip(x, 0, capacities[st.table] - 1)
            env[st.out] = lanes.lane_gather(tbl, safe)
        else:
            raise NotImplementedError(st.kind)


def make_output_dataflow(inputs: Sequence[StreamInput],
                         tables: Sequence[TableInput],
                         steps: Sequence[TileStep],
                         terminals: Sequence[tuple],
                         out_dtype, *, pad_cols_to: int = 1,
                         block_rows: int = 256,
                         interpret: Optional[bool] = None):
    """Build fn(*sources, *tables) -> packed [rows, padded(sum widths)].

    ``terminals`` is the ordered list of ``(buffer_name, width)`` pairs the
    packer epilogue writes; names refer to stream inputs or step outputs.
    The returned callable issues exactly ONE ``pallas_call``.
    """
    interpret = _resolve_interpret(interpret)
    inputs = list(inputs)
    tables = list(tables)
    steps = list(steps)
    terminals = [(str(n), int(w)) for n, w in terminals]
    total = sum(w for _, w in terminals)
    padded = _round_up(max(total, 1), max(pad_cols_to, 1))
    lane_padded = lanes.lane_pad(padded)
    offsets = np.cumsum([0] + [w for _, w in terminals]).tolist()
    capacities = [t.capacity for t in tables]
    n_src = len(inputs)

    def kernel(*refs):
        src_refs, tbl_refs, o_ref = refs[:n_src], refs[n_src:-1], refs[-1]
        env = _load_source_env(inputs, src_refs)
        _run_tile_steps(env, steps, tbl_refs, capacities)
        o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)
        for (name, w), off in zip(terminals, offsets):
            o_ref[:, off:off + w] = env[name].astype(o_ref.dtype)

    def run(*arrays):
        assert len(arrays) == n_src + len(tables), (len(arrays), n_src)
        srcs, tbls = arrays[:n_src], arrays[n_src:]
        rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_srcs, in_specs = _row_tile_sources(inputs, srcs, br, rp)
        padded_tbls, tbl_specs = _pad_tables(tables, tbls)
        out = pl.pallas_call(
            kernel,
            grid=(rp // br,),
            in_specs=in_specs + tbl_specs,
            out_specs=pl.BlockSpec((br, lane_padded), lambda r: (r, 0)),
            out_shape=jax.ShapeDtypeStruct((rp, lane_padded), out_dtype),
            interpret=interpret,
        )(*padded_srcs, *padded_tbls)
        return out[:rows, :padded]

    return run


# ---------------------------------------------------------------------------
# The multi-output fused streaming dataflow kernel (DataflowGroup lowering)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupOutput:
    """The packer epilogue of one member of a ``DataflowGroup``."""

    name: str
    terminals: tuple  # ((buffer_name, width), ...) in pack order
    out_dtype: np.dtype
    pad_cols_to: int = 1


def make_group_dataflow(inputs: Sequence[StreamInput],
                        tables: Sequence[TableInput],
                        steps: Sequence[TileStep],
                        outputs: Sequence[GroupOutput], *,
                        block_rows: int = 256,
                        interpret: Optional[bool] = None):
    """Build fn(*sources, *tables) -> tuple of packed arrays, one per output.

    The grouped form of ``make_output_dataflow``: the merged backward slice
    of SEVERAL ``PackOutput``s runs as ONE row-tiled ``pallas_call``.  Per
    grid step the shared ``TileStep`` program executes exactly once over the
    union tile environment, then each member output's packer epilogue reads
    its terminals from that one environment and stores them at static lane
    offsets of its own packed block — stages shared across outputs are
    computed once per tile instead of once per output.
    """
    interpret = _resolve_interpret(interpret)
    inputs = list(inputs)
    tables = list(tables)
    steps = list(steps)
    outputs = list(outputs)
    capacities = [t.capacity for t in tables]
    n_src = len(inputs)
    n_out = len(outputs)
    paddeds, lane_paddeds, offsets_per_out = [], [], []
    for g in outputs:
        widths = [int(w) for _, w in g.terminals]
        padded = _round_up(max(sum(widths), 1), max(g.pad_cols_to, 1))
        paddeds.append(padded)
        lane_paddeds.append(lanes.lane_pad(padded))
        offsets_per_out.append(np.cumsum([0] + widths).tolist())

    def kernel(*refs):
        src_refs = refs[:n_src]
        tbl_refs = refs[n_src:-n_out]
        out_refs = refs[-n_out:]
        env = _load_source_env(inputs, src_refs)
        _run_tile_steps(env, steps, tbl_refs, capacities)
        for g, o_ref, offs in zip(outputs, out_refs, offsets_per_out):
            o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)
            for (name, w), off in zip(g.terminals, offs):
                o_ref[:, off:off + w] = env[name].astype(o_ref.dtype)

    def run(*arrays):
        assert len(arrays) == n_src + len(tables), (len(arrays), n_src)
        srcs, tbls = arrays[:n_src], arrays[n_src:]
        rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_srcs, in_specs = _row_tile_sources(inputs, srcs, br, rp)
        padded_tbls, tbl_specs = _pad_tables(tables, tbls)
        outs = pl.pallas_call(
            kernel,
            grid=(rp // br,),
            in_specs=in_specs + tbl_specs,
            out_specs=[pl.BlockSpec((br, lp), lambda r: (r, 0))
                       for lp in lane_paddeds],
            out_shape=[jax.ShapeDtypeStruct((rp, lp), g.out_dtype)
                       for g, lp in zip(outputs, lane_paddeds)],
            interpret=interpret,
        )(*padded_srcs, *padded_tbls)
        return tuple(o[:rows, :p] for o, p in zip(outs, paddeds))

    return run


# ---------------------------------------------------------------------------
# The fused per-vocab streaming *fit* kernel
# ---------------------------------------------------------------------------

ABSENT32 = 2 ** 31 - 1  # matches kernels.vocab / kernels.ref chunk sentinel


def make_fit_dataflow(inputs: Sequence[StreamInput],
                      steps: Sequence[TileStep],
                      value_buf: str, capacity: int, *,
                      partitions: int = 1, block_rows: int = 256,
                      interpret: Optional[bool] = None,
                      build_form: str = "auto"):
    """Build fn(*sources) -> (first_pos int32[capacity], counts int32[capacity]).

    One ``pallas_call`` over grid ``(partitions, row_tiles)``: row tiles of
    every raw source stream through the ``TileStep`` chain (map/join only —
    lookups cannot precede a fit), and each table partition accumulates the
    chunk first-occurrence positions and occurrence counts of its value
    range into a lane-padded VMEM block revisited by every row tile (the
    paper's "P HBM banks"; partitions re-scan the stream in parallel, the
    P-fold pass ``kernels/vocab.py`` and ``embedding_bag`` already use).
    Semantics match the staged path exactly: positions are global row-major
    flat offsets over the unpadded chunk, ``ABSENT32`` marks values absent
    from the chunk, counts sum every occurrence (the frequency-filter
    input), and negative / out-of-capacity values drop.

    The per-partition update has two Mosaic-equivalent forms selected by
    the resolved ``interpret`` flag: whole-tile masked scatters
    (``.at[].min`` / ``.at[].add``) in interpret mode, and the staged build
    kernel's RAW-serialized scalar-store loop in compiled mode (scatter
    does not lower under Mosaic).  Both fold identical contributions with
    order-independent combiners, so the outputs are bit-identical; the
    compiled-parity suite pins this on hardware, and ``build_form`` lets
    CPU tests pin it too: "auto" selects by the resolved interpret flag,
    "scatter" / "serial" force one form (the serial form also runs under
    interpret mode, where both forms must agree bit-for-bit).
    """
    if build_form not in ("auto", "scatter", "serial"):
        raise ValueError(f"unknown build_form {build_form!r}")
    inputs = list(inputs)
    steps = list(steps)
    interpret = _resolve_interpret(interpret)
    serial_build = (build_form == "serial"
                    or (build_form == "auto" and not interpret))
    n_src = len(inputs)
    partitions = max(int(partitions), 1)
    part = -(-capacity // partitions)       # logical values per partition
    part_pad = lanes.lane_pad(part)         # lane-padded block width

    def kernel(*refs, n_rows: int):
        src_refs, fp_ref, cnt_ref = refs[:n_src], refs[-2], refs[-1]
        p = pl.program_id(0)
        lo = p * part

        @pl.when(pl.program_id(1) == 0)
        def _init():
            fp_ref[...] = jnp.full(fp_ref.shape, ABSENT32, fp_ref.dtype)
            cnt_ref[...] = jnp.zeros(cnt_ref.shape, cnt_ref.dtype)

        env = _load_source_env(inputs, src_refs)
        for st in steps:
            if st.kind == "map":
                env[st.out] = st.fn(env[st.args[0]])
            elif st.kind == "join":
                env[st.out] = st.fn(env[st.args[0]], env[st.args[1]])
            else:  # pragma: no cover - legality pass rejects lookups
                raise NotImplementedError(st.kind)
        vals = env[value_buf]
        br, width = vals.shape
        row0 = pl.program_id(1) * br

        if not serial_build:
            # whole-tile masked scatter into this partition's block
            row = row0 + jax.lax.broadcasted_iota(jnp.int32, vals.shape, 0)
            col = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
            local = vals - lo
            ok = ((row < n_rows) & (vals >= 0) & (vals < capacity)
                  & (local >= 0) & (local < part))
            pos = jnp.where(ok, row * width + col, ABSENT32).reshape(-1)
            idx = jnp.where(ok, local, 0).reshape(-1)  # masked -> no-ops
            one = jnp.where(ok, 1, 0).astype(jnp.int32).reshape(-1)
            fp_ref[...] = fp_ref[...].at[0, idx].min(pos)
            cnt_ref[...] = cnt_ref[...].at[0, idx].add(one)
        else:
            # Mosaic-legal form: serial per-row scan with dynamic scalar
            # stores (the staged vocab build's RAW-serialized II); min/add
            # are order-independent, so this folds the exact same values
            def body(r, _):
                gr = row0 + r
                for c in range(width):  # static lane offset per column
                    v = vals[r, c]
                    local = v - lo

                    @pl.when((gr < n_rows) & (v >= 0) & (v < capacity)
                             & (local >= 0) & (local < part))
                    def _upd(local=local, pos=gr * width + c):
                        fp_ref[0, local] = jnp.minimum(fp_ref[0, local], pos)
                        cnt_ref[0, local] = cnt_ref[0, local] + 1

                return 0

            jax.lax.fori_loop(0, br, body, 0)

    def run(*srcs):
        assert len(srcs) == n_src, (len(srcs), n_src)
        rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_srcs, in_specs = _row_tile_sources(
            inputs, srcs, br, rp, partitioned=True)
        fp, cnt = pl.pallas_call(
            functools.partial(kernel, n_rows=rows),
            grid=(partitions, rp // br),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((1, part_pad), lambda p, r: (0, p)),
                       pl.BlockSpec((1, part_pad), lambda p, r: (0, p))],
            out_shape=[
                jax.ShapeDtypeStruct((1, partitions * part_pad), jnp.int32),
                jax.ShapeDtypeStruct((1, partitions * part_pad), jnp.int32)],
            interpret=interpret,
        )(*padded_srcs)
        # un-interleave the lane padding: block p holds logical values
        # [p*part, (p+1)*part) in its first ``part`` lanes
        def unpad(t):
            t = t.reshape(partitions, part_pad)[:, :part].reshape(-1)
            return t[:capacity]
        return unpad(fp), unpad(cnt)

    return run
