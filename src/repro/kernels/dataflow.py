"""Streaming Pallas dataflow kernels (paper §3: the full FPGA pipeline).

This module is the kernel-side half of plan-level fusion.  It hosts three
factories, in increasing order of fusion:

``make_fused_stage``
    One chain of stateless operators as one streaming kernel (Stage-A).
    Used by the stage-at-a-time fallback path.

``make_packer``
    The format-aware packer as its own kernel (fallback epilogue): column
    blocks are concatenated along lanes, cast to the trainer dtype, and the
    width padded to the layout ``train_step`` declares.

``make_output_dataflow``
    The whole backward slice of one ``PackOutput`` as ONE row-tiled kernel —
    the TPU statement of the paper's streaming dataflow.  Per grid step, a
    row block of every raw source streams into VMEM, the fused elementwise
    chains / hex decode / vocab rank-lookup / one-hot expansion execute
    per-tile as ``TileStep``s of a single kernel body, and every terminal
    buffer is stored at its static lane offset of the packed output block.
    Intermediates live only in VMEM registers — no HBM tensor ever
    materializes between operators, and the separate packer pass disappears
    (packing is the kernel's epilogue).  Each byte of the stream crosses
    HBM exactly twice: raw in, packed out.

``make_group_dataflow``
    The merged backward slice of SEVERAL ``PackOutput``s (a planner
    ``DataflowGroup``) as ONE row-tiled kernel with one packed output block
    per member.  The shared ``TileStep`` program runs once per tile; each
    member's packer epilogue reads its terminals from the same VMEM tile
    environment — the optimizer's cross-output CSE, realized in-kernel.

``make_fit_dataflow``
    The fit-phase sibling: the backward slice of one ``VocabFit`` — decode,
    bounding chains, joins — plus the chunk first-occurrence + count build
    as ONE row-tiled kernel.  The two int32[capacity] accumulators are the
    kernel outputs, revisited by every grid step (the paper's VocabGen keyed
    reduction as a grid-carried VMEM table); value tiles never round-trip to
    HBM between the upstream chains and the build.  The scatter form
    (``.at[].min`` / ``.at[].add``) replaces the staged build kernel's
    RAW-serialized loop — the whole tile updates per step.

Vocabulary tables enter the dataflow kernel pre-resolved: the compiler folds
the OOV rule (``miss -> n_unique``) into the table before the call, so the
in-kernel lookup is a pure partitionable gather.

Tiling: block columns are the natural buffer widths (the packer already
handles sub-128 lanes); block rows are multiples of 8 (sublanes); the grid
streams row blocks — the paper's batch-of-rows FIFO granularity.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


# ---------------------------------------------------------------------------
# Stage-A: one fused stateless chain as one kernel (fallback path)
# ---------------------------------------------------------------------------

def make_fused_stage(chain_fn, *, in_dtype, out_dtype, hex_width: int = 0,
                     block_rows: int = 256, block_cols: int = 512,
                     interpret: bool = True):
    """Build a jit-compatible fn: x -> fused(x).

    chain_fn: elementwise block function. For hex inputs it receives the
    (w, br, bc) uint8 block and must fold the leading digit axis itself.
    """

    def kernel(x_ref, o_ref):
        o_ref[...] = chain_fn(x_ref[...]).astype(o_ref.dtype)

    @functools.partial(jax.jit, static_argnames=())
    def run(x):
        if hex_width:
            w, rows, cols = x.shape
            assert w == hex_width, (x.shape, hex_width)
        else:
            rows, cols = x.shape
        br = min(block_rows, _round_up(rows, 8))
        bc = min(block_cols, _round_up(cols, 128))
        rp, cp = _round_up(rows, br), _round_up(cols, bc)
        # pad to block multiples (padding lanes carry zeros; sliced off below)
        if hex_width:
            xp = jnp.pad(x, ((0, 0), (0, rp - rows), (0, cp - cols)))
            in_spec = pl.BlockSpec((hex_width, br, bc), lambda i, j: (0, i, j))
        else:
            xp = jnp.pad(x, ((0, rp - rows), (0, cp - cols)))
            in_spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
        grid = (rp // br, cp // bc)
        out = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[in_spec],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((rp, cp), out_dtype),
            interpret=interpret,
        )(xp)
        return out[:rows, :cols]

    return run


def vmem_bytes_estimate(in_dtype, out_dtype, hex_width: int,
                        block_rows: int, block_cols: int) -> int:
    """Planner helper: VMEM working set claimed by one grid step."""
    in_b = np.dtype(in_dtype).itemsize * block_rows * block_cols * (hex_width or 1)
    out_b = np.dtype(out_dtype).itemsize * block_rows * block_cols
    return 2 * (in_b + out_b)  # x2 for double buffering


# ---------------------------------------------------------------------------
# Format-aware packer as its own kernel (fallback epilogue)
# ---------------------------------------------------------------------------

def make_packer(col_widths, in_dtypes, out_dtype, *, pad_cols_to: int = 128,
                block_rows: int = 256, interpret: bool = True):
    """Build fn(blocks...) -> packed [rows, padded(sum(col_widths))]."""
    col_widths = [int(w) for w in col_widths]
    total = sum(col_widths)
    padded = _round_up(total, pad_cols_to)
    offsets = np.cumsum([0] + col_widths).tolist()

    def kernel(*refs):
        o_ref = refs[-1]
        o_ref[...] = jnp.zeros_like(o_ref)
        for k, x_ref in enumerate(refs[:-1]):
            o_ref[:, offsets[k]:offsets[k + 1]] = x_ref[...].astype(o_ref.dtype)

    def run(*blocks):
        assert len(blocks) == len(col_widths)
        rows = blocks[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_blocks = [jnp.pad(b, ((0, rp - rows), (0, 0))) for b in blocks]
        out = pl.pallas_call(
            kernel,
            grid=(rp // br,),
            in_specs=[pl.BlockSpec((br, w), lambda r: (r, 0))
                      for w in col_widths],
            out_specs=pl.BlockSpec((br, padded), lambda r: (r, 0)),
            out_shape=jax.ShapeDtypeStruct((rp, padded), out_dtype),
            interpret=interpret,
        )(*padded_blocks)
        return out[:rows]

    return run


# ---------------------------------------------------------------------------
# The fused per-output streaming dataflow kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamInput:
    """One raw column block streamed through the kernel, row-tiled."""

    name: str
    width: int
    dtype: np.dtype
    hex_width: int = 0  # > 0: digit-major uint8[hex_width, rows, width]


@dataclasses.dataclass(frozen=True)
class TableInput:
    """One frozen, OOV-resolved vocab table staged whole per grid step."""

    vocab_id: str
    capacity: int


@dataclasses.dataclass(frozen=True)
class TileStep:
    """One operator application inside the kernel body.

    kind:
      "map"    — unary per-tile fn (fused elementwise chain, hex fold,
                 one-hot expansion); ``fn(tile) -> tile``.
      "join"   — binary per-tile fn (Cartesian cross); ``fn(a, b) -> tile``.
      "lookup" — gather through ``tables[table]`` (rank lookup; the OOV rule
                 is pre-folded into the table, so a miss gathers n_unique).
    """

    kind: str
    out: str
    args: tuple
    fn: Optional[Callable] = None
    table: int = -1


def _row_tile_sources(inputs, srcs, br: int, rp: int):
    """Pad each raw source to the row-tile multiple and emit its BlockSpec
    (hex sources are digit-major 3-d; the digit axis is not tiled)."""
    rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
    padded_srcs, in_specs = [], []
    for inp, x in zip(inputs, srcs):
        if inp.hex_width:
            padded_srcs.append(jnp.pad(x, ((0, 0), (0, rp - rows), (0, 0))))
            in_specs.append(pl.BlockSpec((inp.hex_width, br, inp.width),
                                         lambda r: (0, r, 0)))
        else:
            padded_srcs.append(jnp.pad(x, ((0, rp - rows), (0, 0))))
            in_specs.append(pl.BlockSpec((br, inp.width),
                                         lambda r: (r, 0)))
    return padded_srcs, in_specs


def _run_tile_steps(env: dict, steps, tbl_refs):
    """Execute the TileStep program over VMEM-resident tiles in ``env``."""
    for st in steps:
        if st.kind == "map":
            env[st.out] = st.fn(env[st.args[0]])
        elif st.kind == "join":
            env[st.out] = st.fn(env[st.args[0]], env[st.args[1]])
        elif st.kind == "lookup":
            tbl = tbl_refs[st.table][...]  # (1, capacity), OOV-resolved
            x = env[st.args[0]]
            safe = jnp.clip(x, 0, tbl.shape[-1] - 1)
            env[st.out] = jnp.take(tbl[0], safe.reshape(-1),
                                   axis=0).reshape(x.shape)
        else:
            raise NotImplementedError(st.kind)


def make_output_dataflow(inputs: Sequence[StreamInput],
                         tables: Sequence[TableInput],
                         steps: Sequence[TileStep],
                         terminals: Sequence[tuple],
                         out_dtype, *, pad_cols_to: int = 1,
                         block_rows: int = 256, interpret: bool = True):
    """Build fn(*sources, *tables) -> packed [rows, padded(sum widths)].

    ``terminals`` is the ordered list of ``(buffer_name, width)`` pairs the
    packer epilogue writes; names refer to stream inputs or step outputs.
    The returned callable issues exactly ONE ``pallas_call``.
    """
    inputs = list(inputs)
    tables = list(tables)
    steps = list(steps)
    terminals = [(str(n), int(w)) for n, w in terminals]
    total = sum(w for _, w in terminals)
    padded = _round_up(max(total, 1), max(pad_cols_to, 1))
    offsets = np.cumsum([0] + [w for _, w in terminals]).tolist()
    n_src = len(inputs)

    def kernel(*refs):
        src_refs, tbl_refs, o_ref = refs[:n_src], refs[n_src:-1], refs[-1]
        env = {inp.name: r[...] for inp, r in zip(inputs, src_refs)}
        _run_tile_steps(env, steps, tbl_refs)
        o_ref[...] = jnp.zeros_like(o_ref)
        for (name, w), off in zip(terminals, offsets):
            o_ref[:, off:off + w] = env[name].astype(o_ref.dtype)

    def run(*arrays):
        assert len(arrays) == n_src + len(tables), (len(arrays), n_src)
        srcs, tbls = arrays[:n_src], arrays[n_src:]
        rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_srcs, in_specs = _row_tile_sources(inputs, srcs, br, rp)
        for t, a in zip(tables, tbls):
            assert a.shape == (1, t.capacity), (a.shape, t.capacity)
            in_specs.append(pl.BlockSpec((1, t.capacity), lambda r: (0, 0)))
        out = pl.pallas_call(
            kernel,
            grid=(rp // br,),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((br, padded), lambda r: (r, 0)),
            out_shape=jax.ShapeDtypeStruct((rp, padded), out_dtype),
            interpret=interpret,
        )(*padded_srcs, *tbls)
        return out[:rows]

    return run


# ---------------------------------------------------------------------------
# The multi-output fused streaming dataflow kernel (DataflowGroup lowering)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupOutput:
    """The packer epilogue of one member of a ``DataflowGroup``."""

    name: str
    terminals: tuple  # ((buffer_name, width), ...) in pack order
    out_dtype: np.dtype
    pad_cols_to: int = 1


def make_group_dataflow(inputs: Sequence[StreamInput],
                        tables: Sequence[TableInput],
                        steps: Sequence[TileStep],
                        outputs: Sequence[GroupOutput], *,
                        block_rows: int = 256, interpret: bool = True):
    """Build fn(*sources, *tables) -> tuple of packed arrays, one per output.

    The grouped form of ``make_output_dataflow``: the merged backward slice
    of SEVERAL ``PackOutput``s runs as ONE row-tiled ``pallas_call``.  Per
    grid step the shared ``TileStep`` program executes exactly once over the
    union tile environment, then each member output's packer epilogue reads
    its terminals from that one environment and stores them at static lane
    offsets of its own packed block — stages shared across outputs are
    computed once per tile instead of once per output.
    """
    inputs = list(inputs)
    tables = list(tables)
    steps = list(steps)
    outputs = list(outputs)
    n_src = len(inputs)
    n_out = len(outputs)
    paddeds, offsets_per_out = [], []
    for g in outputs:
        widths = [int(w) for _, w in g.terminals]
        paddeds.append(_round_up(max(sum(widths), 1), max(g.pad_cols_to, 1)))
        offsets_per_out.append(np.cumsum([0] + widths).tolist())

    def kernel(*refs):
        src_refs = refs[:n_src]
        tbl_refs = refs[n_src:-n_out]
        out_refs = refs[-n_out:]
        env = {inp.name: r[...] for inp, r in zip(inputs, src_refs)}
        _run_tile_steps(env, steps, tbl_refs)
        for g, o_ref, offs in zip(outputs, out_refs, offsets_per_out):
            o_ref[...] = jnp.zeros_like(o_ref)
            for (name, w), off in zip(g.terminals, offs):
                o_ref[:, off:off + w] = env[name].astype(o_ref.dtype)

    def run(*arrays):
        assert len(arrays) == n_src + len(tables), (len(arrays), n_src)
        srcs, tbls = arrays[:n_src], arrays[n_src:]
        rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_srcs, in_specs = _row_tile_sources(inputs, srcs, br, rp)
        for t, a in zip(tables, tbls):
            assert a.shape == (1, t.capacity), (a.shape, t.capacity)
            in_specs.append(pl.BlockSpec((1, t.capacity), lambda r: (0, 0)))
        outs = pl.pallas_call(
            kernel,
            grid=(rp // br,),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((br, p), lambda r: (r, 0))
                       for p in paddeds],
            out_shape=[jax.ShapeDtypeStruct((rp, p), g.out_dtype)
                       for g, p in zip(outputs, paddeds)],
            interpret=interpret,
        )(*padded_srcs, *tbls)
        return tuple(o[:rows] for o in outs)

    return run


# ---------------------------------------------------------------------------
# The fused per-vocab streaming *fit* kernel
# ---------------------------------------------------------------------------

ABSENT32 = 2 ** 31 - 1  # matches kernels.vocab / kernels.ref chunk sentinel


def make_fit_dataflow(inputs: Sequence[StreamInput],
                      steps: Sequence[TileStep],
                      value_buf: str, capacity: int, *,
                      block_rows: int = 256, interpret: bool = True):
    """Build fn(*sources) -> (first_pos int32[capacity], counts int32[capacity]).

    One ``pallas_call``: row tiles of every raw source stream through the
    ``TileStep`` chain (map/join only — lookups cannot precede a fit), the
    resulting ``value_buf`` tile is flattened row-major, and the chunk
    first-occurrence positions and occurrence counts accumulate into two
    VMEM-resident tables revisited by every grid step.  Semantics match the
    staged path exactly: positions are global row-major flat offsets over the
    unpadded chunk, ``ABSENT32`` marks values absent from the chunk, and
    counts sum every occurrence (the frequency-filter input).

    The build uses whole-tile scatter updates rather than the staged
    kernel's serial fori_loop; like the in-kernel one-hot of the apply
    dataflow this is interpret-mode-validated — real-TPU Mosaic lowering is
    tracked as a ROADMAP hardware-pass item.
    """
    inputs = list(inputs)
    steps = list(steps)
    n_src = len(inputs)

    def kernel(*refs, n_rows: int):
        src_refs, fp_ref, cnt_ref = refs[:n_src], refs[-2], refs[-1]

        @pl.when(pl.program_id(0) == 0)
        def _init():
            fp_ref[...] = jnp.full_like(fp_ref, ABSENT32)
            cnt_ref[...] = jnp.zeros_like(cnt_ref)

        env = {inp.name: r[...] for inp, r in zip(inputs, src_refs)}
        for st in steps:
            if st.kind == "map":
                env[st.out] = st.fn(env[st.args[0]])
            elif st.kind == "join":
                env[st.out] = st.fn(env[st.args[0]], env[st.args[1]])
            else:  # pragma: no cover - legality pass rejects lookups
                raise NotImplementedError(st.kind)
        vals = env[value_buf]
        br, width = vals.shape
        # global row-major flat position of each element; padding rows are
        # masked out (position -> ABSENT32 so min is a no-op, count += 0)
        row = pl.program_id(0) * br + jax.lax.broadcasted_iota(
            jnp.int32, vals.shape, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, vals.shape, 1)
        # match the staged build kernel's in-bounds check exactly: values
        # >= capacity drop via the scatter's OOB rule, but negatives must be
        # masked here — JAX index normalization would wrap them to the end
        # of the table instead of dropping them
        ok = (row < n_rows) & (vals >= 0)
        pos = jnp.where(ok, row * width + col, ABSENT32).reshape(-1)
        idx = jnp.where(ok, vals, 0).reshape(-1)  # masked entries are no-ops
        one = jnp.where(ok, 1, 0).astype(jnp.int32).reshape(-1)
        fp_ref[...] = fp_ref[...].at[0, idx].min(pos)
        cnt_ref[...] = cnt_ref[...].at[0, idx].add(one)

    def run(*srcs):
        assert len(srcs) == n_src, (len(srcs), n_src)
        rows = srcs[0].shape[1] if inputs[0].hex_width else srcs[0].shape[0]
        br = min(block_rows, _round_up(rows, 8))
        rp = _round_up(rows, br)
        padded_srcs, in_specs = _row_tile_sources(inputs, srcs, br, rp)
        fp, cnt = pl.pallas_call(
            functools.partial(kernel, n_rows=rows),
            grid=(rp // br,),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((1, capacity), lambda r: (0, 0)),
                       pl.BlockSpec((1, capacity), lambda r: (0, 0))],
            out_shape=[jax.ShapeDtypeStruct((1, capacity), jnp.int32),
                       jax.ShapeDtypeStruct((1, capacity), jnp.int32)],
            interpret=interpret,
        )(*padded_srcs)
        return fp[0], cnt[0]

    return run
