"""Vocabulary build (VocabGen) and lookup (VocabMap) Pallas kernels.

TPU adaptation of the paper's stateful operators (§3.2.2):

VocabGen — the FPGA builds the table in a pipelined RAW-serialized loop
(II = 2 cycles on-chip, ~6 off-chip).  On TPU the equivalent structure is a
table *partitioned across the grid* (the paper's "P HBM banks"): each grid
step owns one table partition in VMEM and scans the value stream, keeping the
min first-occurrence position for in-partition values.  The serial
read-modify-write over the stream inside a partition mirrors the paper's
RAW-limited II; partitions run in parallel exactly like HBM banks.

VocabMap — keyed lookups against the frozen table.  Partition-parallel form:
each grid step gathers hits for its table partition; a max-combine across
partitions assembles the result (every key hits exactly one partition, misses
contribute -1).  This avoids unsupported full-table dynamic gathers when the
table exceeds VMEM; the in-partition gather is the banked lane gather of
``kernels.lanes`` (no flat reshapes — the form Mosaic lowers).

Partition blocks are lane-padded: each partition of ``capacity``
occupies ``lane_pad(capacity // partitions)`` lanes of the kernel-side
buffer (padding lanes are inert — bounds checks use the logical partition
size) and the wrappers re-interleave the logical table on return, so any
``capacity % 128`` works in compiled mode.

``interpret=None`` resolves through ``kernels.backend.default_interpret``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import lanes
from repro.kernels.backend import default_interpret

ABSENT32 = 2 ** 31 - 1  # python int: safe to close over inside kernel bodies


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _unpad_partitions(t, partitions: int, part: int, part_pad: int):
    """(1, partitions*part_pad) kernel buffer -> logical [capacity] table."""
    t = t.reshape(partitions, part_pad)[:, :part].reshape(-1)
    return t


# ---------------------------------------------------------------------------
# VocabGen: chunk-local first-occurrence build
# ---------------------------------------------------------------------------

def _build_kernel(vals_ref, fp_ref, *, part_size: int, n_vals: int):
    """Grid dim 0 = table partition p. fp_ref block: partition of first_pos
    (lane-padded; only the first ``part_size`` lanes are logical)."""
    p = pl.program_id(0)
    lo = p * part_size

    @pl.when(pl.program_id(1) == 0)
    def _init():
        fp_ref[...] = jnp.full(fp_ref.shape, ABSENT32, fp_ref.dtype)

    vals = vals_ref[...]  # (1, chunk) int32 block of the stream
    chunk = vals.shape[-1]
    base = pl.program_id(1) * chunk

    def body(i, _):
        v = vals[0, i] - lo
        inb = (v >= 0) & (v < part_size)

        @pl.when(inb & (base + i < n_vals))
        def _upd():
            cur = fp_ref[0, v]
            fp_ref[0, v] = jnp.minimum(cur, base + i)

        return 0

    jax.lax.fori_loop(0, chunk, body, 0)


def vocab_build_chunk(values, capacity: int, *, partitions: int = 1,
                      stream_block: int = 4096,
                      interpret: Optional[bool] = None):
    """First-occurrence position within one chunk. int32[capacity], ABSENT32=absent.

    values: int32[n] in [0, capacity).
    """
    if interpret is None:
        interpret = default_interpret()
    n = int(values.shape[0])
    if capacity % max(partitions, 1):
        raise ValueError("capacity must divide evenly into partitions")
    part = capacity // partitions
    part_pad = lanes.lane_pad(part)
    nb = _round_up(max(n, 1), stream_block)
    vp = jnp.pad(values, (0, nb - n), constant_values=-1).reshape(1, nb)

    out = pl.pallas_call(
        functools.partial(_build_kernel, part_size=part, n_vals=n),
        grid=(partitions, nb // stream_block),
        in_specs=[pl.BlockSpec((1, stream_block), lambda p, c: (0, c))],
        out_specs=pl.BlockSpec((1, part_pad), lambda p, c: (0, p)),
        out_shape=jax.ShapeDtypeStruct((1, partitions * part_pad), jnp.int32),
        interpret=interpret,
    )(vp)
    return _unpad_partitions(out, partitions, part, part_pad)


# ---------------------------------------------------------------------------
# VocabMap: partition-parallel gather
# ---------------------------------------------------------------------------

def _lookup_kernel(x_ref, tbl_ref, o_ref, *, part_size: int):
    """Grid: (row blocks, partitions). o accumulates max over partitions."""
    p = pl.program_id(1)
    lo = p * part_size
    x = x_ref[...]

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.full(o_ref.shape, -1, o_ref.dtype)

    local = x - lo
    inb = (local >= 0) & (local < part_size)
    safe = jnp.where(inb, local, 0)
    tbl = tbl_ref[...]  # (1, lane_pad(part_size))
    got = lanes.lane_gather(tbl, safe)
    got = jnp.where(inb, got, -1)
    o_ref[...] = jnp.maximum(o_ref[...], got)


def vocab_lookup(x, table, n_unique, *, partitions: int = 1,
                 block_rows: int = 256, interpret: Optional[bool] = None):
    """Map x through table (absent -> -1 -> OOV index n_unique).

    x: int32[rows, cols] in [0, capacity); table: int32[capacity].
    """
    if interpret is None:
        interpret = default_interpret()
    rows, cols = x.shape
    capacity = int(table.shape[0])
    if capacity % max(partitions, 1):
        raise ValueError("capacity must divide evenly into partitions")
    part = capacity // partitions
    part_pad = lanes.lane_pad(part)
    br = min(block_rows, _round_up(rows, 8))
    bc = _round_up(cols, 128)
    rp = _round_up(rows, br)
    xp = jnp.pad(x, ((0, rp - rows), (0, bc - cols)))
    tbl = jnp.pad(table.reshape(partitions, part),
                  ((0, 0), (0, part_pad - part))).reshape(1, -1)

    out = pl.pallas_call(
        functools.partial(_lookup_kernel, part_size=part),
        grid=(rp // br, partitions),
        in_specs=[
            pl.BlockSpec((br, bc), lambda r, p: (r, 0)),
            pl.BlockSpec((1, part_pad), lambda r, p: (0, p)),
        ],
        out_specs=pl.BlockSpec((br, bc), lambda r, p: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, bc), jnp.int32),
        interpret=interpret,
    )(xp, tbl)
    out = out[:rows, :cols]
    return jnp.where(out >= 0, out, n_unique).astype(jnp.int32)
