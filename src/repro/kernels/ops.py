"""Jit'd public wrappers over the Pallas kernels.

``interpret=None`` on every wrapper resolves through
``kernels.backend.default_interpret`` — compiled mode (interpret=False)
whenever the default JAX backend has a compiled Pallas target (TPU/Mosaic,
GPU/Triton), interpret mode otherwise.  The kernel modules apply the same
default themselves; the wrappers resolve eagerly only so the jit static
argnames see a concrete bool.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels import dataflow as _dataflow
from repro.kernels import embedding_bag as _bag
from repro.kernels import vocab as _vocab
from repro.kernels.backend import compiled_backend, default_interpret

__all__ = [
    "compiled_backend", "default_interpret",
    "fused_stage", "output_dataflow", "group_dataflow", "fit_dataflow",
    "vocab_build_chunk", "vocab_lookup", "packer",
    "embedding_bag", "embedding_bag_cached",
]


def fused_stage(chain_fn, *, in_dtype, out_dtype, hex_width=0,
                block_rows=256, block_cols=512, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _dataflow.make_fused_stage(
        chain_fn, in_dtype=in_dtype, out_dtype=out_dtype, hex_width=hex_width,
        block_rows=block_rows, block_cols=block_cols, interpret=interpret)


def output_dataflow(inputs, tables, steps, terminals, out_dtype, *,
                    pad_cols_to=1, block_rows=256, interpret=None):
    """One PackOutput's full streaming program as a single Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    return jax.jit(_dataflow.make_output_dataflow(
        inputs, tables, steps, terminals, out_dtype,
        pad_cols_to=pad_cols_to, block_rows=block_rows, interpret=interpret))


def group_dataflow(inputs, tables, steps, outputs, *,
                   block_rows=256, interpret=None):
    """A DataflowGroup's merged streaming program — several PackOutputs'
    packed blocks from a single Pallas kernel."""
    if interpret is None:
        interpret = default_interpret()
    return jax.jit(_dataflow.make_group_dataflow(
        inputs, tables, steps, outputs,
        block_rows=block_rows, interpret=interpret))


def fit_dataflow(inputs, steps, value_buf, capacity, *,
                 partitions=1, block_rows=256, interpret=None):
    """One VocabFit's full fit chunk (decode + bound + first-pos/count
    build) as a single Pallas kernel.  ``partitions`` splits the accumulator
    table across the grid (the vocab-build HBM-bank pattern)."""
    if interpret is None:
        interpret = default_interpret()
    return jax.jit(_dataflow.make_fit_dataflow(
        inputs, steps, value_buf, capacity, partitions=partitions,
        block_rows=block_rows, interpret=interpret))


@functools.partial(jax.jit, static_argnames=("capacity", "partitions", "interpret"))
def vocab_build_chunk(values, *, capacity, partitions=1, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _vocab.vocab_build_chunk(values, capacity, partitions=partitions,
                                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("partitions", "interpret"))
def vocab_lookup(x, table, n_unique, *, partitions=1, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _vocab.vocab_lookup(x, table, n_unique, partitions=partitions,
                               interpret=interpret)


def packer(col_widths, in_dtypes, out_dtype, *, pad_cols_to=128,
           block_rows=256, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return jax.jit(_dataflow.make_packer(
        col_widths, in_dtypes, out_dtype, pad_cols_to=pad_cols_to,
        block_rows=block_rows, interpret=interpret))


@functools.partial(jax.jit, static_argnames=("partitions", "interpret"))
def embedding_bag(table, indices, *, partitions=1, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return _bag.embedding_bag(table, indices, partitions=partitions,
                              interpret=interpret)


@functools.partial(jax.jit, static_argnames=("partitions", "interpret"))
def embedding_bag_cached(table, cache, slot_idx, cold_idx=None, *,
                         partitions=1, interpret=None):
    """Two-level cached bag: hot slots from the VMEM cache, cold indices
    through the partitioned table pass (``cold_idx=None`` = fully staged)."""
    if interpret is None:
        interpret = default_interpret()
    return _bag.embedding_bag_cached(table, cache, slot_idx, cold_idx,
                                     partitions=partitions,
                                     interpret=interpret)
