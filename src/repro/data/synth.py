"""Synthetic dataset generators mirroring the paper's three datasets (§4.1.1).

- Dataset-I  : Criteo-Kaggle shape — 13 dense f32 + 26 sparse 8-char hex + label.
- Dataset-II : wide synthetic — 504 dense + 42 sparse hex.
- Dataset-III: Dataset-I column structure, sharded into many files (industrial
  ingest).  Row counts are scaled by ``scale`` so CI-sized runs stay tractable;
  benchmarks report per-row throughput, which is scale-invariant.

Sparse values follow a Zipf-like distribution over a bounded id universe so
vocabulary builds see realistic skew (hot keys + long tail); a configurable
missing-rate produces all-zero hex strings (the paper's FillMissing path).
"""

from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.core.schema import Schema

_HEX = np.frombuffer(b"0123456789abcdef", dtype=np.uint8)


def _hex_encode(vals: np.ndarray, width: int) -> np.ndarray:
    """uint32[n] -> uint8[n, width] ASCII hex (lowercase)."""
    out = np.empty(vals.shape + (width,), np.uint8)
    v = vals.astype(np.uint64)
    for i in range(width - 1, -1, -1):
        out[..., i] = _HEX[(v & 0xF).astype(np.int64)]
        v >>= np.uint64(4)
    return out


def _zipf_ids(rng, n, universe, a=1.3):
    ids = rng.zipf(a, size=n) % universe
    return ids.astype(np.uint32)


def gen_batch(schema: Schema, n_rows: int, rng: np.random.Generator, *,
              id_universe: int = 1 << 22, missing_rate: float = 0.02) -> dict:
    """One raw columnar batch for any dense/sparse/label schema."""
    batch = {}
    for f in schema:
        if f.kind == "dense":
            x = rng.lognormal(mean=1.0, sigma=2.0, size=n_rows).astype(np.float32)
            neg = rng.random(n_rows) < 0.15
            x = np.where(neg, -x, x)  # negatives exercise Clamp
            if missing_rate:
                x[rng.random(n_rows) < missing_rate] = np.nan
            batch[f.name] = x
        elif f.kind == "sparse":
            ids = _zipf_ids(rng, n_rows, id_universe)
            col = _hex_encode(ids, f.hex_width)
            if missing_rate:
                col[rng.random(n_rows) < missing_rate] = 0  # all-zero = missing
            batch[f.name] = col
        elif f.kind == "label":
            batch[f.name] = (rng.random(n_rows) < 0.03).astype(np.float32)
        elif f.kind == "token":
            batch[f.name] = rng.integers(
                0, id_universe, size=(n_rows, f.seq_len)).astype(np.int32)
    return batch


def dataset_batches(which: str, *, rows: int, batch_size: int, seed: int = 0,
                    missing_rate: float = 0.02) -> Iterator[dict]:
    """Stream raw batches for dataset I/II/III (III = I's columns)."""
    schema = {"I": Schema.criteo_kaggle(), "II": Schema.synthetic_wide(),
              "III": Schema.criteo_kaggle()}[which]
    rng = np.random.default_rng(seed)
    emitted = 0
    while emitted < rows:
        n = min(batch_size, rows - emitted)
        yield gen_batch(schema, n, rng, missing_rate=missing_rate)
        emitted += n


def dataset_schema(which: str) -> Schema:
    return {"I": Schema.criteo_kaggle(), "II": Schema.synthetic_wide(),
            "III": Schema.criteo_kaggle()}[which]


def lm_event_batches(seq_len: int, *, rows: int, batch_size: int,
                     seed: int = 0, id_universe: int = 1 << 22
                     ) -> Iterator[dict]:
    """Raw LM event-log batches (unbounded ids; SigridHash bounds them)."""
    rng = np.random.default_rng(seed)
    emitted = 0
    while emitted < rows:
        n = min(batch_size, rows - emitted)
        toks = rng.integers(0, id_universe, size=(n, seq_len)).astype(np.int32)
        lbl = np.roll(toks, -1, axis=1)
        yield {"tokens_raw": toks, "label": lbl}
        emitted += n


def materialize(schema: Schema, it: Iterator[dict]) -> dict:
    """Concatenate a batch stream into one in-memory columnar table."""
    cols: dict[str, list] = {}
    for b in it:
        for k, v in b.items():
            cols.setdefault(k, []).append(v)
    return {k: np.concatenate(v) for k, v in cols.items()}
