"""Columnar binary dataset format (offline Parquet stand-in).

The paper stores Criteo as uncompressed, memory-aligned binary Parquet for
columnar processing (§4.1.1).  pyarrow is unavailable offline, so we use an
equivalent self-describing container:

  <dir>/manifest.json      schema + shard index
  <dir>/shard_NNNNN.npz    one np.savez per shard, one array per column

Shards enable Dataset-III-style parallel ingest (the paper shards the 1TB
click logs into 1024 files); readers stream shard-by-shard with selective
column access (only requested columns are materialized).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Iterator, Optional, Sequence

import numpy as np

from repro.core.schema import FeatureSpec, Schema

MANIFEST = "manifest.json"


def write_dataset(path: str, schema: Schema, batches: Iterator[dict]) -> dict:
    """Write an iterator of columnar batches as shards. Returns the manifest."""
    os.makedirs(path, exist_ok=True)
    shards = []
    total = 0
    for i, batch in enumerate(batches):
        schema.validate_batch(batch)
        n = int(next(iter(batch.values())).shape[0])
        name = f"shard_{i:05d}.npz"
        # atomic publish: write to temp then rename (restart safety);
        # NOTE np.savez appends ".npz" when missing
        fd, tmp = tempfile.mkstemp(dir=path, suffix=".npz")
        os.close(fd)
        with open(tmp, "wb") as fh:
            np.savez(fh, **batch)
        os.replace(tmp, os.path.join(path, name))
        shards.append({"file": name, "rows": n})
        total += n
    manifest = {
        "format": "repro-columnar-v1",
        "rows": total,
        "shards": shards,
        "schema": [
            {"name": f.name, "kind": f.kind, "dtype": f.dtype,
             "hex_width": f.hex_width, "seq_len": f.seq_len}
            for f in schema],
    }
    with open(os.path.join(path, MANIFEST), "w") as fh:
        json.dump(manifest, fh, indent=1)
    return manifest


def read_manifest(path: str) -> dict:
    with open(os.path.join(path, MANIFEST)) as fh:
        return json.load(fh)


def load_schema(path: str) -> Schema:
    man = read_manifest(path)
    return Schema([FeatureSpec(**f) for f in man["schema"]])


def iter_shards(path: str, columns: Optional[Sequence[str]] = None,
                start_shard: int = 0, *, shard_index: int = 0,
                shard_count: int = 1) -> Iterator[dict]:
    """Stream shards with selective column access.

    ``columns`` is the projection pushdown point: ``np.load`` is lazy per
    key, so unrequested columns are never read off disk.  ``shard_index`` /
    ``shard_count`` select every ``shard_count``-th shard file (file-level
    sharding for parallel ingest — reader *i* of *n* touches a disjoint
    subset of shard files).
    """
    if not 0 <= shard_index < shard_count:
        raise ValueError(f"shard_index {shard_index} not in [0, {shard_count})")
    man = read_manifest(path)
    for sh in man["shards"][start_shard:][shard_index::shard_count]:
        with np.load(os.path.join(path, sh["file"])) as z:
            names = columns if columns is not None else list(z.files)
            yield {c: z[c] for c in names}


def iter_batches(path: str, batch_size: int,
                 columns: Optional[Sequence[str]] = None,
                 drop_remainder: bool = True) -> Iterator[dict]:
    """Re-batch the shard stream to a fixed batch size."""
    carry: Optional[dict] = None
    for shard in iter_shards(path, columns):
        if carry is not None:
            shard = {k: np.concatenate([carry[k], shard[k]]) for k in shard}
        n = next(iter(shard.values())).shape[0]
        ofs = 0
        while n - ofs >= batch_size:
            yield {k: v[ofs:ofs + batch_size] for k, v in shard.items()}
            ofs += batch_size
        carry = {k: v[ofs:] for k, v in shard.items()} if ofs < n else None
    if carry is not None and not drop_remainder:
        n = next(iter(carry.values())).shape[0]
        if n:
            yield carry
