"""First-class ingest sources with lazily-applied, chainable specs.

A ``Source`` is a declarative description of a raw columnar batch stream —
the ingest half of the paper's training-aware ETL abstraction (§3).  Instead
of hand-wiring a Python iterator into the executor, callers name *what* to
read and *how* (shard, projection, batch geometry, ordering key, arrival
times), and the planner/runtime consume those specs:

    src = (Source.columnar("/data/criteo")
               .shard(host_id, n_hosts)        # file-level shard selection
               .rebatch(65536))                # decouple shard size from batch
    job = EtlJob(pipeline, src)                # projection pushed automatically

Spec semantics
--------------
- ``.columns(names)``   projection: the columnar reader never materializes
  unrequested columns (``np.load`` is lazy per key); generated/stream sources
  filter the emitted dicts.  ``repro.session.EtlJob`` pushes the pipeline's
  referenced-column set here automatically.
- ``.shard(i, n)``      reader *i* of *n*: shard-file-level for columnar
  datasets, round-robin by batch index for generated/stream sources.
- ``.rebatch(b)``       split / coalesce incoming batches to exactly ``b``
  rows, carrying remainders across source-batch (and shard) boundaries.
- ``.length_key(fn)``   host-side ordering key ``fn(raw_batch) -> float``
  computed at read time, so ``bucket_by_length`` ordering never syncs the
  transform stage's device futures (ROADMAP follow-on).
- ``.arrival(ts)``      per-batch arrival timestamps (sequence or
  ``fn(batch_index) -> float``) for freshness experiments; the runtime
  records the arrivals of delivered batches.

All specs are lazy: nothing moves until the Source is iterated.  Chaining
returns a new Source; a Source is re-iterable whenever its reader is
(columnar / synth always are, ``Source.stream`` over a bare iterator is
one-shot).
"""

from __future__ import annotations

import dataclasses
import queue as queue_lib
import threading
from typing import Callable, Iterator, Optional, Sequence, Union

import numpy as np

from repro.core.schema import Schema
from repro.data import columnar as columnar_lib
from repro.data import synth as synth_lib


@dataclasses.dataclass(frozen=True)
class SourceSpec:
    """Lazily-applied ingest spec (see module docstring)."""

    columns: Optional[tuple] = None     # projection (None = all columns)
    shard_index: int = 0
    shard_count: int = 1
    rebatch_rows: Optional[int] = None
    drop_remainder: bool = False
    length_key: Optional[Callable] = None
    arrival: Optional[object] = None    # sequence of floats | fn(idx) -> float

    def arrival_fn(self) -> Optional[Callable[[int], Optional[float]]]:
        """Normalize ``arrival`` to an index -> timestamp lookup."""
        if self.arrival is None:
            return None
        if callable(self.arrival):
            return self.arrival
        seq = list(self.arrival)
        return lambda i: seq[i] if i < len(seq) else None


class _WAKE:
    """Sentinel a closing Source injects into its feed so a reader blocked
    on an empty queue wakes immediately instead of sleeping out its whole
    poll interval (shutdown-latency fix).  Readers discard it and re-check
    their close token, so a stale wake from a previous iteration's close is
    harmless."""


class _CloseChannel:
    """Close signal scoped to the *active* iteration of a blocking reader.

    ``token()`` hands each new iteration a fresh event, so closing one
    executor run (``Source.close``) never poisons a later re-iteration of
    the same Source (one active iteration at a time).  ``wake`` (optional)
    runs after the event is set to unblock a reader parked inside a blocking
    get — e.g. pushing ``_WAKE`` into a ``queue.Queue`` feed, or notifying a
    bus subscription's condition.
    """

    def __init__(self, wake: Optional[Callable[[], None]] = None):
        self._current: Optional[threading.Event] = None
        self._wake = wake

    def token(self) -> threading.Event:
        self._current = threading.Event()
        return self._current

    def set(self) -> None:
        if self._current is not None:
            self._current.set()
        if self._wake is not None:
            self._wake()


def _first_len(batch: dict) -> int:
    return int(next(iter(batch.values())).shape[0])


def rebatch(batches: Iterator[dict], batch_size: int, *,
            drop_remainder: bool = False) -> Iterator[dict]:
    """Re-slice a batch stream to a fixed row count.

    Rows carry across incoming batch boundaries (coalescing small shards,
    splitting large ones); the final partial batch is emitted unless
    ``drop_remainder``.
    """
    if batch_size <= 0:
        raise ValueError("rebatch size must be positive")
    carry: Optional[dict] = None
    for batch in batches:
        if carry is not None:
            batch = {k: np.concatenate([carry[k], batch[k]]) for k in batch}
        n = _first_len(batch)
        ofs = 0
        while n - ofs >= batch_size:
            yield {k: v[ofs:ofs + batch_size] for k, v in batch.items()}
            ofs += batch_size
        carry = ({k: v[ofs:] for k, v in batch.items()} if ofs < n else None)
    if carry is not None and not drop_remainder and _first_len(carry):
        yield carry


class Source:
    """Declarative raw-batch stream; see the module docstring.

    ``reader(spec)`` yields raw columnar dict batches with the *native*
    capabilities already applied; the generic wrapper applies whatever the
    reader does not handle itself (column filter, batch-index sharding,
    rebatching).
    """

    def __init__(self, reader: Callable[[SourceSpec], Iterator[dict]], *,
                 name: str = "source", spec: Optional[SourceSpec] = None,
                 native: frozenset = frozenset(),
                 schema: Optional[Schema] = None,
                 close_event: Optional[_CloseChannel] = None):
        self._reader = reader
        self.name = name
        self.spec = spec or SourceSpec()
        self._native = native
        self.schema = schema
        self._close_event = close_event

    # ---- chainable specs (each returns a new Source) ---------------------

    def _with(self, **changes) -> "Source":
        return Source(self._reader, name=self.name,
                      spec=dataclasses.replace(self.spec, **changes),
                      native=self._native, schema=self.schema,
                      close_event=self._close_event)

    def columns(self, names: Sequence[str]) -> "Source":
        """Project to ``names`` — pushed into the columnar reader so
        unreferenced columns are never materialized."""
        return self._with(columns=tuple(dict.fromkeys(names)))

    def shard(self, index: int, count: int) -> "Source":
        """Select this reader's 1/``count`` share of the stream."""
        if not 0 <= index < count:
            raise ValueError(f"shard index {index} not in [0, {count})")
        return self._with(shard_index=index, shard_count=count)

    def rebatch(self, batch_size: int, *,
                drop_remainder: bool = False) -> "Source":
        """Emit exactly ``batch_size`` rows per batch (micro-batch split /
        coalesce), decoupling source shard size from ``BatchingPolicy``."""
        if batch_size <= 0:
            raise ValueError("rebatch size must be positive")
        return self._with(rebatch_rows=batch_size,
                          drop_remainder=drop_remainder)

    def length_key(self, fn: Callable[[dict], float]) -> "Source":
        """Attach a host-side ordering key computed on the raw batch at read
        time; ``bucket_by_length`` then never syncs device futures."""
        return self._with(length_key=fn)

    def arrival(self, timestamps) -> "Source":
        """Attach per-batch arrival timestamps (freshness experiments)."""
        return self._with(arrival=timestamps)

    # ---- iteration -------------------------------------------------------

    def __iter__(self) -> Iterator[dict]:
        spec = self.spec
        it = self._reader(spec)
        if spec.columns is not None and "columns" not in self._native:
            cols = spec.columns
            it = ({k: b[k] for k in cols} for b in it)
        if spec.shard_count > 1 and "shard" not in self._native:
            idx, cnt = spec.shard_index, spec.shard_count
            it = (b for i, b in enumerate(it) if i % cnt == idx)
        if spec.rebatch_rows is not None:
            it = rebatch(it, spec.rebatch_rows,
                         drop_remainder=spec.drop_remainder)
        return it

    def close(self) -> None:
        """Unblock the *active* iteration of a blocking reader (queue
        streams) — the executor calls this on stop so shutdown never leaks
        a read thread parked on an empty feed.  A later re-iteration of the
        Source starts fresh; no-op for sources without a blocking reader."""
        if self._close_event is not None:
            self._close_event.set()

    def __repr__(self) -> str:
        return f"<Source {self.name} {self.spec}>"

    # ---- factories -------------------------------------------------------

    @staticmethod
    def columnar(path: str, *, batch_size: Optional[int] = None,
                 start_shard: int = 0) -> "Source":
        """Stream a ``repro-columnar-v1`` dataset directory.

        Projection and sharding are native: ``.columns`` reaches the
        ``np.load`` key access (unrequested columns stay on disk) and
        ``.shard(i, n)`` selects every n-th shard *file*.  ``batch_size``
        is sugar for ``.rebatch(batch_size)``.
        """
        def reader(spec: SourceSpec) -> Iterator[dict]:
            cols = list(spec.columns) if spec.columns is not None else None
            return columnar_lib.iter_shards(
                path, cols, start_shard,
                shard_index=spec.shard_index, shard_count=spec.shard_count)

        src = Source(reader, name=f"columnar:{path}",
                     native=frozenset({"columns", "shard"}),
                     schema=columnar_lib.load_schema(path))
        return src.rebatch(batch_size) if batch_size else src

    @staticmethod
    def synth(schema: Union[str, Schema], *, rows: int, batch_size: int,
              seed: int = 0, missing_rate: float = 0.02) -> "Source":
        """Synthetic dataset stream: ``schema`` is a paper dataset name
        ("I" | "II" | "III") or any ``Schema`` (generated via
        ``synth.gen_batch``).  Re-iterable and deterministic per seed."""
        if isinstance(schema, str):
            which = schema
            schema_obj = synth_lib.dataset_schema(which)

            def reader(spec: SourceSpec) -> Iterator[dict]:
                return synth_lib.dataset_batches(
                    which, rows=rows, batch_size=batch_size, seed=seed,
                    missing_rate=missing_rate)

            name = f"synth:{which}"
        else:
            schema_obj = schema

            def reader(spec: SourceSpec) -> Iterator[dict]:
                rng = np.random.default_rng(seed)
                emitted = 0
                while emitted < rows:
                    n = min(batch_size, rows - emitted)
                    yield synth_lib.gen_batch(schema_obj, n, rng,
                                              missing_rate=missing_rate)
                    emitted += n

            name = "synth:schema"
        return Source(reader, name=name, schema=schema_obj)

    @staticmethod
    def lm_events(seq_len: int, *, rows: int, batch_size: int, seed: int = 0,
                  id_universe: int = 1 << 22) -> "Source":
        """Raw LM event-log stream (unbounded ids; SigridHash bounds them)."""
        def reader(spec: SourceSpec) -> Iterator[dict]:
            return synth_lib.lm_event_batches(
                seq_len, rows=rows, batch_size=batch_size, seed=seed,
                id_universe=id_universe)

        return Source(reader, name=f"lm_events:{seq_len}",
                      schema=Schema.lm_events(seq_len))

    @staticmethod
    def stream(obj, *, poll_s: float = 0.2) -> "Source":
        """Wrap an online feed: a zero-arg callable returning a fresh
        iterator (re-iterable), a ``queue.Queue`` drained until a ``None``
        sentinel, or any iterable (one-shot).

        Queue readers poll with ``poll_s`` and end when ``close()`` is
        called (the executor does so on stop), so a producer that dies
        without sending the sentinel cannot leak the read thread.  Close is
        *immediate*: it also injects a wake sentinel into the queue, so a
        reader parked on an empty feed never sleeps out the rest of its
        poll interval before noticing.
        """
        if isinstance(obj, queue_lib.Queue):
            def wake() -> None:
                try:
                    obj.put_nowait(_WAKE)
                except queue_lib.Full:
                    # a full queue has no reader blocked on get(); the
                    # close event is observed at the next poll boundary
                    pass

            channel = _CloseChannel(wake=wake)

            def reader(spec: SourceSpec) -> Iterator[dict]:
                closed = channel.token()
                while not closed.is_set():
                    try:
                        item = obj.get(timeout=poll_s)
                    except queue_lib.Empty:
                        continue
                    if item is _WAKE:
                        continue  # close wake (maybe stale): re-check token
                    if item is None:
                        return
                    yield item

            return Source(reader, name="stream:queue", close_event=channel)
        if callable(obj):
            return Source(lambda spec: iter(obj()), name="stream:callable")
        return Source(lambda spec: iter(obj), name="stream:iterable")

    @staticmethod
    def events(bus, topic: str = "events", *,
               poll_s: float = 0.2) -> "Source":
        """Subscribe to a ``repro.online.bus.EventBus`` topic as a Source.

        The subscription is taken eagerly (no event published after this
        call is missed even if iteration starts later) and each event's bus
        arrival timestamp rides the ``Source.arrival`` spec, so the
        executor's freshness machinery — the delivered-staleness histogram
        and ``repro.online.shed``'s global oldest-first shedding — sees true
        event ages.  ``close()`` wakes a blocked reader immediately; the
        stream ends when the bus closes.  Don't ``rebatch``/``shard`` an
        events source: arrival stamps are per published event, and respec'ing
        the geometry would misalign them.
        """
        sub = bus.subscribe(topic)
        channel = _CloseChannel(wake=sub.wake)
        arrivals: dict = {}   # emit index -> arrival; popped once consumed

        def reader(spec: SourceSpec) -> Iterator[dict]:
            closed = channel.token()
            idx = 0
            while not closed.is_set():
                ev = sub.get(timeout=poll_s, cancel=closed)
                if ev is None:
                    if sub.closed and not len(sub):
                        return  # bus closed and drained
                    continue    # timeout or close wake: re-check the token
                batch, arrival = ev
                arrivals[idx] = arrival
                idx += 1
                yield batch

        src = Source(reader, name=f"events:{topic}", close_event=channel)
        return src.arrival(lambda i: arrivals.pop(i, None))


def as_source(obj) -> Source:
    """Coerce anything batch-yielding into a Source (identity for one)."""
    return obj if isinstance(obj, Source) else Source.stream(obj)
