"""Sharded, atomic, async checkpointing with elastic resharding.

Layout (one directory per step)::

    <dir>/step_000100/manifest.json   pytree structure + leaf index
    <dir>/step_000100/leaf_00042.npy  one array per leaf
    <dir>/step_000100/COMMITTED       written last (atomic publish marker)

- Atomicity: leaves are written into a temp dir, fsync'd, renamed, and the
  COMMITTED marker written last; restore ignores uncommitted directories, so
  a crash mid-save can never corrupt the restore path (restart safety).
- Async: ``save_async`` snapshots device arrays to host (blocking only on
  transfer) and writes in a background thread — the train loop continues.
- Elastic resharding: leaves are stored as full logical arrays; ``restore``
  device_puts them with whatever NamedShardings the *current* mesh dictates,
  so a 256-chip checkpoint restores onto 512 chips (or 8) unchanged.
  On a real multi-host pod each host writes only the shards it owns and
  restore uses ``jax.make_array_from_single_device_arrays``; the single-host
  container uses the consolidated form of the same manifest format.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Optional

import jax
import numpy as np

_COMMIT = "COMMITTED"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(tree: Any, ckpt_dir: str, step: int) -> str:
    """Blocking save. Returns the committed directory path."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(ckpt_dir, exist_ok=True)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_save_")
    try:
        index = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(leaf)
            name = f"leaf_{i:05d}.npy"
            np.save(os.path.join(tmp, name), arr)
            index.append({"file": name, "shape": list(arr.shape),
                          "dtype": str(arr.dtype)})
        manifest = {"step": step, "n_leaves": len(leaves),
                    "treedef": str(treedef), "index": index}
        with open(os.path.join(tmp, "manifest.json"), "w") as fh:
            json.dump(manifest, fh)
        with open(os.path.join(tmp, _COMMIT), "w") as fh:
            fh.write("ok")
        if os.path.isdir(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


class AsyncCheckpointer:
    """Snapshot-to-host then write in a daemon thread; at most one in flight."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[BaseException] = None

    def save_async(self, tree, ckpt_dir: str, step: int):
        self.wait()
        host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)

        def work():
            try:
                save(host_tree, ckpt_dir, step)
            except BaseException as e:  # pragma: no cover
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            raise self.last_error


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, d, _COMMIT)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, step: Optional[int] = None,
            shardings: Any = None) -> Any:
    """Restore into the structure of ``template``.

    shardings: optional pytree of NamedSharding (same structure) — the elastic
    path: arrays are placed directly onto the current mesh regardless of the
    mesh geometry that wrote the checkpoint.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not os.path.exists(os.path.join(d, _COMMIT)):
        raise FileNotFoundError(f"checkpoint {d} not committed")
    with open(os.path.join(d, "manifest.json")) as fh:
        manifest = json.load(fh)
    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    if len(leaves_t) != manifest["n_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves; template has "
            f"{len(leaves_t)} — structure mismatch")
    arrays = [np.load(os.path.join(d, e["file"])) for e in manifest["index"]]
    for a, t in zip(arrays, leaves_t):
        if tuple(a.shape) != tuple(np.shape(t)):
            raise ValueError(f"leaf shape {a.shape} != template {np.shape(t)}")
    if shardings is not None:
        sh_leaves = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec"))
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, sh_leaves)]
    else:
        arrays = [jax.device_put(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays)


def prune(ckpt_dir: str, keep: int = 3):
    """Delete all but the newest ``keep`` *committed* checkpoints.

    Only committed directories count toward ``keep``: a ``step_*`` dir
    without the COMMITTED marker is crash garbage (the marker is written
    inside the temp dir before the atomic rename, so an in-flight save is
    never visible as an uncommitted ``step_*``) and is deleted outright —
    it must not displace a committed checkpoint from the keep window.
    """
    if not os.path.isdir(ckpt_dir):
        return
    committed, garbage = [], []
    for d in os.listdir(ckpt_dir):
        if not d.startswith("step_"):
            continue
        if os.path.exists(os.path.join(ckpt_dir, d, _COMMIT)):
            committed.append(int(d.split("_")[1]))
        else:
            garbage.append(d)
    for d in garbage:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    committed.sort()
    for s in committed[:-keep] if keep else committed:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)