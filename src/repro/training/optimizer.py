"""Optimizers from scratch (no optax offline): AdamW + Adafactor.

Dtype policy: moments stored in ``opt_state_dtype`` — bf16 moments halve
optimizer HBM for the 405B config; Adafactor's factored second moment is the
1T (Kimi-K2) fit strategy.  All update math runs in f32 regardless of the
storage dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(tree, max_norm):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), n


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params, tcfg: TrainConfig):
    dt = jnp.dtype(tcfg.opt_state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params)}


def adamw_update(grads, state, params, step, tcfg: TrainConfig):
    b1, b2, eps = tcfg.beta1, tcfg.beta2, tcfg.eps
    t = (step + 1).astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
        mh, vh = m32 / c1, v32 / c2
        step_ = mh / (jnp.sqrt(vh) + eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - tcfg.lr * (step_ + tcfg.weight_decay * p32)
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree_util.tree_map(upd, grads, state["m"], state["v"], params)
    new_p = jax.tree_util.tree_map(lambda o: o[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda o: o[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda o: o[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_p, {"m": new_m, "v": new_v}


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory ~ O(rows+cols) per matrix)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params, tcfg: TrainConfig):
    dt = jnp.dtype(tcfg.opt_state_dtype)

    def one(p):
        if _factored(p.shape):
            return {"vr": jnp.zeros(p.shape[:-1], dt),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], dt)}
        return {"v": jnp.zeros(p.shape, dt)}

    return {"f": jax.tree_util.tree_map(one, params)}


def adafactor_update(grads, state, params, step, tcfg: TrainConfig):
    eps = 1e-30
    d = 1.0  # clipping threshold
    t = (step + 1).astype(jnp.float32)
    beta2 = 1.0 - t ** -0.8  # schedule from the paper

    def upd(g, st, p):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if "vr" in st:
            vr = beta2 * st["vr"].astype(jnp.float32) + (1 - beta2) * g2.mean(-1)
            vc = beta2 * st["vc"].astype(jnp.float32) + (1 - beta2) * g2.mean(-2)
            denom = (vr[..., None] / jnp.maximum(
                vr.mean(-1, keepdims=True)[..., None], eps)) * vc[..., None, :]
            u = g32 * jax.lax.rsqrt(jnp.maximum(denom, eps))
            new_st = {"vr": vr.astype(st["vr"].dtype),
                      "vc": vc.astype(st["vc"].dtype)}
        else:
            v = beta2 * st["v"].astype(jnp.float32) + (1 - beta2) * g2
            u = g32 * jax.lax.rsqrt(jnp.maximum(v, eps))
            new_st = {"v": v.astype(st["v"].dtype)}
        # update clipping (RMS(u) <= d)
        rms_u = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms_u / d)
        p32 = p.astype(jnp.float32)
        p32 = p32 - tcfg.lr * u - tcfg.lr * tcfg.weight_decay * p32
        return p32.astype(p.dtype), new_st

    # grads' array leaves stop the traversal; st arrives as the {v}/{vr,vc}
    # subtree for that param
    out = jax.tree_util.tree_map(upd, grads, state["f"], params)
    istup = lambda x: isinstance(x, tuple)
    new_p = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup)
    new_f = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup)
    return new_p, {"f": new_f}


# ---------------------------------------------------------------------------
# dispatch
# ---------------------------------------------------------------------------

def opt_init(params, tcfg: TrainConfig):
    if tcfg.optimizer == "adamw":
        return adamw_init(params, tcfg)
    if tcfg.optimizer == "adafactor":
        return adafactor_init(params, tcfg)
    raise ValueError(tcfg.optimizer)


def opt_update(grads, state, params, step, tcfg: TrainConfig):
    if tcfg.max_grad_norm:
        grads, gnorm = clip_by_global_norm(grads, tcfg.max_grad_norm)
    else:
        gnorm = global_norm(grads)
    if tcfg.optimizer == "adamw":
        p, s = adamw_update(grads, state, params, step, tcfg)
    else:
        p, s = adafactor_update(grads, state, params, step, tcfg)
    return p, s, gnorm