"""Gradient machinery: microbatch accumulation + int8 compression w/ error
feedback.

Microbatching (grad accumulation) serves two purposes at scale:
1. activation memory: only one microbatch's activations live at a time;
2. compute/comm overlap: with FSDP/TP sharded params, XLA's latency-hiding
   scheduler overlaps the reduce-scatter/all-gather of microbatch i with the
   backward compute of microbatch i+1 (no explicit code needed — the scan
   carries the accumulator, leaving the collectives dependence-free).

int8 compression with error feedback (beyond-paper distributed-optimization
trick): gradients are quantized to int8 with a per-tensor scale before the
data-parallel mean; the quantization residual is carried to the next step so
the bias vanishes in expectation (EF-SGD).  Used with shard_map-explicit DP.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def split_microbatches(batch: dict, n_micro: int) -> dict:
    """(rows, ...) -> (n_micro, rows/n_micro, ...) for every batch tensor."""

    def one(x):
        r = x.shape[0]
        assert r % n_micro == 0, (r, n_micro)
        return x.reshape((n_micro, r // n_micro) + x.shape[1:])

    return jax.tree_util.tree_map(one, batch)


def microbatched_value_and_grad(loss_fn: Callable, n_micro: int,
                                accum_dtype="float32", grad_specs=None):
    """loss_fn(params, batch) -> (loss, grads) averaged over microbatches.

    Implemented as a lax.scan so depth doesn't blow up the HLO and the
    accumulator forms a clean dependence chain for the scheduler.

    grad_specs: optional pytree of PartitionSpec matching params.  CRITICAL at
    scale: without it GSPMD may leave the accumulator replicated and
    all-reduce FULL f32 weight gradients every step; constraining it to the
    param sharding turns that into the FSDP reduce-scatter.
    """
    vg = jax.value_and_grad(loss_fn)

    def constrain(tree):
        if grad_specs is None:
            return tree
        from repro.distributed.sharding import get_active_mesh
        from jax.sharding import NamedSharding
        mesh = get_active_mesh()
        if mesh is None:
            return tree
        return jax.tree_util.tree_map(
            lambda x, sp: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, sp)), tree, grad_specs)

    if n_micro <= 1:
        def fn1(params, batch):
            loss, g = vg(params, batch)
            return loss, constrain(g)
        return fn1

    def fn(params, batch):
        micro = split_microbatches(batch, n_micro)

        def step(acc, mb):
            loss, g = vg(params, mb)
            acc_loss, acc_g = acc
            acc_g = jax.tree_util.tree_map(
                lambda a, b: a + b.astype(a.dtype), acc_g, constrain(g))
            return (acc_loss + loss, constrain(acc_g)), None

        zero_g = constrain(jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.dtype(accum_dtype)), params))
        (loss_sum, g_sum), _ = jax.lax.scan(step, (jnp.float32(0), zero_g),
                                            micro)
        inv = 1.0 / n_micro
        g = jax.tree_util.tree_map(lambda a: (a * inv), g_sum)
        return loss_sum * inv, g

    return fn


# ---------------------------------------------------------------------------
# int8 gradient compression with error feedback
# ---------------------------------------------------------------------------

def ef_init(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def quantize_int8(x):
    """Per-tensor symmetric int8. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads, ef_state, axis_name: str):
    """Error-feedback int8 all-reduce mean over a shard_map axis.

    Per device: g' = g + residual; q = int8(g'); residual' = g' - deq(q);
    all-reduce the int8 payload (8x less ICI traffic than f32, 4x vs bf16),
    then dequantize the mean.
    """

    def one(g, ef):
        gf = g.astype(jnp.float32) + ef
        # shared scale: scalar pmax first (cheap), so every device quantizes
        # on the same grid and the int8 psum is exact in the quantized domain
        amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        new_ef = gf - q.astype(jnp.float32) * scale
        q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)  # int8 payload
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        mean = q_sum.astype(jnp.float32) * scale / n
        return mean.astype(g.dtype), new_ef

    out = jax.tree_util.tree_map(one, grads, ef_state)
    istup = lambda x: isinstance(x, tuple)
    g = jax.tree_util.tree_map(lambda o: o[0], out, is_leaf=istup)
    ef = jax.tree_util.tree_map(lambda o: o[1], out, is_leaf=istup)
    return g, ef