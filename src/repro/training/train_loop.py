"""Train-step construction + the checkpointed, fault-tolerant driver loop."""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import TrainConfig
from repro.distributed import sharding as shd
from repro.training import checkpoint as ckpt_lib
from repro.training import fault as fault_lib
from repro.training.grad import microbatched_value_and_grad
from repro.training.optimizer import opt_init, opt_update


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any
    step: jax.Array

    @staticmethod
    def create(params, tcfg: TrainConfig) -> "TrainState":
        return TrainState(params=params, opt=opt_init(params, tcfg),
                          step=jnp.zeros((), jnp.int32))


def make_train_step(loss_fn: Callable, tcfg: TrainConfig,
                    grad_specs=None) -> Callable:
    """loss_fn(params, batch) -> scalar. Returns step(state, batch)."""
    n_micro = max(tcfg.microbatch, 1)
    vg = microbatched_value_and_grad(loss_fn, n_micro,
                                     accum_dtype=tcfg.accum_dtype,
                                     grad_specs=grad_specs)

    def train_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        loss, grads = vg(state.params, batch)
        new_p, new_opt, gnorm = opt_update(grads, state.opt, state.params,
                                           state.step, tcfg)
        new_state = TrainState(params=new_p, opt=new_opt,
                               step=state.step + 1)
        return new_state, {"loss": loss.astype(jnp.float32),
                           "grad_norm": gnorm}

    return train_step


def jit_train_step(train_step, mesh, state_shapes, batch_shapes, *,
                   fsdp: bool = False, n_experts: int = 0,
                   donate_batch: bool = False):
    """pjit the step with explicit in/out shardings and state donation.

    ``donate_batch=True`` additionally donates the batch argument — the
    zero-copy half of the ETL handoff: the streaming executor's place stage
    already delivers buffers in the exact ``in_shardings`` layout, so with
    donation XLA reuses the packed batch's HBM for step temporaries instead
    of copying (the paper's "FPGA writes training-ready batches directly
    into accelerator memory").  Only enable it when every batch is consumed
    exactly once (always true for executor-fed loops); a donated batch is
    invalid after the step.  The CPU backend cannot alias donated inputs,
    so the request is ignored there (no warning spam on smoke runs).

    NOTE: for grad-accumulation sharding, build the step via
    ``make_train_step(loss, tcfg, grad_specs=param_specs(...))``.
    """
    donate_batch = donate_batch and jax.default_backend() != "cpu"
    pspec = shd.param_specs(state_shapes.params, mesh, fsdp=fsdp,
                            n_experts=n_experts)
    # optimizer moments run through the same rule engine: AdamW m/v paths end
    # with the param name so the same rule fires; Adafactor's factored vr/vc
    # take the default (FSDP-sharded when enabled — ZeRO covers opt state too)
    opt_spec = shd.param_specs(state_shapes.opt, mesh, fsdp=fsdp,
                               n_experts=n_experts)
    state_spec = TrainState(params=pspec, opt=opt_spec, step=P())
    batch_spec = shd.batch_specs(batch_shapes, mesh)
    to_sh = lambda tree: jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda x: isinstance(x, P))
    return jax.jit(train_step,
                   in_shardings=(to_sh(state_spec), to_sh(batch_spec)),
                   out_shardings=(to_sh(state_spec), None),
                   donate_argnums=(0, 1) if donate_batch else (0,)), state_spec


# ---------------------------------------------------------------------------
# driver loop: checkpoint/restart + watchdog + throughput accounting
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class LoopConfig:
    total_steps: int
    ckpt_dir: str = ""
    ckpt_every: int = 0
    log_every: int = 50
    watchdog_s: float = 0.0
    keep_ckpts: int = 3


def train_loop(state: TrainState, step_fn, batches, loop_cfg: LoopConfig,
               *, async_ckpt: bool = True, on_metrics=None,
               embed_cache=None, embed_tables=None) -> TrainState:
    """Run to total_steps with periodic async checkpoints + watchdog.

    ``batches`` may be a plain iterable or a staged ``StreamingExecutor``;
    an executor is stopped on exit (so breaking at ``total_steps`` tears the
    prefetch stages down promptly) and its stats surface in the metrics.

    ``embed_cache`` threads a ``lookahead.EmbedCache`` alongside the train
    state: before each step the batch's lookahead plan is applied against
    the CURRENT embedding tables (``embed_tables(state.params)``, default
    ``params["tables"]``) so the cached forward reads fresh rows.  Plans
    must be applied in delivery order — the loop is that order.
    """
    ckpt = ckpt_lib.AsyncCheckpointer() if async_ckpt else None
    wd = fault_lib.Watchdog(loop_cfg.watchdog_s) if loop_cfg.watchdog_s else None
    etl_stats = getattr(batches, "stats", None)
    if embed_cache is not None and embed_tables is None:
        embed_tables = lambda params: params["tables"]
    t0 = time.perf_counter()
    train_s = 0.0
    try:
        for batch in batches:
            step_no = int(state.step)
            if step_no >= loop_cfg.total_steps:
                break
            if embed_cache is not None:
                batch = embed_cache.advance(embed_tables(state.params), batch)
            if wd:
                wd.arm()
            ts = time.perf_counter()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            train_s += time.perf_counter() - ts
            if wd:
                wd.check()
                wd.disarm()
            step_no = int(state.step)
            if loop_cfg.log_every and step_no % loop_cfg.log_every == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step_no
                m["train_utilization"] = train_s / max(
                    time.perf_counter() - t0, 1e-9)
                if etl_stats is not None:
                    m["etl_starved_s"] = etl_stats.consumer_wait_s
                    m["etl_overlapped_s"] = etl_stats.overlapped_etl_s
                    cache = getattr(etl_stats, "cache", None)
                    if cache is not None:
                        m["emb_cache_hit_rate"] = cache.hit_rate()
                if on_metrics:
                    on_metrics(m)
                else:
                    print(f"[train] step={step_no} "
                          + " ".join(f"{k}={v:.5g}" for k, v in m.items()
                                     if k != "step"), flush=True)
            if (loop_cfg.ckpt_every and loop_cfg.ckpt_dir
                    and step_no % loop_cfg.ckpt_every == 0):
                if ckpt:
                    ckpt.save_async(state, loop_cfg.ckpt_dir, step_no)
                else:
                    ckpt_lib.save(state, loop_cfg.ckpt_dir, step_no)
                ckpt_lib.prune(loop_cfg.ckpt_dir, loop_cfg.keep_ckpts)
    finally:
        stop = getattr(batches, "stop", None)
        if callable(stop):
            stop()
        if ckpt:
            ckpt.wait()
        if wd:
            wd.close()
    return state


def resume_or_init(make_state: Callable[[], TrainState], ckpt_dir: str,
                   shardings=None) -> TrainState:
    """Restore the latest committed checkpoint, else build fresh state."""
    template = jax.eval_shape(make_state)
    step = ckpt_lib.latest_step(ckpt_dir) if ckpt_dir else None
    if step is None:
        return make_state()
    zeros = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), template)
    return ckpt_lib.restore(ckpt_dir, zeros, step=step, shardings=shardings)