"""Fault tolerance: watchdog, restartable training, failure injection.

The 1000-node posture: node failures surface as (a) a hung collective (the
watchdog kills the step and the launcher restarts from the last committed
checkpoint), or (b) a clean process crash (the restart wrapper re-enters the
loop; checkpoint restore is elastic so the replacement topology may differ).
Straggler mitigation at the data layer lives in etl_runtime (reader timeout +
skip-and-refill); here we handle trainer-side hangs and crashes.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


class WatchdogTimeout(RuntimeError):
    pass


class Watchdog:
    """Arms a timer around each step; fires if a step exceeds the budget.

    On real hardware a hung all-reduce never returns — the watchdog thread
    raises in the coordinator so the launcher can tear down and restart.
    """

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._deadline: Optional[float] = None
        self._fired = threading.Event()
        self._lock = threading.Lock()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._stop = threading.Event()
        self._thread.start()

    def _run(self):
        while not self._stop.wait(0.05):
            with self._lock:
                dl = self._deadline
            if dl is not None and time.monotonic() > dl:
                self._fired.set()

    def arm(self):
        with self._lock:
            self._deadline = time.monotonic() + self.timeout_s
        self._fired.clear()

    def disarm(self):
        with self._lock:
            self._deadline = None

    def check(self):
        if self._fired.is_set():
            raise WatchdogTimeout(
                f"step exceeded {self.timeout_s}s watchdog budget")

    def close(self):
        self._stop.set()


@dataclass
class RestartStats:
    restarts: int = 0
    failures: list = field(default_factory=list)


def run_with_restarts(make_fn: Callable[[], Callable[[], None]],
                      max_restarts: int = 3,
                      retriable=(WatchdogTimeout, RuntimeError)) -> RestartStats:
    """Run fn() to completion, restarting after retriable failures.

    ``make_fn`` rebuilds the loop closure each attempt (fresh restore from the
    last committed checkpoint — the checkpoint/restart contract).
    """
    stats = RestartStats()
    attempt = 0
    while True:
        fn = make_fn()
        try:
            fn()
            return stats
        except retriable as e:
            stats.failures.append(repr(e))
            attempt += 1
            stats.restarts = attempt
            if attempt > max_restarts:
                raise
