"""EtlJob: the single session facade over compile → fit → streaming batches.

The paper's training-aware ETL abstraction (§3) ends at the trainer, not at
``Pipeline.compile()``: freshness, ordering, batching, sharding and overlap
are one contract.  ``EtlJob`` is that contract as an object — it owns the
whole lifecycle that launchers used to hand-wire::

    pipe = paper_pipeline("II", small_vocab=65536, batch_size=4096)
    src  = Source.columnar("/data/criteo").shard(host, n_hosts).rebatch(4096)
    job  = EtlJob(pipe, src, backend="pallas", mesh=mesh,
                  fit_source=Source.columnar("/data/criteo_sample"))
    job.fit()                      # learn vocab tables (projected fit read)
    with job.batches() as batches: # staged prefetching executor
        for packed in batches:
            state, m = train_step(state, packed)
    print(job.stats().stage_breakdown())

What the facade does for you:

- **compile**: a ``Pipeline`` template is compiled on first use with the
  job's ``backend``/``fuse``/``interpret`` knobs (an already-compiled
  pipeline is accepted as-is).
- **projection pushdown**: the planner exports the referenced-column set
  (``ExecutionPlan.referenced_columns``) and the job projects the Source to
  it, so a columnar dataset never materializes unreferenced columns; the
  fit phase is projected to the (smaller) vocab-fit closure.
- **overlapped fit ingest**: ``fit()`` drives the projected read through
  the executor's read stage (``SourcePrefetcher``), so the fused chunk
  build overlaps the next chunk's read instead of blocking on it
  (``fit_read_stats`` has the read-stage occupancy).
- **semantics overrides**: ``freshness=`` / ``ordering=`` replace the
  pipeline template's policies for this job without rebuilding the DAG.
- **executor lifecycle**: ``batches()`` starts the staged prefetching
  executor (credits, adaptive credits, mesh/sharding placement, straggler
  timeout) and tears it down on exit; ``stats()`` exposes the run's
  ``RuntimeStats``; ``metrics_file`` exports them as Prometheus text on
  close.

``etl_runtime.multitenant.PipelineManager`` composes one ``EtlJob`` per
tenant under a shared credit budget and a weighted round-robin transform
service.
"""

from __future__ import annotations

import contextlib
import dataclasses
import warnings
from typing import Callable, Optional

from repro.core.compiler import CompiledPipeline
from repro.core.pipeline import Pipeline
from repro.core.semantics import (FreshnessPolicy, OrderingPolicy,
                                  PipelineSemantics)
from repro.data.source import Source, as_source
from repro.etl_runtime.runtime import (RuntimeStats, SourcePrefetcher,
                                       StreamingExecutor, default_length_key)


class EtlJob:
    """One ETL session: ``(Pipeline, Source, overrides) -> batches``.

    Parameters
    ----------
    pipeline : a ``Pipeline`` template (compiled lazily with ``backend`` /
        ``fuse`` / ``optimize`` / ``interpret``) or an
        already-``CompiledPipeline``.  ``optimize="auto"`` (default) runs
        the relational optimizer before lowering — see
        ``EtlJob.optimize_report()``.
    source : the apply-phase ``Source`` (anything batch-yielding is coerced
        via ``Source.stream``); may be ``None`` for fit-/apply-only jobs.
    fit_source : Source for ``fit()`` when it differs from ``source``.
    freshness, ordering : per-job overrides of the pipeline's semantics.
    credits, adaptive_credits, max_credits, read_timeout_s, mesh, sharding,
    place, length_key, transform_service, clock : forwarded to the executor
        (see ``StreamingExecutor``).  ``adaptive_credits=True`` is
        deprecated — pass ``autotune=`` instead.
    autotune : ``True`` builds the measured-throughput
        ``PipelineController`` over the executor's runtime knobs; a
        ``PipelineController`` instance is bound as-is.  On the pallas
        backend the job additionally declares the compile-time knobs —
        planner ``row_tile`` and fuse on/off — whose actuator recompiles
        via ``CompiledPipeline.with_knobs`` (vocabulary state shared) and
        hot-swaps the executor's transform program.
    embed_cache : optional ``etl_runtime.lookahead.EmbedCacheConfig``; adds
        the lookahead prefetch stage to the executor (rows, window,
        per-table on/off) so delivered batches carry embedding-cache plans.
    rebatch : when True, rebatch the source to the batching policy's
        ``batch_size`` (decouples source shard geometry from the trainer).
    pushdown : when False, skip the automatic column projection.
    metrics_file : if set, write Prometheus-text stage stats here on close.
    metrics_labels : extra labels for the metrics export.
    """

    def __init__(self, pipeline, source=None, *,
                 backend: str = "jnp", fuse: str = "auto",
                 optimize: str = "auto",
                 interpret: Optional[bool] = None,
                 fit_source=None,
                 freshness: Optional[FreshnessPolicy] = None,
                 ordering: Optional[OrderingPolicy] = None,
                 credits: int = 2, adaptive_credits: bool = False,
                 max_credits: int = 8, autotune=None, clock=None,
                 read_timeout_s: float = 30.0,
                 mesh=None, sharding=None, place=None,
                 length_key: Callable = default_length_key,
                 transform_service=None, embed_cache=None,
                 rebatch: bool = False, pushdown: bool = True,
                 metrics_file: str = "", metrics_labels: Optional[dict] = None,
                 name: Optional[str] = None):
        self._template: Optional[Pipeline] = None
        self._compiled: Optional[CompiledPipeline] = None
        if isinstance(pipeline, Pipeline):
            self._template = pipeline
        elif callable(pipeline):
            # CompiledPipeline, or any raw->packed callable (tests, shims)
            self._compiled = pipeline
        else:
            raise TypeError("pipeline must be a Pipeline or a compiled "
                            f"apply program, got {type(pipeline).__name__}")
        self._backend = backend
        self._fuse = fuse
        self._optimize = optimize
        self._interpret = interpret
        self._source = as_source(source) if source is not None else None
        self._fit_source = (as_source(fit_source)
                            if fit_source is not None else None)
        self._freshness = freshness
        self._ordering = ordering
        if adaptive_credits and autotune is None:
            warnings.warn(
                "adaptive_credits=True is deprecated; pass autotune=True "
                "(or a PipelineController) for the unified knob controller",
                DeprecationWarning, stacklevel=2)
        self._autotune = autotune
        self._executor_kw = dict(
            credits=credits, adaptive_credits=adaptive_credits,
            max_credits=max_credits, read_timeout_s=read_timeout_s,
            mesh=mesh, sharding=sharding, place=place,
            length_key=length_key, transform_service=transform_service,
            lookahead=embed_cache, clock=clock)
        self._rebatch = rebatch
        self._pushdown = pushdown
        self.metrics_file = metrics_file
        self.metrics_labels = dict(metrics_labels or {})
        self.name = name or getattr(pipeline, "name", "etl-job")
        self._executor: Optional[StreamingExecutor] = None
        self._last_stats: Optional[RuntimeStats] = None
        self._fit_read_stats = None  # StageStats of the last fit read stage

    # ---- compile ---------------------------------------------------------

    @property
    def compiled(self) -> CompiledPipeline:
        """The compiled apply/fit program (compiles the template on first
        use)."""
        if self._compiled is None:
            self._compiled = self._template.compile(
                backend=self._backend, interpret=self._interpret,
                fuse=self._fuse, optimize=self._optimize)
        return self._compiled

    @property
    def semantics(self) -> Optional[PipelineSemantics]:
        """Pipeline semantics with this job's overrides applied."""
        base = getattr(self.compiled, "semantics", None)
        if base is None and self._template is not None:
            base = self._template.semantics
        if base is None:
            return None
        changes = {}
        if self._freshness is not None:
            changes["freshness"] = self._freshness
        if self._ordering is not None:
            changes["ordering"] = self._ordering
        return dataclasses.replace(base, **changes) if changes else base

    # ---- sources (projection pushdown) -----------------------------------

    def _project(self, src: Source, columns) -> Source:
        """Push a column set into a Source unless the user already
        projected (an explicit ``.columns`` spec wins) or supplied a host
        ``length_key`` — the key function may read columns the pipeline
        itself never references, so only an explicit projection narrows
        such a source."""
        if (not self._pushdown or src.spec.columns is not None
                or src.spec.length_key is not None):
            return src
        return src.columns(columns)

    def apply_source(self) -> Source:
        """The effective apply-phase Source: user spec + pushed projection
        (+ rebatch to the batching policy when requested)."""
        if self._source is None:
            raise ValueError("EtlJob has no source; pass one at construction")
        plan = getattr(self.compiled, "plan", None)
        src = self._source
        if plan is not None:
            src = self._project(src, plan.referenced_columns())
        sem = self.semantics
        if self._rebatch and sem is not None and src.spec.rebatch_rows is None:
            src = src.rebatch(sem.batching.batch_size,
                              drop_remainder=sem.batching.drop_remainder)
        return src

    # ---- fit -------------------------------------------------------------

    def fit(self, source=None, *, prefetch: bool = True):
        """Fit phase: learn vocabulary tables from ``source`` (default: the
        job's ``fit_source``, else its apply source), with the fit read
        projected to the vocab-fit closure's columns.

        The projected read runs through the staged executor's read stage
        (``SourcePrefetcher``): a background reader fills a credit-bounded
        queue while the (fused) chunk build consumes, so fit ingest overlaps
        the build instead of blocking on the reader.  ``prefetch=False``
        keeps the old inline iteration (debugging / deterministic traces);
        read-stage occupancy lands in ``fit_read_stats``.
        """
        src = source if source is not None else (self._fit_source
                                                 or self._source)
        plan = getattr(self.compiled, "plan", None)
        if src is None:
            if plan is None or not plan.vocab_fits:
                return self.compiled.fit(iter(()))  # stateless: bump version
            raise ValueError("fit requires a source (pipeline has vocabs)")
        if plan is not None and not plan.vocab_fits:
            return self.compiled.fit(iter(()))  # stateless: no read needed
        src = as_source(src)
        if plan is not None:
            src = self._project(src, plan.fit_referenced_columns())
        if not prefetch:
            return self.compiled.fit(iter(src))
        reader = SourcePrefetcher(
            src, credits=self._executor_kw["credits"],
            name=f"{self.name}-fit-read")
        try:
            state = self.compiled.fit(iter(reader))
        finally:
            reader.close()
            self._fit_read_stats = reader.stats
        return state

    # ---- apply (one-shot, bench/debug path) ------------------------------

    def apply(self, raw_batch: dict) -> dict:
        """Apply the compiled program to one raw batch (no executor)."""
        return self.compiled(raw_batch)

    # ---- executor lifecycle ----------------------------------------------

    def executor(self, transform=None) -> StreamingExecutor:
        """Build (without starting) the staged prefetching executor for this
        job's pipeline + effective source.  ``transform`` overrides the
        transform-stage callable while keeping the job's compiled semantics
        and every other knob — ``repro.online.OnlineTrainer`` wraps the
        compiled program to tag each batch with its vocabulary version."""
        autotune = self._autotune
        holder: dict = {"ex": None}
        if autotune and transform is None:
            autotune = self._autotune_controller(autotune, holder)
        ex = StreamingExecutor(transform or self.compiled,
                               self.apply_source(),
                               semantics=self.semantics,
                               autotune=autotune,
                               **self._executor_kw)
        holder["ex"] = ex
        return ex

    def _autotune_controller(self, autotune, holder: dict):
        """Normalize ``autotune=`` to a ``PipelineController``, declaring
        the job-level compile-time knobs (planner ``row_tile``, fuse
        on/off) when the compiled pipeline supports ``with_knobs`` (the
        pallas backend).  The actuator recompiles — vocabulary state
        shared, variants cached — and hot-swaps the executor's transform
        program; the executor then binds its own runtime knobs."""
        from repro.etl_runtime.controller import Knob, PipelineController
        ctl = (autotune if isinstance(autotune, PipelineController)
               else PipelineController([]))
        cp = self.compiled
        if not hasattr(cp, "with_knobs") or cp.backend != "pallas":
            return ctl
        have = {k.name for k in ctl.knobs}
        base_tile = cp.plan.row_tile
        cur = {"row_tile": base_tile, "fuse": cp.fuse_spec() != "off"}
        variants = {(base_tile, cur["fuse"]): cp}

        def swap():
            key = (cur["row_tile"], cur["fuse"])
            new = variants.get(key)
            if new is None:
                new = cp.with_knobs(row_tile=cur["row_tile"],
                                    fuse="auto" if cur["fuse"] else "off")
                variants[key] = new
            ex = holder["ex"]
            if ex is not None:
                ex.swap_pipeline(new)
                ex.stats.knobs["row_tile"] = cur["row_tile"]
                ex.stats.knobs["fuse"] = cur["fuse"]

        def apply_row_tile(v):
            cur["row_tile"] = int(v)
            swap()

        def apply_fuse(v):
            cur["fuse"] = bool(v)
            swap()

        if "row_tile" not in have:
            cands = tuple(sorted({64, 128, 256, 512, base_tile}))
            ctl.knobs.append(Knob("row_tile", cands, value=base_tile,
                                  apply=apply_row_tile, kind="compute"))
        if "fuse" not in have and cp.fuse_spec() != "off":
            ctl.knobs.append(Knob("fuse", (False, True), value=cur["fuse"],
                                  apply=apply_fuse, kind="compute"))
        return ctl

    def start(self) -> StreamingExecutor:
        if self._executor is None:
            self._executor = self.executor()
            self._executor.start()
        return self._executor

    @contextlib.contextmanager
    def batches(self):
        """Context manager over the job's batch stream: starts the staged
        executor, yields it (iterate for packed batches), and on exit stops
        the stages and writes the metrics file when configured."""
        ex = self.start()
        try:
            yield ex
        finally:
            self.close()

    def close(self) -> None:
        """Stop the executor (if running) and export metrics when asked."""
        if self._executor is not None:
            self._executor.stop()
            self._last_stats = self._executor.stats
            self._executor = None
        if self.metrics_file and self._last_stats is not None:
            self.write_metrics(self.metrics_file)

    def stop(self) -> None:
        self.close()

    def __enter__(self) -> StreamingExecutor:
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- observability ---------------------------------------------------

    def stats(self) -> Optional[RuntimeStats]:
        """RuntimeStats of the live executor, else the last finished run."""
        if self._executor is not None:
            return self._executor.stats
        return self._last_stats

    def write_metrics(self, path: str, *,
                      labels: Optional[dict] = None) -> None:
        from repro.etl_runtime import metrics as metrics_lib
        stats = self.stats()
        if stats is None:
            return
        all_labels = {**self.metrics_labels, **(labels or {})}
        metrics_lib.write_metrics_file(
            path, metrics_lib.stats_to_prometheus(stats, labels=all_labels))

    @property
    def state(self):
        """Vocabulary PipelineState of the compiled pipeline."""
        return self.compiled.state

    def lowering_report(self) -> dict:
        return self.compiled.lowering_report()

    def fit_lowering_report(self) -> dict:
        return self.compiled.fit_lowering_report()

    def optimize_report(self) -> dict:
        """What the relational optimizer did to the compiled plan (CSE /
        pushdown counts, DataflowGroups, per-output grouping decisions)."""
        return self.compiled.optimize_report()

    @property
    def fit_read_stats(self):
        """StageStats of the last ``fit()`` read stage (None before fit or
        with ``prefetch=False``): busy = source reads, wait_out = reader
        ahead of the build, wait_in = build waited on ingest."""
        return self._fit_read_stats
