"""Compiler: lowers an ExecutionPlan to an executable pipeline (paper §3.1/§3.4).

Three backends share identical semantics (tests enforce bit-equality):

- ``numpy``  : the CPU-baseline oracle (the paper's pandas path).
- ``jnp``    : XLA-jitted; stages are fused by XLA (the GPU/NVTabular analogue).
- ``pallas`` : each fused stage / vocab op / packer runs as an explicit Pallas
  kernel with BlockSpec VMEM tiling — the FPGA-dataflow analogue. The whole
  apply program is wrapped in one jit so a batch is a single device dispatch.

Vocabulary *fit* is streamed: chunked first-occurrence build (Pallas kernel or
jnp scatter-min), merged into a two-int32 global state, finalized into frozen
rank tables.  Tables are pipeline state, versioned for point-in-time
correctness, and passed to the apply program as arguments (no recompilation on
table refresh — the partial-reconfiguration analogue is a state swap).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as ops_lib
from repro.core.dag import NodeType
from repro.core.planner import (CrossStage, ExecutionPlan, FusedStage,
                                OneHotStage, PackOutput, VocabLookupStage)
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclasses.dataclass
class PipelineState:
    """Frozen vocabulary tables + version (freshness bookkeeping)."""

    tables: dict  # vocab_id -> int32[capacity]
    n_unique: dict  # vocab_id -> int (python int; also passed as scalar array)
    version: int = 0

    def as_args(self):
        keys = sorted(self.tables)
        return ([self.tables[k] for k in keys],
                [jnp.asarray(self.n_unique[k], jnp.int32) for k in keys], keys)


def _chain_fn(stage: FusedStage):
    """Code-generate the fused elementwise function for one stage."""
    ops_seq = list(stage.ops)
    hexw = stage.in_hex_width

    def chain(x):
        rest = ops_seq
        if hexw:
            if not isinstance(ops_seq[0], ops_lib.Hex2Int):
                raise TypeError("hex source must be consumed by Hex2Int first")
            x = kref.hex2int_digit_major(x)
            rest = ops_seq[1:]
        for op in rest:
            x = op.jnp_expr(x)
        return x

    return chain


def _chain_numpy(stage: FusedStage, x):
    ops_seq = list(stage.ops)
    if stage.in_hex_width:
        if not isinstance(ops_seq[0], ops_lib.Hex2Int):
            raise TypeError("hex source must be consumed by Hex2Int first")
        # numpy path uses trailing-hex layout [rows, cols, w]
        x = ops_seq[0].numpy(x)
        ops_seq = ops_seq[1:]
    for op in ops_seq:
        x = op.numpy(x)
    return x


class CompiledPipeline:
    """Executable ETL pipeline with fit/apply phases."""

    def __init__(self, plan: ExecutionPlan, graph, backend: str = "jnp", *,
                 interpret: Optional[bool] = None, name: str = "pipeline"):
        if backend not in ("numpy", "jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.plan = plan
        self.graph = graph
        self.backend = backend
        self.name = name
        self.interpret = kops.default_interpret() if interpret is None else interpret
        self.state = PipelineState(
            tables={vf.vocab_id: np.full(vf.capacity, -1, np.int32)
                    for vf in plan.vocab_fits},
            n_unique={vf.vocab_id: 0 for vf in plan.vocab_fits},
            version=0)
        self._source_nodes = {n.id: n for n in graph.nodes
                              if n.kind == NodeType.SOURCE}
        if backend != "numpy":
            self._apply_jit = jax.jit(self._build_apply())
            self._fit_chunk_jit = jax.jit(self._build_fit_chunk())

    # ------------------------------------------------------------------
    # source assembly: raw columnar batch -> source buffers
    # ------------------------------------------------------------------

    def _gather_sources(self, raw: dict) -> dict:
        """numpy backend: assemble column blocks on the host.

        jnp/pallas backends assemble INSIDE the jit (§Perf E1): the host-side
        np.stack/transpose of the hex columns cost ~1/3 of apply wall time;
        on device it fuses into the first kernel's read."""
        out = {}
        for buf in self.plan.source_buffers:
            node = self._source_nodes[buf]
            feats = node.features
            if feats[0].seq_len:  # token column: (rows, seq)
                out[buf] = np.asarray(raw[feats[0].name])
            elif feats[0].is_hex:
                cols = np.stack([np.asarray(raw[f.name]) for f in feats], axis=1)
                out[buf] = cols  # (rows, n, w)
            else:
                cols = [np.asarray(raw[f.name]) for f in feats]
                out[buf] = np.stack(cols, axis=1)
        return out

    def _raw_columns(self, raw: dict) -> dict:
        """Pass-through of the raw columns needed by the source buffers."""
        cols = {}
        for buf in self.plan.source_buffers:
            for f in self._source_nodes[buf].features:
                cols[f.name] = np.asarray(raw[f.name])
        return cols

    def _assemble_sources_jnp(self, cols: dict) -> dict:
        """Device-side source assembly (traced; part of the jit program)."""
        out = {}
        for buf in self.plan.source_buffers:
            node = self._source_nodes[buf]
            feats = node.features
            if feats[0].seq_len:
                out[buf] = cols[feats[0].name]
            elif feats[0].is_hex:
                stacked = jnp.stack([cols[f.name] for f in feats], axis=1)
                out[buf] = jnp.moveaxis(stacked, -1, 0)  # digit-major
            else:
                out[buf] = jnp.stack([cols[f.name] for f in feats], axis=1)
        return out

    # ------------------------------------------------------------------
    # stage interpreters
    # ------------------------------------------------------------------

    def _run_stages_numpy(self, bufs: dict, stage_ids=None) -> dict:
        for s in self.plan.stages:
            if stage_ids is not None and s.stage_id not in stage_ids:
                continue
            if isinstance(s, FusedStage):
                bufs[s.out_buf] = _chain_numpy(s, bufs[s.in_buf])
            elif isinstance(s, CrossStage):
                bufs[s.out_buf] = s.op.numpy2(bufs[s.in_a], bufs[s.in_b])
            elif isinstance(s, OneHotStage):
                bufs[s.out_buf] = s.op.numpy(bufs[s.in_buf])
            elif isinstance(s, VocabLookupStage):
                tbl = self.state.tables[s.vocab_id]
                vm = ops_lib.VocabMap(s.capacity)
                bufs[s.out_buf] = vm.numpy_apply(bufs[s.in_buf], tbl)
            else:
                raise NotImplementedError(type(s))
        return bufs

    def _stage_fns(self) -> dict:
        """Per-stage jnp/pallas callables keyed by stage_id."""
        fns = {}
        for s in self.plan.stages:
            if isinstance(s, FusedStage):
                chain = _chain_fn(s)
                if self.backend == "pallas":
                    fns[s.stage_id] = kops.fused_stage(
                        chain, in_dtype=s.in_dtype, out_dtype=s.out_dtype,
                        hex_width=s.in_hex_width,
                        block_rows=32 * s.lanes,
                        block_cols=4 * s.vector_width,
                        interpret=self.interpret)
                else:
                    fns[s.stage_id] = chain
            elif isinstance(s, CrossStage):
                fns[s.stage_id] = s.op.jnp_expr2
            elif isinstance(s, OneHotStage):
                fns[s.stage_id] = s.op.jnp_expr
            elif isinstance(s, VocabLookupStage):
                parts = 1 if s.placement == "vmem" else max(
                    1, (4 * s.capacity) // (4 << 20))
                if self.backend == "pallas":
                    def mk(parts=parts):
                        def f(x, tbl, n):
                            return kops.vocab_lookup(x, tbl, n, partitions=parts,
                                                     interpret=self.interpret)
                        return f
                    fns[s.stage_id] = mk()
                else:
                    fns[s.stage_id] = kref.vocab_lookup
        return fns

    def _build_apply(self) -> Callable:
        plan = self.plan
        fns = self._stage_fns()
        packers = {}
        if self.backend == "pallas":
            for po in plan.pack:
                widths = [plan.buffers[b].width for b in po.buffers]
                dts = [plan.buffers[b].dtype for b in po.buffers]
                packers[po.name] = kops.packer(
                    widths, dts, po.dtype, pad_cols_to=po.pad_cols_to,
                    interpret=self.interpret)

        def apply_fn(tables, n_uniques, cols):
            bufs = dict(self._assemble_sources_jnp(cols))
            for s in plan.stages:
                if isinstance(s, FusedStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, CrossStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_a], bufs[s.in_b])
                elif isinstance(s, OneHotStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, VocabLookupStage):
                    bufs[s.out_buf] = fns[s.stage_id](
                        bufs[s.in_buf], tables[s.vocab_id],
                        n_uniques[s.vocab_id])
            out = {}
            for po in plan.pack:
                blocks = [bufs[b] for b in po.buffers]
                if self.backend == "pallas" and not po.squeeze:
                    out[po.name] = packers[po.name](*blocks)
                else:
                    packed = kref.pack_blocks(blocks, po.dtype, po.pad_cols_to)
                    out[po.name] = packed[:, 0] if po.squeeze else packed
            return out

        return apply_fn

    def _build_fit_chunk(self) -> Callable:
        """One streamed fit chunk: run upstream stages, build chunk first-pos."""
        plan = self.plan
        fns = self._stage_fns()
        fit_ids = set(plan.fit_stage_ids)
        builds = {}
        for vf in plan.vocab_fits:
            parts = 1 if vf.placement == "vmem" else max(
                1, (4 * vf.capacity) // (4 << 20))
            if self.backend == "pallas":
                def mk(vf=vf, parts=parts):
                    def f(vals):
                        return kops.vocab_build_chunk(
                            vals, capacity=vf.capacity, partitions=parts,
                            interpret=self.interpret)
                    return f
                builds[vf.vocab_id] = mk()
            else:
                builds[vf.vocab_id] = (
                    lambda vals, vf=vf: kref.vocab_build_chunk(vals, vf.capacity))

        def fit_chunk(cols):
            bufs = dict(self._assemble_sources_jnp(cols))
            for s in plan.stages:
                if s.stage_id not in fit_ids:
                    continue
                if isinstance(s, FusedStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, CrossStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_a], bufs[s.in_b])
                elif isinstance(s, OneHotStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, VocabLookupStage):
                    raise AssertionError("lookup cannot precede fit")
            out = {}
            for vf in plan.vocab_fits:
                vals = bufs[vf.in_buf].reshape(-1)
                # first-occurrence positions + counts (frequency filter)
                out[vf.vocab_id] = (builds[vf.vocab_id](vals),
                                    kref.vocab_counts_chunk(vals, vf.capacity))
            return out

        return fit_chunk

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def fit(self, batch_iter) -> PipelineState:
        """Stream batches; learn vocabulary tables (paper's fit phase)."""
        if not self.plan.vocab_fits:
            self.state = dataclasses.replace(self.state,
                                             version=self.state.version + 1)
            return self.state
        if self.backend == "numpy":
            gens = {vf.vocab_id: ops_lib.VocabGen(vf.capacity,
                                                  min_count=vf.min_count)
                    for vf in self.plan.vocab_fits}
            states = {vid: g.init_state() for vid, g in gens.items()}
            offset = 0
            for raw in batch_iter:
                bufs = self._gather_sources(raw)
                bufs = self._run_stages_numpy(bufs,
                                              set(self.plan.fit_stage_ids))
                n_elems = 0
                for vf in self.plan.vocab_fits:
                    vals = bufs[vf.in_buf].reshape(-1)
                    n_elems = max(n_elems, vals.size)
                    states[vf.vocab_id] = gens[vf.vocab_id].update(
                        states[vf.vocab_id], vals, offset)
                offset += n_elems
            tables = {vid: gens[vid].finalize(st) for vid, st in states.items()}
        else:
            states = {vf.vocab_id: kref.vocab_state_init(vf.capacity)
                      for vf in self.plan.vocab_fits}
            mincounts = {vf.vocab_id: vf.min_count
                         for vf in self.plan.vocab_fits}
            for ci, raw in enumerate(batch_iter):
                sources = {k: jnp.asarray(v)
                           for k, v in self._raw_columns(raw).items()}
                chunk_fps = self._fit_chunk_jit(sources)
                for vid, (fp, cnt) in chunk_fps.items():
                    states[vid] = kref.vocab_merge(states[vid], fp, ci,
                                                   chunk_counts=cnt)
            tables = {vid: np.asarray(kref.vocab_finalize(
                          st, min_count=mincounts[vid]))
                      for vid, st in states.items()}
        n_unique = {vid: ops_lib.VocabGen.n_unique(t)
                    for vid, t in tables.items()}
        self.state = PipelineState(tables=tables, n_unique=n_unique,
                                   version=self.state.version + 1)
        return self.state

    def __call__(self, raw_batch: dict) -> dict:
        """Apply phase: raw columnar batch -> packed training-ready tensors."""
        if self.backend == "numpy":
            sources = self._gather_sources(raw_batch)
            bufs = self._run_stages_numpy(dict(sources))
            out = {}
            for po in self.plan.pack:
                blocks = [bufs[b] for b in po.buffers]
                rows = blocks[0].shape[0]
                cat = np.concatenate(
                    [np.asarray(b, dtype=po.dtype).reshape(rows, -1)
                     for b in blocks], axis=1)
                padded = -(-cat.shape[1] // po.pad_cols_to) * po.pad_cols_to
                if padded != cat.shape[1]:
                    cat = np.pad(cat, ((0, 0), (0, padded - cat.shape[1])))
                out[po.name] = cat[:, 0] if po.squeeze else cat
            return out
        tables = {vid: jnp.asarray(t) for vid, t in self.state.tables.items()}
        n_uniq = {vid: jnp.asarray(n, jnp.int32)
                  for vid, n in self.state.n_unique.items()}
        cols = {k: jnp.asarray(v) for k, v in self._raw_columns(raw_batch).items()}
        return self._apply_jit(tables, n_uniq, cols)

    # stats used by benchmarks / Table-4 analogue
    def resource_summary(self) -> dict:
        return self.plan.resource_summary()
