"""Compiler: lowers an ExecutionPlan to an executable pipeline (paper §3.1/§3.4).

Three backends share identical semantics (tests enforce bit-equality):

- ``numpy``  : the CPU-baseline oracle (the paper's pandas path).
- ``jnp``    : XLA-jitted; stages are fused by XLA (the GPU/NVTabular analogue).
- ``pallas`` : the streaming-dataflow analogue of the paper's FPGA pipeline.

Plans are rewritten by ``core/optimizer.optimize_plan`` before lowering
(``optimize="auto"``, the default): cross-output CSE, dead-stage pushdown,
and ``DataflowGroup`` formation.  The rewrite applies to every backend, so
the three-backend bit-equality invariant also pins optimized semantics;
``optimize="off"`` compiles the planner's plan verbatim.

The pallas backend then has three lowerings, chosen per ``PackOutput`` —
the fallback ladder is grouped → fused → staged:

- **grouped** (``fuse="auto"`` + ``optimize="auto"``): every
  ``DataflowGroup`` the optimizer proved legal lowers to ONE row-tiled
  streaming kernel emitting ALL member outputs' packed blocks per tile
  (``kernels/dataflow.make_group_dataflow``); stages shared across member
  outputs execute once per tile instead of once per output.
- **fused** (``fuse="auto"``): every legal ungrouped output lowers to ONE
  row-tiled streaming kernel (``kernels/dataflow.make_output_dataflow``).
  Raw column blocks stream through VMEM; the fused elementwise chains, hex
  decode, vocab rank-lookup and one-hot expansion execute per-tile as stages
  of a single kernel body; results land at their static lane offsets of the
  packed output.  No intermediate HBM tensors, no separate packer pass —
  this is the paper's "operators connected by on-chip FIFOs with a
  format-aware packer" as one ``pallas_call`` per output.
- **staged** (fallback, or ``fuse="off"``): each fused stage / vocab op /
  packer runs as its own Pallas kernel with full HBM materialization in
  between — the NVTabular-style baseline the paper argues against, kept both
  as the legality escape hatch (HBM-resident tables, oversized tiles,
  unknown stage kinds) and as the measurable comparison point for
  ``benchmarks/bench_pipelines.py``.

Either way the whole apply program is wrapped in one jit so a batch is a
single device dispatch, and the numpy/jnp oracles are untouched — the
three-backend bit-equality invariant pins fused and staged semantics alike.

The pallas kernels run interpret (CPU validation) or compiled
(Mosaic/Triton) per the ONE flag resolved here: ``interpret=None`` asks
``kernels.backend.default_interpret`` (capability-based), the resolved
bool re-judges fusion legality for the compiled lowering's VMEM extra
(``reason_kind="mosaic-illegal"`` fallback, never a crash) and is handed
to every kernel — kernels never re-resolve it.

Vocabulary *fit* is streamed: chunked first-occurrence build, merged into a
two-int32 global state, finalized into frozen rank tables.  On the pallas
backend the fit chunk has the same two lowerings as apply, chosen per
``VocabFit`` from the plan's ``FitProgram`` nodes:

- **fused** (``fuse="auto"``): every legal vocab lowers its whole fit chunk
  — upstream chains, hex decode, and the first-occurrence + count build — to
  ONE row-tiled streaming kernel (``kernels/dataflow.make_fit_dataflow``);
  no intermediate HBM tensors between the upstream stages and the build.
- **staged** (fallback, or ``fuse="off"``): upstream stages run as separate
  kernels with HBM materialization, then ``kernels/vocab.vocab_build_chunk``
  builds the first-pos table (HBM-placed capacities always take this path —
  the fused kernel's accumulators are VMEM-resident).

Chunk results are merged identically either way, so ``PipelineState`` is
bit-identical across lowerings (tests pin this).  Tables are pipeline state,
versioned for point-in-time correctness, and passed to the apply program as
arguments (no recompilation on table refresh — the partial-reconfiguration
analogue is a state swap).  For fused outputs the OOV rule is folded into the
table once per table version (cached host-side; O(capacity) at fit/swap time,
nothing per batch), so the in-kernel lookup is a pure gather.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as ops_lib
from repro.core.dag import NodeType
from repro.core.optimizer import optimize_plan
from repro.core.planner import (CrossStage, DataflowGroup, DataflowProgram,
                                ExecutionPlan, FitProgram, FusedStage,
                                OneHotStage, PackOutput, VocabLookupStage,
                                build_plan_programs)
from repro.kernels import lanes
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels.dataflow import (GroupOutput, StreamInput, TableInput,
                                    TileStep)


def count_pallas_calls(jaxpr) -> int:
    """Count ``pallas_call`` equations in a (Closed)Jaxpr, nested included.

    Used by tests to assert the fused lowering really issues a single
    streaming kernel per PackOutput.
    """
    inner = getattr(jaxpr, "jaxpr", jaxpr)
    n = 0
    for eqn in inner.eqns:
        if eqn.primitive.name == "pallas_call":
            n += 1
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                if hasattr(sub, "eqns") or hasattr(sub, "jaxpr"):
                    n += count_pallas_calls(sub)
    return n


@dataclasses.dataclass
class PipelineState:
    """Frozen vocabulary tables + version (freshness bookkeeping)."""

    tables: dict  # vocab_id -> int32[capacity]
    n_unique: dict  # vocab_id -> int (python int; also passed as scalar array)
    version: int = 0

    def as_args(self):
        keys = sorted(self.tables)
        return ([self.tables[k] for k in keys],
                [jnp.asarray(self.n_unique[k], jnp.int32) for k in keys], keys)


def _chain_fn(stage: FusedStage):
    """Code-generate the fused elementwise function for one stage."""
    ops_seq = list(stage.ops)
    hexw = stage.in_hex_width

    def chain(x):
        rest = ops_seq
        if hexw:
            if not isinstance(ops_seq[0], ops_lib.Hex2Int):
                raise TypeError("hex source must be consumed by Hex2Int first")
            x = kref.hex2int_digit_major(x)
            rest = ops_seq[1:]
        for op in rest:
            x = op.jnp_expr(x)
        return x

    return chain


def _chain_numpy(stage: FusedStage, x):
    ops_seq = list(stage.ops)
    if stage.in_hex_width:
        if not isinstance(ops_seq[0], ops_lib.Hex2Int):
            raise TypeError("hex source must be consumed by Hex2Int first")
        # numpy path uses trailing-hex layout [rows, cols, w]
        x = ops_seq[0].numpy(x)
        ops_seq = ops_seq[1:]
    for op in ops_seq:
        x = op.numpy(x)
    return x


class CompiledPipeline:
    """Executable ETL pipeline with fit/apply phases."""

    def __init__(self, plan: ExecutionPlan, graph, backend: str = "jnp", *,
                 interpret: Optional[bool] = None, name: str = "pipeline",
                 fuse: str = "auto", optimize: str = "auto", semantics=None):
        if backend not in ("numpy", "jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        # fuse: "auto" / "off", or a per-output spec — a set/sequence of
        # output names to force STAGED (the controller's per-output fuse
        # knob), or a {output: bool} dict (False = staged)
        fuse_off: frozenset = frozenset()
        if isinstance(fuse, dict):
            fuse_off = frozenset(k for k, v in fuse.items() if not v)
            fuse = "auto"
        elif isinstance(fuse, (set, frozenset, list, tuple)):
            fuse_off = frozenset(fuse)
            fuse = "auto"
        elif fuse not in ("auto", "off"):
            raise ValueError(f"unknown fuse mode {fuse!r}")
        self._fuse_off = fuse_off
        if optimize not in ("auto", "off"):
            raise ValueError(f"unknown optimize mode {optimize!r}")
        # resolve the ONE interpret flag first: fusion legality depends on it
        # (the compiled lowering's lane-padding / gather scratch shrinks what
        # fits the VMEM budget), so it must be settled before any legality
        # rebuild below — kernels never re-resolve, they are handed this flag
        self.interpret = (kops.default_interpret() if interpret is None
                          else bool(interpret))
        if backend == "pallas" and not self.interpret and not plan.compiled_mode:
            # re-judge every fusion slice for the compiled lowering; slices
            # legal in interpret mode but over the compiled budget fall back
            # staged with reason_kind "mosaic-illegal" (never a crash)
            plan = dataclasses.replace(
                plan, dataflows=[], fit_dataflows=[], groups=[],
                opt_info=dict(plan.opt_info))
            build_plan_programs(plan, compiled=True)
        if optimize == "auto":
            # plan-level rewrite (CSE + pushdown + grouping); applied for
            # every backend so numpy/jnp/pallas stay bit-identical over the
            # SAME rewritten plan — the optimizer equivalence property then
            # pins optimize="auto" against "off" across backends.  The
            # rewrite preserves plan.compiled_mode, so regrouping keeps
            # judging merged slices with the mode resolved above.
            plan = optimize_plan(plan)
        self.plan = plan
        self.graph = graph
        self.backend = backend
        self.name = name
        self.fuse = fuse
        self.optimize = optimize
        # the template's PipelineSemantics ride along so the runtime (and
        # EtlJob) see the declared freshness/ordering/batching contract
        self.semantics = semantics
        # per-output fused programs: only the pallas backend has a tile
        # codegen; jnp relies on XLA fusion and numpy is the oracle
        self._fused_programs: dict[str, DataflowProgram] = {}
        self._fused_fit_programs: dict[str, FitProgram] = {}
        # multi-output fused dataflows: groups the optimizer proved legal,
        # active only where the fused tile codegen is (pallas + fuse=auto)
        self._active_groups: list[DataflowGroup] = []
        self._grouped_outputs: dict[str, int] = {}
        if backend == "pallas" and fuse == "auto":
            self._fused_programs = {dp.output: dp for dp in plan.dataflows
                                    if dp.legal
                                    and dp.output not in self._fuse_off}
            self._fused_fit_programs = {fp.vocab_id: fp
                                        for fp in plan.fit_dataflows
                                        if fp.legal}
            self._active_groups = [g for g in plan.groups
                                   if all(o in self._fused_programs
                                          for o in g.outputs)]
            self._grouped_outputs = {o: gi
                                     for gi, g in enumerate(self._active_groups)
                                     for o in g.outputs}
        self.state = PipelineState(
            tables={vf.vocab_id: np.full(vf.capacity, -1, np.int32)
                    for vf in plan.vocab_fits},
            n_unique={vf.vocab_id: 0 for vf in plan.vocab_fits},
            version=0)
        self._source_nodes = {n.id: n for n in graph.nodes
                              if n.kind == NodeType.SOURCE}
        self._resolved_cache: tuple = (-1, {})
        self._staged_cache: tuple = (-1, ({}, {}))
        self._staged_vocab_ids: list[str] = []
        # fit closure source buffers, computed once (used by all fit paths)
        self._fit_bufs = plan.fit_source_buffers()
        if backend != "numpy":
            self._apply_fn = self._build_apply()
            self._apply_jit = jax.jit(self._apply_fn)
            self._fit_chunk_fn = self._build_fit_chunk()
            self._fit_chunk_jit = jax.jit(self._fit_chunk_fn)

    # ------------------------------------------------------------------
    # knob recompilation (the controller's row_tile / fuse actuator)
    # ------------------------------------------------------------------

    def fuse_spec(self):
        """The current fuse setting in ``with_knobs``-compatible form:
        ``"off"``, ``"auto"``, or the frozenset of staged-forced outputs."""
        if self.fuse == "off":
            return "off"
        return frozenset(self._fuse_off) if self._fuse_off else "auto"

    def with_knobs(self, *, row_tile: Optional[int] = None, fuse=None):
        """Recompile this pipeline at new knob settings, SHARING vocabulary
        state with the original.

        ``row_tile`` retiles every fused kernel (legality is re-judged at
        the new tile — a tile that no longer fits the VMEM budget falls
        back staged, never crashes); ``fuse`` takes the same forms as the
        constructor ("auto"/"off"/per-output spec).  Omitted knobs keep
        their current values.  The returned pipeline aliases ``self.state``
        — tables fitted on either are visible to both, so a mid-run swap
        (``StreamingExecutor.swap_pipeline``) is bit-identical to a fresh
        compile at the same settings (pinned by tests/test_controller.py).
        """
        new_tile = (self.plan.row_tile if row_tile is None
                    else max(1, int(row_tile)))
        new_fuse = self.fuse_spec() if fuse is None else fuse
        # re-judge all fusion programs from scratch at the new tile; the
        # constructor re-resolves compiled-mode legality (and re-optimizes)
        # exactly as a fresh compile would
        plan = dataclasses.replace(
            self.plan, dataflows=[], fit_dataflows=[], groups=[],
            opt_info={}, compiled_mode=False, row_tile=new_tile)
        build_plan_programs(plan)
        new = CompiledPipeline(plan, self.graph, self.backend,
                               interpret=self.interpret, name=self.name,
                               fuse=new_fuse, optimize=self.optimize,
                               semantics=self.semantics)
        new.state = self.state
        return new

    # ------------------------------------------------------------------
    # source assembly: raw columnar batch -> source buffers
    # ------------------------------------------------------------------

    def _gather_sources(self, raw: dict, buffers=None) -> dict:
        """numpy backend: assemble column blocks on the host.

        jnp/pallas backends assemble INSIDE the jit (§Perf E1): the host-side
        np.stack/transpose of the hex columns cost ~1/3 of apply wall time;
        on device it fuses into the first kernel's read."""
        out = {}
        for buf in (self.plan.source_buffers if buffers is None else buffers):
            node = self._source_nodes[buf]
            feats = node.features
            if feats[0].seq_len:  # token column: (rows, seq)
                out[buf] = np.asarray(raw[feats[0].name])
            elif feats[0].is_hex:
                cols = np.stack([np.asarray(raw[f.name]) for f in feats], axis=1)
                out[buf] = cols  # (rows, n, w)
            else:
                cols = [np.asarray(raw[f.name]) for f in feats]
                out[buf] = np.stack(cols, axis=1)
        return out

    def _raw_columns(self, raw: dict, buffers=None) -> dict:
        """Pass-through of the raw columns needed by the source buffers."""
        cols = {}
        for buf in (self.plan.source_buffers if buffers is None else buffers):
            for f in self._source_nodes[buf].features:
                cols[f.name] = np.asarray(raw[f.name])
        return cols

    def _assemble_sources_jnp(self, cols: dict, buffers=None) -> dict:
        """Device-side source assembly (traced; part of the jit program)."""
        out = {}
        for buf in (self.plan.source_buffers if buffers is None else buffers):
            node = self._source_nodes[buf]
            feats = node.features
            if feats[0].seq_len:
                out[buf] = cols[feats[0].name]
            elif feats[0].is_hex:
                stacked = jnp.stack([cols[f.name] for f in feats], axis=1)
                out[buf] = jnp.moveaxis(stacked, -1, 0)  # digit-major
            else:
                out[buf] = jnp.stack([cols[f.name] for f in feats], axis=1)
        return out

    # ------------------------------------------------------------------
    # stage interpreters
    # ------------------------------------------------------------------

    def _run_stages_numpy(self, bufs: dict, stage_ids=None,
                          state: Optional[PipelineState] = None) -> dict:
        # state is an explicit snapshot so one batch never mixes two
        # vocabulary versions when an online refit swaps self.state mid-run
        state = self.state if state is None else state
        for s in self.plan.stages:
            if stage_ids is not None and s.stage_id not in stage_ids:
                continue
            if isinstance(s, FusedStage):
                bufs[s.out_buf] = _chain_numpy(s, bufs[s.in_buf])
            elif isinstance(s, CrossStage):
                bufs[s.out_buf] = s.op.numpy2(bufs[s.in_a], bufs[s.in_b])
            elif isinstance(s, OneHotStage):
                bufs[s.out_buf] = s.op.numpy(bufs[s.in_buf])
            elif isinstance(s, VocabLookupStage):
                tbl = state.tables[s.vocab_id]
                vm = ops_lib.VocabMap(s.capacity)
                bufs[s.out_buf] = vm.numpy_apply(bufs[s.in_buf], tbl)
            else:
                raise NotImplementedError(type(s))
        return bufs

    def _stage_fns(self, needed_ids: Optional[set] = None) -> dict:
        """Per-stage jnp/pallas callables keyed by stage_id.

        ``needed_ids`` restricts codegen to the stages the staged path will
        actually run (fused outputs bypass per-stage kernels entirely).
        """
        fns = {}
        for s in self.plan.stages:
            if needed_ids is not None and s.stage_id not in needed_ids:
                continue
            if isinstance(s, FusedStage):
                chain = _chain_fn(s)
                if self.backend == "pallas":
                    fns[s.stage_id] = kops.fused_stage(
                        chain, in_dtype=s.in_dtype, out_dtype=s.out_dtype,
                        hex_width=s.in_hex_width,
                        block_rows=32 * s.lanes,
                        block_cols=4 * s.vector_width,
                        interpret=self.interpret)
                else:
                    fns[s.stage_id] = chain
            elif isinstance(s, CrossStage):
                fns[s.stage_id] = s.op.jnp_expr2
            elif isinstance(s, OneHotStage):
                fns[s.stage_id] = s.op.jnp_expr
            elif isinstance(s, VocabLookupStage):
                parts = 1 if s.placement == "vmem" else max(
                    1, (4 * s.capacity) // (4 << 20))
                if self.backend == "pallas":
                    def mk(parts=parts):
                        def f(x, tbl, n):
                            return kops.vocab_lookup(x, tbl, n, partitions=parts,
                                                     interpret=self.interpret)
                        return f
                    fns[s.stage_id] = mk()
                else:
                    fns[s.stage_id] = kref.vocab_lookup
        return fns

    def _build_dataflow_fn(self, po: PackOutput, dp: DataflowProgram):
        """Lower one legal DataflowProgram to its single streaming kernel."""
        plan = self.plan
        inputs = [StreamInput(b, plan.buffers[b].width, plan.buffers[b].dtype,
                              plan.buffers[b].hex_width)
                  for b in dp.source_buffers]
        steps, tables = self._dataflow_steps(dp.stage_ids, dp.vocab_ids)
        terminals = [(b, plan.buffers[b].width) for b in po.buffers]
        return kops.output_dataflow(inputs, tables, steps, terminals,
                                    po.dtype, pad_cols_to=po.pad_cols_to,
                                    block_rows=plan.row_tile,
                                    interpret=self.interpret)

    def _dataflow_steps(self, stage_ids, vocab_ids):
        """TileStep program + TableInput list for an apply-side slice
        (lookup steps resolved against the slice's vocab table order)."""
        tbl_index = {vid: i for i, vid in enumerate(vocab_ids)}
        tables: list = [None] * len(vocab_ids)
        steps = []
        for sid in stage_ids:
            s = self.plan.stage_by_id(sid)
            if isinstance(s, VocabLookupStage):
                idx = tbl_index[s.vocab_id]
                tables[idx] = TableInput(s.vocab_id, s.capacity)
                steps.append(TileStep("lookup", s.out_buf, (s.in_buf,),
                                      table=idx))
            else:
                steps.extend(self._tile_steps([sid]))
        return steps, tables

    def _build_group_fn(self, group: DataflowGroup):
        """Lower one DataflowGroup to its single multi-output kernel."""
        plan = self.plan
        inputs = [StreamInput(b, plan.buffers[b].width, plan.buffers[b].dtype,
                              plan.buffers[b].hex_width)
                  for b in group.source_buffers]
        steps, tables = self._dataflow_steps(group.stage_ids, group.vocab_ids)
        outs = []
        for name in group.outputs:
            po = next(p for p in plan.pack if p.name == name)
            outs.append(GroupOutput(
                name, tuple((b, plan.buffers[b].width) for b in po.buffers),
                po.dtype, po.pad_cols_to))
        return kops.group_dataflow(inputs, tables, steps, outs,
                                   block_rows=plan.row_tile,
                                   interpret=self.interpret)

    def _tile_steps(self, stage_ids) -> list[TileStep]:
        """Shared TileStep codegen for the fused apply/fit kernel bodies
        (lookup steps are resolved by the apply-side caller)."""
        steps: list[TileStep] = []
        for sid in stage_ids:
            s = self.plan.stage_by_id(sid)
            if isinstance(s, FusedStage):
                steps.append(TileStep("map", s.out_buf, (s.in_buf,),
                                      fn=_chain_fn(s)))
            elif isinstance(s, CrossStage):
                steps.append(TileStep("join", s.out_buf, (s.in_a, s.in_b),
                                      fn=s.op.jnp_expr2))
            elif isinstance(s, OneHotStage):
                # lane-aligned in-kernel form: same values as op.jnp_expr,
                # but without the trailing-axis reshape Mosaic rejects
                steps.append(TileStep(
                    "map", s.out_buf, (s.in_buf,),
                    fn=(lambda x, d=s.op.depth: lanes.onehot_lanes(x, d))))
            else:  # pragma: no cover - legality passes reject these
                raise NotImplementedError(type(s))
        return steps

    def _build_fit_dataflow_fn(self, fp: FitProgram):
        """Lower one legal FitProgram to its single streaming fit kernel."""
        plan = self.plan
        inputs = [StreamInput(b, plan.buffers[b].width, plan.buffers[b].dtype,
                              plan.buffers[b].hex_width)
                  for b in fp.source_buffers]
        steps = self._tile_steps(fp.stage_ids)
        # partition the first-pos/count accumulators across the grid (the
        # vocab-build HBM-bank pattern) once a single lane-padded block
        # would be large: ~64K entries per partition keeps each (1, part)
        # accumulator pair ~512 KiB of VMEM
        partitions = max(1, -(-fp.capacity // 65536))
        return kops.fit_dataflow(inputs, steps, fp.in_buf, fp.capacity,
                                 partitions=partitions,
                                 block_rows=plan.row_tile,
                                 interpret=self.interpret)

    def _build_apply(self) -> Callable:
        plan = self.plan
        fused = self._fused_programs
        staged_pos = [po for po in plan.pack if po.name not in fused]
        if fused:
            staged_ids: set = set()
            for po in staged_pos:
                staged_ids.update(plan.output_slice(po))
        else:
            staged_ids = {s.stage_id for s in plan.stages}
        # raw tables only reach the device for staged lookups; fully fused
        # vocabularies travel solely as their cached OOV-resolved form
        self._staged_vocab_ids = sorted(
            s.vocab_id for s in plan.stages
            if isinstance(s, VocabLookupStage) and s.stage_id in staged_ids)
        dfmap = {dp.output: dp for dp in plan.dataflows}
        fns = self._stage_fns(staged_ids)
        grouped = self._grouped_outputs
        group_fns = [self._build_group_fn(g) for g in self._active_groups]
        dataflows = {name: self._build_dataflow_fn(
                         next(po for po in plan.pack if po.name == name), dp)
                     for name, dp in fused.items() if name not in grouped}
        packers = {}
        if self.backend == "pallas":
            for po in staged_pos:
                widths = [plan.buffers[b].width for b in po.buffers]
                dts = [plan.buffers[b].dtype for b in po.buffers]
                packers[po.name] = kops.packer(
                    widths, dts, po.dtype, pad_cols_to=po.pad_cols_to,
                    block_rows=plan.row_tile,
                    interpret=self.interpret)

        def apply_fn(tables, n_uniques, resolved, cols):
            bufs = dict(self._assemble_sources_jnp(cols))
            for s in plan.stages:
                if s.stage_id not in staged_ids:
                    continue
                if isinstance(s, FusedStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, CrossStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_a], bufs[s.in_b])
                elif isinstance(s, OneHotStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, VocabLookupStage):
                    bufs[s.out_buf] = fns[s.stage_id](
                        bufs[s.in_buf], tables[s.vocab_id],
                        n_uniques[s.vocab_id])
            # each DataflowGroup issues ONE kernel for all member outputs;
            # shared stages execute once per tile for the whole group
            gout = {}
            for g, gfn in zip(self._active_groups, group_fns):
                args = ([bufs[b] for b in g.source_buffers]
                        + [resolved[vid] for vid in g.vocab_ids])
                for name, packed in zip(g.outputs, gfn(*args)):
                    gout[name] = packed
            out = {}
            for po in plan.pack:
                dp = dfmap.get(po.name)
                if po.name in gout:
                    packed = gout[po.name]
                    out[po.name] = packed[:, 0] if po.squeeze else packed
                    continue
                if po.name in fused:
                    args = ([bufs[b] for b in dp.source_buffers]
                            + [resolved[vid] for vid in dp.vocab_ids])
                    packed = dataflows[po.name](*args)
                    out[po.name] = packed[:, 0] if po.squeeze else packed
                    continue
                blocks = [bufs[b] for b in po.buffers]
                if self.backend == "pallas" and not po.squeeze:
                    out[po.name] = packers[po.name](*blocks)
                else:
                    packed = kref.pack_blocks(blocks, po.dtype, po.pad_cols_to)
                    out[po.name] = packed[:, 0] if po.squeeze else packed
            return out

        return apply_fn

    def _build_fit_chunk(self) -> Callable:
        """One streamed fit chunk: chunk first-occurrence positions + counts.

        Legally-fused vocabs (pallas backend, ``fuse="auto"``) run their
        whole chunk — upstream chains, hex decode, and the build — as ONE
        streaming kernel (``kernels/dataflow.make_fit_dataflow``), with no
        HBM tensor between upstream stages and ``vocab_build_chunk``.  The
        rest take the staged path (per-stage kernels, then the build kernel),
        restricted to exactly the stages the staged vocabs still need.
        """
        plan = self.plan
        fused_fit = self._fused_fit_programs
        staged_vfs = [vf for vf in plan.vocab_fits
                      if vf.vocab_id not in fused_fit]
        if fused_fit:
            staged_ids: set = set()
            for vf in staged_vfs:
                staged_ids.update(plan.fit_slice(vf))
        else:
            staged_ids = set(plan.fit_stage_ids)
        fns = self._stage_fns(staged_ids)
        fit_kernels = {vid: self._build_fit_dataflow_fn(fp)
                       for vid, fp in fused_fit.items()}
        builds = {}
        for vf in staged_vfs:
            parts = 1 if vf.placement == "vmem" else max(
                1, (4 * vf.capacity) // (4 << 20))
            if self.backend == "pallas":
                def mk(vf=vf, parts=parts):
                    def f(vals):
                        return kops.vocab_build_chunk(
                            vals, capacity=vf.capacity, partitions=parts,
                            interpret=self.interpret)
                    return f
                builds[vf.vocab_id] = mk()
            else:
                builds[vf.vocab_id] = (
                    lambda vals, vf=vf: kref.vocab_build_chunk(vals, vf.capacity))

        fit_bufs = self._fit_bufs

        def fit_chunk(cols):
            bufs = dict(self._assemble_sources_jnp(cols, fit_bufs))
            for s in plan.stages:
                if s.stage_id not in staged_ids:
                    continue
                if isinstance(s, FusedStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, CrossStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_a], bufs[s.in_b])
                elif isinstance(s, OneHotStage):
                    bufs[s.out_buf] = fns[s.stage_id](bufs[s.in_buf])
                elif isinstance(s, VocabLookupStage):
                    raise AssertionError("lookup cannot precede fit")
            out = {}
            for vf in plan.vocab_fits:
                if vf.vocab_id in fit_kernels:
                    fp = fused_fit[vf.vocab_id]
                    out[vf.vocab_id] = fit_kernels[vf.vocab_id](
                        *(bufs[b] for b in fp.source_buffers))
                    continue
                vals = bufs[vf.in_buf].reshape(-1)
                # first-occurrence positions + counts (frequency filter)
                out[vf.vocab_id] = (builds[vf.vocab_id](vals),
                                    kref.vocab_counts_chunk(vals, vf.capacity))
            return out

        return fit_chunk

    # ------------------------------------------------------------------
    # public API
    # ------------------------------------------------------------------

    def fit(self, batch_iter) -> PipelineState:
        """Stream batches; learn vocabulary tables (paper's fit phase)."""
        if not self.plan.vocab_fits:
            self.state = dataclasses.replace(self.state,
                                             version=self.state.version + 1)
            return self.state
        tables, n_unique = self._fit_tables(batch_iter)
        self.state = PipelineState(tables=tables, n_unique=n_unique,
                                   version=self.state.version + 1)
        return self.state

    def fit_incremental(self, batch_iter) -> PipelineState:
        """Online vocabulary refresh over a window of NEW events.

        Unlike ``fit`` (which rebuilds the tables from scratch), this merges
        the window into the current state **rank-stably**: every value the
        pipeline already admitted keeps its rank — so embedding rows learned
        by a live trainer keep their meaning across the swap — and values
        first seen in the window are appended in first-occurrence order at
        ranks ``n_unique ..``.  The frequency filter (``min_count``) applies
        per window.  The swap is a single attribute store of a fresh
        ``PipelineState`` with a version bump, so concurrent apply calls
        (which snapshot the state once per batch) are each served by exactly
        one version, and the per-version resolved/staged table caches refresh
        automatically.
        """
        cur = self.state
        if not self.plan.vocab_fits:
            self.state = dataclasses.replace(cur, version=cur.version + 1)
            return self.state
        win_tables, _ = self._fit_tables(batch_iter)
        tables, n_unique = {}, {}
        for vid, wt in win_tables.items():
            base = np.asarray(cur.tables[vid])
            n = int(cur.n_unique[vid])
            wt = np.asarray(wt)
            new_vals = np.flatnonzero((wt >= 0) & (base < 0))
            order = np.argsort(wt[new_vals], kind="stable")
            merged = base.copy()
            merged[new_vals[order]] = n + np.arange(len(new_vals),
                                                    dtype=np.int32)
            tables[vid] = merged
            n_unique[vid] = n + int(len(new_vals))
        self.state = PipelineState(tables=tables, n_unique=n_unique,
                                   version=cur.version + 1)
        return self.state

    def _fit_tables(self, batch_iter) -> tuple:
        """Run the (fused) chunked fit machinery over ``batch_iter`` and
        return ``(tables, n_unique)`` without touching ``self.state``."""
        if self.backend == "numpy":
            gens = {vf.vocab_id: ops_lib.VocabGen(vf.capacity,
                                                  min_count=vf.min_count)
                    for vf in self.plan.vocab_fits}
            states = {vid: g.init_state() for vid, g in gens.items()}
            offset = 0
            fit_bufs = self._fit_bufs
            for raw in batch_iter:
                bufs = self._gather_sources(raw, fit_bufs)
                bufs = self._run_stages_numpy(bufs,
                                              set(self.plan.fit_stage_ids))
                n_elems = 0
                for vf in self.plan.vocab_fits:
                    vals = bufs[vf.in_buf].reshape(-1)
                    n_elems = max(n_elems, vals.size)
                    states[vf.vocab_id] = gens[vf.vocab_id].update(
                        states[vf.vocab_id], vals, offset)
                offset += n_elems
            tables = {vid: gens[vid].finalize(st) for vid, st in states.items()}
        else:
            states = {vf.vocab_id: kref.vocab_state_init(vf.capacity)
                      for vf in self.plan.vocab_fits}
            mincounts = {vf.vocab_id: vf.min_count
                         for vf in self.plan.vocab_fits}
            fit_bufs = self._fit_bufs
            for ci, raw in enumerate(batch_iter):
                sources = {k: jnp.asarray(v)
                           for k, v in self._raw_columns(raw, fit_bufs).items()}
                chunk_fps = self._fit_chunk_jit(sources)
                for vid, (fp, cnt) in chunk_fps.items():
                    states[vid] = kref.vocab_merge(states[vid], fp, ci,
                                                   chunk_counts=cnt)
            tables = {vid: np.asarray(kref.vocab_finalize(
                          st, min_count=mincounts[vid]))
                      for vid, st in states.items()}
        n_unique = {vid: ops_lib.VocabGen.n_unique(t)
                    for vid, t in tables.items()}
        return tables, n_unique

    def _resolved_tables(self, state: Optional[PipelineState] = None) -> dict:
        """OOV-resolved (1, capacity) tables for the fused kernels' gathers:
        table'[v] = rank if present else n_unique.  Computed once per state
        version — tables only change at fit/swap time, so the apply hot path
        never pays the O(capacity) fold per batch."""
        state = self.state if state is None else state
        fused_vids = {vid for dp in self._fused_programs.values()
                      for vid in dp.vocab_ids}
        if not fused_vids:
            return {}
        ver, cached = self._resolved_cache
        if ver == state.version:
            return cached
        resolved = {}
        for vid in sorted(fused_vids):
            t = np.asarray(state.tables[vid])
            n = state.n_unique[vid]
            resolved[vid] = jnp.asarray(
                np.where(t >= 0, t, n).astype(np.int32).reshape(1, -1))
        self._resolved_cache = (state.version, resolved)
        return resolved

    def _staged_table_args(self, state: Optional[PipelineState] = None) -> tuple:
        """Device-resident raw tables + n_unique scalars for the staged
        lookups only, uploaded once per state version (fully fused
        vocabularies never ship their raw table to the apply program)."""
        state = self.state if state is None else state
        ver, cached = self._staged_cache
        if ver == state.version:
            return cached
        tables = {vid: jnp.asarray(state.tables[vid])
                  for vid in self._staged_vocab_ids}
        n_uniq = {vid: jnp.asarray(state.n_unique[vid], jnp.int32)
                  for vid in self._staged_vocab_ids}
        self._staged_cache = (state.version, (tables, n_uniq))
        return tables, n_uniq

    def apply_versioned(self, raw_batch: dict) -> tuple:
        """Apply one batch against a single state snapshot and return
        ``(packed, version)`` — the snapshot is read exactly once, so a
        concurrent ``fit_incremental`` swap can never serve one batch a mix
        of two vocabulary versions, and the caller learns which version
        transformed the batch (``repro.online`` tags delivered batches
        with it)."""
        state = self.state
        if self.backend == "numpy":
            sources = self._gather_sources(raw_batch)
            bufs = self._run_stages_numpy(dict(sources), state=state)
            out = {}
            for po in self.plan.pack:
                blocks = [bufs[b] for b in po.buffers]
                rows = blocks[0].shape[0]
                cat = np.concatenate(
                    [np.asarray(b, dtype=po.dtype).reshape(rows, -1)
                     for b in blocks], axis=1)
                padded = -(-cat.shape[1] // po.pad_cols_to) * po.pad_cols_to
                if padded != cat.shape[1]:
                    cat = np.pad(cat, ((0, 0), (0, padded - cat.shape[1])))
                out[po.name] = cat[:, 0] if po.squeeze else cat
            return out, state.version
        tables, n_uniq = self._staged_table_args(state)
        cols = {k: jnp.asarray(v) for k, v in self._raw_columns(raw_batch).items()}
        return (self._apply_jit(tables, n_uniq, self._resolved_tables(state),
                                cols), state.version)

    def __call__(self, raw_batch: dict) -> dict:
        """Apply phase: raw columnar batch -> packed training-ready tensors."""
        return self.apply_versioned(raw_batch)[0]

    def referenced_columns(self) -> list:
        """Raw columns the apply program reads (projection-pushdown set)."""
        return self.plan.referenced_columns()

    # stats used by benchmarks / Table-4 analogue
    def resource_summary(self) -> dict:
        return self.plan.resource_summary()

    def optimize_report(self) -> dict:
        """What the optimizer pass did to the compiled plan (see
        ``ExecutionPlan.optimize_report``); ``optimized=False`` with zero
        counts when compiled with ``optimize="off"``."""
        return self.plan.optimize_report()

    def lowering_report(self) -> dict:
        """Per-output lowering decision: grouped / fused / staged.

        Keys are PackOutput names; ``path`` is "grouped" (member of a
        multi-output fused dataflow — ``group`` lists the members sharing
        the kernel), "fused" (own single streaming kernel) or "staged".
        For staged outputs ``reason`` says what fell back and
        ``reason_kind`` classifies *why*: "budget" (VMEM working set),
        "stage-kind" (no tile codegen for a stage), "hbm-table"
        (HBM-resident vocab), "hex-terminal", "mosaic-illegal" (fits the
        logical budget but not the compiled lowering's lane-padded /
        gather-scratch one — interpret mode would fuse it), or "" when
        the backend/fuse mode simply has no tile codegen.
        """
        dfmap = {dp.output: dp for dp in self.plan.dataflows}
        groups = {name: self._active_groups[gi]
                  for name, gi in self._grouped_outputs.items()}
        rep = {}
        for po in self.plan.pack:
            dp = dfmap.get(po.name)
            if po.name in groups:
                path = "grouped"
            elif po.name in self._fused_programs:
                path = "fused"
            else:
                path = "staged"
            rep[po.name] = {
                "path": path,
                "group": list(groups[po.name].outputs)
                         if po.name in groups else [],
                "legal": dp.legal if dp else False,
                "reason": dp.reason if dp else "no dataflow program planned",
                "reason_kind": dp.reason_kind if dp else "",
                "n_stages": dp.n_stages if dp else 0,
                "vocab_ids": list(dp.vocab_ids) if dp else [],
            }
        return rep

    def fit_lowering_report(self) -> dict:
        """Per-vocab fit lowering decision: fused single-kernel vs staged.

        Keys are vocab ids; ``path`` is "fused" or "staged"; for staged
        vocabs ``reason`` says what fell back and ``reason_kind``
        classifies why (same taxonomy as ``lowering_report``; "" means the
        backend/fuse mode simply has no fit tile codegen).
        """
        fpmap = {fp.vocab_id: fp for fp in self.plan.fit_dataflows}
        rep = {}
        for vf in self.plan.vocab_fits:
            fp = fpmap.get(vf.vocab_id)
            rep[vf.vocab_id] = {
                "path": ("fused" if vf.vocab_id in self._fused_fit_programs
                         else "staged"),
                "legal": fp.legal if fp else False,
                "reason": fp.reason if fp else "no fit program planned",
                "reason_kind": fp.reason_kind if fp else "",
                "n_stages": fp.n_stages if fp else 0,
                "placement": vf.placement,
            }
        return rep

    def stage_execution_counts(self, phase: str = "apply") -> dict:
        """Static per-batch execution count for every plan stage.

        Derived from the lowering decisions (kernel bodies only run at
        trace time under jit, so dynamic counters cannot observe this):
        a stage on the staged path executes once per batch regardless of
        consumer count; a stage in k solo fused kernels re-executes k
        times (once per kernel body); a stage in a DataflowGroup executes
        exactly once for the whole group — the acceptance check that
        shared prefixes run once per batch under the grouped lowering.
        """
        if phase not in ("apply", "fit"):
            raise ValueError(f"unknown phase {phase!r}")
        plan = self.plan
        if phase == "fit":
            counts = {sid: 0 for sid in plan.fit_stage_ids}
            staged_ids: set = set()
            for vf in plan.vocab_fits:
                if vf.vocab_id not in self._fused_fit_programs:
                    staged_ids.update(plan.fit_slice(vf))
            for sid in staged_ids:
                counts[sid] += 1
            for fp in self._fused_fit_programs.values():
                for sid in fp.stage_ids:
                    counts[sid] += 1
            return counts
        counts = {s.stage_id: 0 for s in plan.stages}
        staged_ids = set()
        for po in plan.pack:
            if po.name not in self._fused_programs:
                staged_ids.update(plan.output_slice(po))
        for sid in staged_ids:
            counts[sid] += 1
        for g in self._active_groups:
            for sid in g.stage_ids:
                counts[sid] += 1
        for name, dp in self._fused_programs.items():
            if name in self._grouped_outputs:
                continue
            for sid in dp.stage_ids:
                counts[sid] += 1
        return counts

    def traced_pallas_call_count(self, raw_batch: dict,
                                 phase: str = "apply") -> int:
        """Number of pallas_call primitives a phase's program traces to.

        ``phase="apply"``: the grouped lowering traces one streaming kernel
        per DataflowGroup plus one per solo fused output — strictly fewer
        calls than outputs whenever grouping engaged (the acceptance
        invariant); the ungrouped fused lowering traces exactly one call
        per output; the staged lowering traces one call per stage plus one
        per packer.  ``phase="fit"``: the fused fit chunk traces one call
        per legally-fused vocab (plus the staged kernels of any fallback
        vocab).
        """
        if phase not in ("apply", "fit"):
            raise ValueError(f"unknown phase {phase!r}")
        if self.backend == "numpy":
            return 0
        if phase == "fit":
            cols = {k: jnp.asarray(v) for k, v in
                    self._raw_columns(raw_batch, self._fit_bufs).items()}
            jaxpr = jax.make_jaxpr(self._fit_chunk_fn)(cols)
            return count_pallas_calls(jaxpr)
        tables, n_uniq = self._staged_table_args()
        cols = {k: jnp.asarray(v)
                for k, v in self._raw_columns(raw_batch).items()}
        jaxpr = jax.make_jaxpr(self._apply_fn)(tables, n_uniq,
                                               self._resolved_tables(), cols)
        return count_pallas_calls(jaxpr)
