"""Training-aware ETL semantics: freshness, ordering, batching (paper §1, §3).

These policies are part of the pipeline contract and are enforced by the
streaming runtime (etl_runtime/runtime.py):

- BatchingPolicy : emitted batch geometry (the packer pads/aligns to it).
- FreshnessPolicy: bound on batch staleness; with continuous training the
  runtime drops batches older than ``max_staleness_batches`` behind the
  trainer instead of feeding stale data (time-to-freshness over completeness).
- OrderingPolicy : fifo (point-in-time order preserved, the default —
  required for online recommenders) or bucket_by_length (LM efficiency mode;
  trades strict arrival order inside a bounded reorder window).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class BatchingPolicy:
    batch_size: int
    drop_remainder: bool = True
    # pack/pad row count to a multiple (TPU sublane alignment)
    align_rows_to: int = 8

    def __post_init__(self):
        if self.batch_size <= 0:
            raise ValueError("batch_size must be positive")


@dataclasses.dataclass(frozen=True)
class FreshnessPolicy:
    # maximum number of batches a packed batch may wait before the trainer
    # consumes it; 0 disables the bound (offline mode)
    max_staleness_batches: int = 0

    @property
    def online(self) -> bool:
        return self.max_staleness_batches > 0


@dataclasses.dataclass(frozen=True)
class OrderingPolicy:
    kind: str = "fifo"  # "fifo" | "bucket_by_length"
    reorder_window: int = 0  # batches; only for bucket_by_length

    def __post_init__(self):
        if self.kind not in ("fifo", "bucket_by_length"):
            raise ValueError(f"unknown ordering {self.kind!r}")
        if self.kind == "fifo" and self.reorder_window:
            raise ValueError("fifo ordering cannot have a reorder window")
        if self.kind == "bucket_by_length" and self.reorder_window < 2:
            raise ValueError("bucket_by_length needs reorder_window >= 2 "
                             "(a smaller window cannot reorder anything)")


@dataclasses.dataclass(frozen=True)
class PipelineSemantics:
    batching: BatchingPolicy
    freshness: FreshnessPolicy = FreshnessPolicy()
    ordering: OrderingPolicy = OrderingPolicy()
