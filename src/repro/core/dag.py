"""Symbolic ETL DAG built from the Python template interface (paper Fig 5).

Users compose pipelines over *column groups* (columnar processing): a node
produces a block of shape [rows, width] (or [rows, width, hex_width] for raw
hex sources).  Stateless operators apply elementwise over the block; stateful
vocabulary operators attach shared state; ``cross`` joins two blocks.

The DAG is purely symbolic — no data moves until the planner/compiler lowers
it into an ExecutionPlan (see planner.py / compiler.py).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import operators as ops_lib
from repro.core.schema import Schema, FeatureSpec


@dataclasses.dataclass
class NodeType:
    SOURCE = "source"
    OP = "op"
    CROSS = "cross"
    VOCAB = "vocab"


class Node:
    """One vertex of the symbolic DAG."""

    _counter = 0

    def __init__(self, kind: str, *, graph: "Graph", parents: tuple["Node", ...] = (),
                 op: Optional[ops_lib.Operator] = None,
                 features: Optional[list[FeatureSpec]] = None,
                 group_kind: str = ""):
        Node._counter += 1
        self.id = f"n{Node._counter}"
        self.kind = kind
        self.graph = graph
        self.parents = parents
        self.op = op
        self.features = features or []
        self.group_kind = group_kind
        graph.nodes.append(self)
        # dtype/width propagation
        if kind == NodeType.SOURCE:
            f0 = self.features[0]
            self.dtype = np.dtype(np.uint8) if f0.is_hex else f0.raw_dtype()
            self.width = (self.features[0].seq_len or 1) if f0.seq_len else len(self.features)
            self.hex_width = f0.hex_width
        elif kind == NodeType.CROSS:
            a, b = parents
            if a.width != b.width:
                raise ValueError(f"cross: width mismatch {a.width} vs {b.width}")
            op.validate(a.dtype)
            op.validate(b.dtype)
            self.dtype = np.dtype(np.int32)
            self.width = a.width
            self.hex_width = 0
        else:
            (p,) = parents
            op.validate(p.dtype)
            self.dtype = op.out_dtype(p.dtype)
            self.width = p.width * op.width_factor()
            self.hex_width = 0

    def __or__(self, op: ops_lib.Operator) -> "Node":
        """``node | Operator()`` chains a transform."""
        if isinstance(op, Vocab):
            return op._attach(self)
        if isinstance(op, (ops_lib.VocabGen, ops_lib.VocabMap)):
            raise TypeError("use the Vocab(...) sugar; VocabGen/VocabMap are "
                            "planned as a fit/apply pair")
        if not isinstance(op, ops_lib.Operator):
            raise TypeError(f"expected Operator, got {type(op)}")
        return Node(NodeType.OP, graph=self.graph, parents=(self,), op=op,
                    group_kind=self.group_kind)

    def __repr__(self):
        o = self.op.name if self.op else ",".join(f.name for f in self.features[:3])
        return f"<{self.kind}:{self.id} {o} w={self.width} {self.dtype}>"


class Vocab:
    """Sugar: plans into VocabGen (fit phase) + VocabMap (apply phase).

    ``node | Vocab(capacity)`` — the paper's Fig 5 pattern where VocabGen's
    keyed reduction builds the table and VocabMap performs keyed lookups
    against the frozen, partitioned table.
    """

    def __init__(self, capacity: int, min_count: int = 1):
        self.capacity = capacity
        self.min_count = min_count

    def _attach(self, parent: Node) -> Node:
        gen = ops_lib.VocabGen(capacity=self.capacity,
                               min_count=self.min_count)
        node = Node(NodeType.VOCAB, graph=parent.graph, parents=(parent,),
                    op=gen, group_kind=parent.group_kind)
        node.vocab_map = ops_lib.VocabMap(capacity=self.capacity)
        node.dtype = np.dtype(np.int32)
        node.width = parent.width
        return node


class Graph:
    """Holds every node created under one Pipeline."""

    def __init__(self, schema: Schema):
        self.schema = schema
        self.nodes: list[Node] = []

    # --- sources -------------------------------------------------------

    def source(self, pattern: str) -> Node:
        feats = self.schema.select(pattern)
        kinds = {f.kind for f in feats}
        if len(kinds) != 1:
            raise ValueError(f"pattern {pattern!r} mixes feature kinds {kinds}")
        hexw = {f.hex_width for f in feats}
        if len(hexw) != 1:
            raise ValueError(f"pattern {pattern!r} mixes hex widths")
        seqs = {f.seq_len for f in feats}
        if len(seqs) != 1 or (seqs != {0} and len(feats) != 1):
            raise ValueError("token (sequence) sources must select a single column")
        return Node(NodeType.SOURCE, graph=self, features=feats,
                    group_kind=feats[0].kind)

    def cross(self, a: Node, b: Node, m: int) -> Node:
        return Node(NodeType.CROSS, graph=self, parents=(a, b),
                    op=ops_lib.Cartesian(m=m), group_kind="sparse")

    # --- traversal helpers ----------------------------------------------

    def topo_order(self, sinks: list[Node]) -> list[Node]:
        seen: dict[str, Node] = {}
        order: list[Node] = []

        def visit(n: Node):
            if n.id in seen:
                return
            seen[n.id] = n
            for p in n.parents:
                visit(p)
            order.append(n)

        for s in sinks:
            visit(s)
        return order
