"""User-facing Python template interface for pipeline composition (paper §3.4).

Example (the paper's Pipeline II on the Criteo schema), driven through the
session facade — the pipeline declares *what* to compute, a ``Source``
declares *what to read*, and ``EtlJob`` owns the compile → fit → streaming
lifecycle (projection is pushed into the Source automatically)::

    from repro.data.source import Source
    from repro.session import EtlJob

    p = Pipeline(Schema.criteo_kaggle(), batch_size=65536)
    d = p.dense("dense_*") | Clamp(0.0) | Logarithm()
    s = p.sparse("sparse_*") | Hex2Int(8) | Modulus(8192) | Vocab(8192)
    p.output("dense", [d], dtype=np.float32, pad_cols_to=128)
    p.output("sparse", [s], dtype=np.int32, pad_cols_to=128)
    p.output("label", [p.label("label")], dtype=np.float32, squeeze=True)

    src = Source.columnar("/data/criteo").rebatch(65536)
    job = EtlJob(p, src, backend="pallas",
                 fit_source=Source.columnar("/data/criteo_sample"))
    job.fit()                       # fit phase: learn vocab tables
    with job.batches() as batches:  # apply phase, overlapped with training
        for packed in batches:
            state, metrics = train_step(state, packed)

The low-level path (``compiled = p.compile(...); compiled.fit(...);
compiled(raw_batch)``) remains available for kernel-level work.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

from repro.core.compiler import CompiledPipeline
from repro.core.dag import Graph, Node, Vocab  # noqa: F401 (re-export Vocab)
from repro.core.operators import (Bucketize, Clamp, FillMissing, Hex2Int,  # noqa: F401
                                  Logarithm, Modulus, OneHot, SigridHash)
from repro.core.planner import Planner
from repro.core.schema import Schema
from repro.core.semantics import (BatchingPolicy, FreshnessPolicy,
                                  OrderingPolicy, PipelineSemantics)


class Pipeline:
    def __init__(self, schema: Schema, *, name: str = "pipeline",
                 batch_size: int = 65536,
                 freshness: Optional[FreshnessPolicy] = None,
                 ordering: Optional[OrderingPolicy] = None):
        self.schema = schema
        self.name = name
        self.graph = Graph(schema)
        self._outputs: list[tuple] = []
        self.semantics = PipelineSemantics(
            batching=BatchingPolicy(batch_size),
            freshness=freshness or FreshnessPolicy(),
            ordering=ordering or OrderingPolicy())

    # --- sources ---------------------------------------------------------

    def dense(self, pattern: str) -> Node:
        return self._source(pattern, "dense")

    def sparse(self, pattern: str) -> Node:
        return self._source(pattern, "sparse")

    def label(self, pattern: str) -> Node:
        return self._source(pattern, "label")

    def tokens(self, pattern: str) -> Node:
        return self._source(pattern, "token")

    def _source(self, pattern: str, kind: str) -> Node:
        node = self.graph.source(pattern)
        if node.group_kind != kind:
            raise TypeError(f"pattern {pattern!r} selects {node.group_kind} "
                            f"features, not {kind}")
        return node

    def cross(self, a: Node, b: Node, m: int) -> Node:
        return self.graph.cross(a, b, m)

    # --- sinks -----------------------------------------------------------

    def output(self, name: str, nodes: list[Node], *, dtype=np.float32,
               pad_cols_to: int = 1, squeeze: bool = False) -> None:
        if any(o[0] == name for o in self._outputs):
            raise ValueError(f"duplicate output {name!r}")
        self._outputs.append((name, list(nodes), np.dtype(dtype),
                              int(pad_cols_to), bool(squeeze)))

    # --- compile ----------------------------------------------------------

    def compile(self, backend: str = "jnp", *, interpret: Optional[bool] = None,
                vmem_budget: int = 4 << 20, lanes: int = 8,
                vector_width: int = 128, fuse="auto",
                optimize: str = "auto",
                row_tile: Optional[int] = None) -> CompiledPipeline:
        """Lower the DAG.  ``optimize="auto"`` runs the relational optimizer
        (cross-output CSE, dead-stage pushdown, multi-output grouping) over
        the plan first; ``optimize="off"`` compiles the planner's plan
        verbatim — outputs are bit-identical either way.  ``fuse="auto"``
        (pallas backend) lowers each ``DataflowGroup`` / legal output to a
        single streaming dataflow kernel; ``fuse="off"`` forces the
        stage-at-a-time lowering (the measurable baseline); a set or
        ``{output: bool}`` dict forces just those outputs staged (the
        controller's per-output fuse knob).

        ``row_tile`` sets the fused kernels' row-tile granularity (default
        ``planner.DATAFLOW_BLOCK_ROWS``); legality is judged at that tile,
        and ``CompiledPipeline.with_knobs`` retunes it later without
        refitting.

        ``interpret=None`` (default) resolves by backend capability
        (``kernels.backend.default_interpret``): compiled Pallas on
        TPU/GPU, interpret mode elsewhere.  The resolved flag threads
        through planner legality, lowering, and every kernel — both modes
        produce bit-identical outputs."""
        if not self._outputs:
            raise ValueError("pipeline has no outputs; call .output(...)")
        planner_kw = {} if row_tile is None else {"row_tile": row_tile}
        planner = Planner(self.graph, vmem_budget=vmem_budget, lanes=lanes,
                          vector_width=vector_width, **planner_kw)
        plan = planner.plan(self._outputs)
        return CompiledPipeline(plan, self.graph, backend,
                                interpret=interpret, name=self.name,
                                fuse=fuse, optimize=optimize,
                                semantics=self.semantics)


# ---------------------------------------------------------------------------
# The paper's three evaluation pipelines (§4.1.3, Fig 9)
# ---------------------------------------------------------------------------

def paper_pipeline(which: str, schema: Optional[Schema] = None, *,
                   modulus: int = 65536, small_vocab: int = 8192,
                   large_vocab: int = 524288, batch_size: int = 65536,
                   fill_missing: bool = True, min_count: int = 1) -> Pipeline:
    """Pipeline I (stateless), II (small vocab), III (large vocab).

    ``fill_missing`` imputes NaN dense values first (Table-1 operator; the
    industrial pipeline cleans before Clamp/Log).  Sparse missing values
    (all-zero hex) map to INT_MISSING and are bounded by Modulus like any id.
    """
    schema = schema or Schema.criteo_kaggle()
    p = Pipeline(schema, name=f"pipeline_{which}", batch_size=batch_size)
    d = p.dense("dense_*")
    if fill_missing:
        d = d | FillMissing(0.0)
    d = d | Clamp(0.0) | Logarithm()
    n_hex = schema.select("sparse_*")[0].hex_width
    # the vocab capacity IS the range of the upstream Modulus (paper §3.2.2)
    if which == "I":
        s = p.sparse("sparse_*") | Hex2Int(n_hex) | Modulus(modulus)
    elif which == "II":
        s = (p.sparse("sparse_*") | Hex2Int(n_hex) | Modulus(small_vocab)
             | Vocab(small_vocab, min_count=min_count))
    elif which == "III":
        s = (p.sparse("sparse_*") | Hex2Int(n_hex) | Modulus(large_vocab)
             | Vocab(large_vocab, min_count=min_count))
    else:
        raise ValueError(f"unknown paper pipeline {which!r}")
    # §Perf E3: minimal aligned pads (13 dense -> 16, 26 sparse -> 32)
    # instead of blanket 128 — the packed batch is 4x smaller and the packer
    # stays sublane-aligned; trainers read cfg-declared padded widths.
    p.output("dense", [d], dtype=np.float32, pad_cols_to=16)
    p.output("sparse", [s], dtype=np.int32, pad_cols_to=32)
    p.output("label", [p.label("label")], dtype=np.float32, squeeze=True)
    return p


def lm_token_pipeline(seq_len: int, vocab_size: int, *, batch_size: int = 256
                      ) -> Pipeline:
    """Streaming event-log -> LM token batch pipeline.

    Raw event ids are bounded into the model's vocab with SigridHash (the
    training-aware path the paper's abstraction generalizes to; the packer
    emits the exact (batch, seq) int32 layout train_step declares).
    """
    schema = Schema.lm_events(seq_len)
    p = Pipeline(schema, name="lm_tokens", batch_size=batch_size)
    t = p.tokens("tokens_raw") | SigridHash(vocab_size)
    lbl = p.label("label")
    p.output("tokens", [t], dtype=np.int32, pad_cols_to=1)
    p.output("labels", [lbl], dtype=np.int32, pad_cols_to=1)
    return p
