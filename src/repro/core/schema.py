"""Feature schema for training-aware ETL pipelines.

The schema is the contract between raw columnar data, the operator DAG, and the
format-aware packer.  It mirrors PipeRec's schema-validation step: every pipeline
is validated against the schema before planning (paper §3.1 step 1), and the
planner uses dtype/shape metadata to verify operator type constraints.

Feature kinds
-------------
- ``dense``  : float32 scalar per row (user age, price, ...).
- ``sparse`` : high-cardinality categorical.  Raw encoding is either a
  fixed-width ASCII-hex string (``hex_width`` bytes, Criteo style) or an int32.
- ``label``  : training target (float32 for CTR, int32 for LM tokens).
- ``token``  : raw token-id column for LM trainers (int32 per row position).
"""

from __future__ import annotations

import dataclasses
import fnmatch
from typing import Iterable

import numpy as np

DenseKind = "dense"
SparseKind = "sparse"
LabelKind = "label"
TokenKind = "token"

_VALID_KINDS = (DenseKind, SparseKind, LabelKind, TokenKind)


@dataclasses.dataclass(frozen=True)
class FeatureSpec:
    """One column of the raw dataset."""

    name: str
    kind: str
    # Raw on-disk dtype.
    dtype: str = "float32"
    # For sparse hex-string columns: number of ASCII chars (8 -> 32-bit value).
    hex_width: int = 0
    # For token columns: sequence length per row (0 = scalar column).
    seq_len: int = 0

    def __post_init__(self):
        if self.kind not in _VALID_KINDS:
            raise ValueError(f"unknown feature kind {self.kind!r} for {self.name!r}")
        if self.kind == SparseKind and self.hex_width not in (0, 4, 8, 16):
            raise ValueError(f"unsupported hex_width {self.hex_width} for {self.name!r}")

    @property
    def is_hex(self) -> bool:
        return self.kind == SparseKind and self.hex_width > 0

    def raw_shape(self, n_rows: int) -> tuple:
        if self.is_hex:
            return (n_rows, self.hex_width)
        if self.seq_len:
            return (n_rows, self.seq_len)
        return (n_rows,)

    def raw_dtype(self) -> np.dtype:
        if self.is_hex:
            return np.dtype(np.uint8)
        return np.dtype(self.dtype)


class Schema:
    """Ordered collection of FeatureSpecs with glob selection."""

    def __init__(self, features: Iterable[FeatureSpec]):
        self.features = list(features)
        self._by_name = {f.name: f for f in self.features}
        if len(self._by_name) != len(self.features):
            raise ValueError("duplicate feature names in schema")

    def __len__(self) -> int:
        return len(self.features)

    def __iter__(self):
        return iter(self.features)

    def __contains__(self, name: str) -> bool:
        return name in self._by_name

    def __getitem__(self, name: str) -> FeatureSpec:
        return self._by_name[name]

    def select(self, pattern: str) -> list[FeatureSpec]:
        """Glob-select features by name, preserving schema order."""
        out = [f for f in self.features if fnmatch.fnmatch(f.name, pattern)]
        if not out:
            raise KeyError(f"pattern {pattern!r} matched no schema features")
        return out

    def select_kind(self, kind: str) -> list[FeatureSpec]:
        return [f for f in self.features if f.kind == kind]

    def validate_batch(self, batch: dict) -> None:
        """Validate a raw columnar batch (dict of name -> np.ndarray)."""
        n_rows = None
        for f in self.features:
            if f.name not in batch:
                raise KeyError(f"batch missing column {f.name!r}")
            col = batch[f.name]
            if n_rows is None:
                n_rows = int(col.shape[0])
            expect = f.raw_shape(n_rows)
            if tuple(col.shape) != expect:
                raise ValueError(
                    f"column {f.name!r}: shape {tuple(col.shape)} != expected {expect}")
            if np.dtype(col.dtype) != f.raw_dtype():
                raise TypeError(
                    f"column {f.name!r}: dtype {col.dtype} != expected {f.raw_dtype()}")

    # -- canned schemas used throughout tests/benchmarks ---------------------

    @staticmethod
    def criteo_kaggle() -> "Schema":
        """Dataset-I: 13 dense f32 + 26 sparse 8-char hex + click label."""
        feats = [FeatureSpec("label", LabelKind, "float32")]
        feats += [FeatureSpec(f"dense_{i}", DenseKind, "float32") for i in range(13)]
        feats += [FeatureSpec(f"sparse_{i}", SparseKind, "uint8", hex_width=8)
                  for i in range(26)]
        return Schema(feats)

    @staticmethod
    def synthetic_wide() -> "Schema":
        """Dataset-II: 504 dense + 42 sparse hex columns."""
        feats = [FeatureSpec("label", LabelKind, "float32")]
        feats += [FeatureSpec(f"dense_{i}", DenseKind, "float32") for i in range(504)]
        feats += [FeatureSpec(f"sparse_{i}", SparseKind, "uint8", hex_width=8)
                  for i in range(42)]
        return Schema(feats)

    @staticmethod
    def lm_events(seq_len: int) -> "Schema":
        """Raw LM event-log schema: hashed id columns that the ETL pipeline maps
        into a bounded token id space (SigridHash/VocabMap path)."""
        return Schema([
            FeatureSpec("tokens_raw", TokenKind, "int32", seq_len=seq_len),
            FeatureSpec("label", LabelKind, "int32", seq_len=seq_len),
        ])
