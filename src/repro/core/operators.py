"""Software-defined ETL operators (paper Table 1) with fit/apply semantics.

Each operator provides three things:

1. ``numpy(x)``   — the pure-numpy oracle (the "CPU pandas baseline" semantics);
2. ``jnp_expr(x)``— a jax.numpy expression implementing the identical transform.
   The expression is written so it is valid BOTH under ``jax.jit`` and inside a
   Pallas kernel body; the compiler chains these expressions to code-generate a
   fused streaming stage (PipeRec's operator fusion, §3.1 step 2).
3. planner metadata — category (dense/sparse/both), statefulness, fusability,
   per-element cost estimates and state size (for the BRAM-vs-HBM analogue
   VMEM-vs-HBM placement decision).

Stateful operators (VocabGen/VocabMap) additionally expose a streaming ``fit``
protocol: ``init_state() -> update(state, batch, row_offset) -> finalize``.
The fit phase is the paper's keyed reduction that builds the vocabulary table;
the apply phase consumes the frozen table (point-in-time correctness: tables are
versioned and frozen before any batch that uses them is emitted).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# Sentinel used for "missing" in integer columns (dense columns use NaN).
INT_MISSING = np.int32(-(2 ** 31))

DENSE, SPARSE, BOTH = "dense", "sparse", "both"


def _mix32_np(x: np.ndarray) -> np.ndarray:
    """lowbias32 finalizer (32-bit splitmix analogue). uint32 -> uint32.

    TPU adaptation note: Pallas/TPU has no 64-bit integers, so SigridHash's
    64-bit hash is replaced by this 32-bit double-round multiplicative mix.
    """
    x = x.astype(np.uint32)
    x ^= x >> 16
    x = (x * np.uint32(0x7FEB352D)).astype(np.uint32)
    x ^= x >> 15
    x = (x * np.uint32(0x846CA68B)).astype(np.uint32)
    x ^= x >> 16
    return x


def _mix32_jnp(x):
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


@dataclasses.dataclass
class Operator:
    """Base class. Subclasses are cheap, declarative dataclasses."""

    # planner metadata (overridden per subclass)
    category: str = dataclasses.field(default=BOTH, init=False)
    stateful: bool = dataclasses.field(default=False, init=False)
    # fusable: elementwise + shape-preserving -> can join a fused stage
    fusable: bool = dataclasses.field(default=True, init=False)
    flops_per_elem: float = dataclasses.field(default=1.0, init=False)

    @property
    def name(self) -> str:
        return type(self).__name__

    # dtype of the output column block given input dtype
    def out_dtype(self, in_dtype: np.dtype) -> np.dtype:
        return np.dtype(in_dtype)

    # width multiplier (OneHot expands a column into K columns)
    def width_factor(self) -> int:
        return 1

    def numpy(self, x: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def jnp_expr(self, x):
        raise NotImplementedError

    def validate(self, in_dtype: np.dtype) -> None:
        """Type/shape constraint check (planner step 1)."""
        del in_dtype

    def state_bytes(self) -> int:
        return 0


# --------------------------------------------------------------------------
# Dense stateless operators
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Clamp(Operator):
    """Restrict values to [lo, hi]; paper default clips negatives to zero."""

    lo: float = 0.0
    hi: float = float("inf")

    def __post_init__(self):
        self.category = DENSE

    def numpy(self, x):
        return np.clip(x, self.lo, None if np.isinf(self.hi) else self.hi)

    def jnp_expr(self, x):
        y = jnp.maximum(x, jnp.asarray(self.lo, x.dtype))
        if not np.isinf(self.hi):
            y = jnp.minimum(y, jnp.asarray(self.hi, x.dtype))
        return y

    def validate(self, in_dtype):
        if not np.issubdtype(in_dtype, np.floating):
            raise TypeError(f"Clamp expects float input, got {in_dtype}")


@dataclasses.dataclass
class Logarithm(Operator):
    """log(x + 1): reduces skew / compresses heavy tails."""

    def __post_init__(self):
        self.category = DENSE
        self.flops_per_elem = 10.0  # transcendental

    def numpy(self, x):
        return np.log1p(x)

    def jnp_expr(self, x):
        return jnp.log1p(x)

    def validate(self, in_dtype):
        if not np.issubdtype(in_dtype, np.floating):
            raise TypeError(f"Logarithm expects float input, got {in_dtype}")


@dataclasses.dataclass
class FillMissing(Operator):
    """Impute NaNs (float) or INT_MISSING sentinels (int) with a default."""

    default: float = 0.0

    def numpy(self, x):
        if np.issubdtype(x.dtype, np.floating):
            return np.where(np.isnan(x), np.asarray(self.default, x.dtype), x)
        return np.where(x == INT_MISSING, np.asarray(int(self.default), x.dtype), x)

    def jnp_expr(self, x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.where(jnp.isnan(x), jnp.asarray(self.default, x.dtype), x)
        return jnp.where(x == INT_MISSING, jnp.asarray(int(self.default), x.dtype), x)


@dataclasses.dataclass
class Bucketize(Operator):
    """Discretize a scalar by bin boundaries: x=37, bins=[10,20,40] -> 3.

    Implemented as sum(x >= b_i) with compile-time constant boundaries, which
    fuses into the streaming stage (searchsorted would break elementwise fusion).
    """

    boundaries: Sequence[float] = ()

    def __post_init__(self):
        self.boundaries = tuple(float(b) for b in self.boundaries)
        if list(self.boundaries) != sorted(self.boundaries):
            raise ValueError("Bucketize boundaries must be sorted")
        self.flops_per_elem = float(len(self.boundaries))

    def out_dtype(self, in_dtype):
        return np.dtype(np.int32)

    def numpy(self, x):
        out = np.zeros(x.shape, np.int32)
        for b in self.boundaries:
            out += (x >= b).astype(np.int32)
        return out

    def jnp_expr(self, x):
        out = jnp.zeros(x.shape, jnp.int32)
        for b in self.boundaries:
            out = out + (x >= jnp.asarray(b, x.dtype)).astype(jnp.int32)
        return out


@dataclasses.dataclass
class OneHot(Operator):
    """Encode small-cardinality bins as K-wide indicators (expands width)."""

    depth: int = 2

    def __post_init__(self):
        self.fusable = False  # expands the column axis
        self.flops_per_elem = float(self.depth)

    def width_factor(self) -> int:
        return self.depth

    def out_dtype(self, in_dtype):
        return np.dtype(np.float32)

    def numpy(self, x):
        x = x.astype(np.int64)
        eye = np.eye(self.depth, dtype=np.float32)
        flat = np.clip(x, 0, self.depth - 1).reshape(-1)
        out = eye[flat].reshape(x.shape + (self.depth,))
        # out-of-range -> all-zero row (match jax.nn.one_hot semantics)
        mask = ((x >= 0) & (x < self.depth)).astype(np.float32)[..., None]
        out = out * mask
        return out.reshape(x.shape[:-1] + (x.shape[-1] * self.depth,))

    def jnp_expr(self, x):
        k = jnp.arange(self.depth, dtype=x.dtype)
        out = (x[..., None] == k).astype(jnp.float32)
        return out.reshape(x.shape[:-1] + (x.shape[-1] * self.depth,))

    def validate(self, in_dtype):
        if not np.issubdtype(in_dtype, np.integer):
            raise TypeError(f"OneHot expects integer input, got {in_dtype}")


# --------------------------------------------------------------------------
# Sparse stateless operators
# --------------------------------------------------------------------------


@dataclasses.dataclass
class Hex2Int(Operator):
    """Fixed-width ASCII-hex column -> int32 (two's complement on overflow).

    Input block has a trailing hex-digit axis: uint8[rows, cols, width].
    Missing values are encoded as all-0x00 strings and map to INT_MISSING.
    """

    width: int = 8

    def __post_init__(self):
        self.category = SPARSE
        self.flops_per_elem = 4.0 * self.width

    def out_dtype(self, in_dtype):
        return np.dtype(np.int32)

    @staticmethod
    def _digit_np(c: np.ndarray) -> np.ndarray:
        c = c.astype(np.int64)
        return np.where(c >= 97, c - 87, np.where(c >= 65, c - 55, c - 48))

    def numpy(self, x):
        assert x.shape[-1] == self.width and x.dtype == np.uint8
        missing = np.all(x == 0, axis=-1)
        dig = self._digit_np(np.where(x == 0, np.uint8(48), x))
        val = np.zeros(x.shape[:-1], np.uint64)
        for i in range(self.width):
            val = (val << np.uint64(4)) | dig[..., i].astype(np.uint64)
        out = (val & np.uint64(0xFFFFFFFF)).astype(np.uint32).view(np.int32)
        return np.where(missing, INT_MISSING, out)

    def jnp_expr(self, x):
        missing = jnp.all(x == 0, axis=-1)
        c = jnp.where(x == 0, jnp.uint8(48), x).astype(jnp.int32)
        dig = jnp.where(c >= 97, c - 87, jnp.where(c >= 65, c - 55, c - 48))
        dig = dig.astype(jnp.uint32)
        val = jnp.zeros(x.shape[:-1], jnp.uint32)
        for i in range(self.width):
            val = (val << jnp.uint32(4)) | dig[..., i]
        out = val.astype(jnp.int32)
        return jnp.where(missing, INT_MISSING, out)

    def validate(self, in_dtype):
        if np.dtype(in_dtype) != np.uint8:
            raise TypeError(f"Hex2Int expects uint8 ASCII input, got {in_dtype}")


@dataclasses.dataclass
class Modulus(Operator):
    """Positive modulus: (-7) mod 5 -> 3. Bounds ids to [0, m)."""

    m: int = 65536

    def __post_init__(self):
        self.category = SPARSE
        if self.m <= 0:
            raise ValueError("Modulus m must be positive")

    def numpy(self, x):
        out = np.mod(x.astype(np.int64), self.m).astype(np.int32)
        return out

    def jnp_expr(self, x):
        # int32-safe positive mod (jnp.mod on int32 already follows sign of
        # divisor, but INT_MISSING edge cases go through the same path).
        return jnp.mod(x, jnp.asarray(self.m, x.dtype)).astype(jnp.int32)

    def validate(self, in_dtype):
        if not np.issubdtype(in_dtype, np.integer):
            raise TypeError(f"Modulus expects integer input, got {in_dtype}")


@dataclasses.dataclass
class SigridHash(Operator):
    """Bound categorical ids: hash(id) % m (32-bit mix; see DESIGN.md note)."""

    m: int = 65536

    def __post_init__(self):
        self.category = SPARSE
        self.flops_per_elem = 12.0

    def numpy(self, x):
        h = _mix32_np(x.astype(np.int64).astype(np.uint32) if x.dtype != np.uint32 else x)
        return np.mod(h, np.uint32(self.m)).astype(np.int32)

    def jnp_expr(self, x):
        h = _mix32_jnp(x)
        return jnp.mod(h, jnp.uint32(self.m)).astype(jnp.int32)

    def validate(self, in_dtype):
        if not np.issubdtype(in_dtype, np.integer):
            raise TypeError(f"SigridHash expects integer input, got {in_dtype}")


@dataclasses.dataclass
class Cartesian(Operator):
    """Cross two categorical columns into a new bounded key.

    Binary operator: planner wires two parents; jnp_expr2/numpy2 take both.
    """

    m: int = 65536

    def __post_init__(self):
        self.category = SPARSE
        self.fusable = False  # binary: joins two streams (broadcast edge)
        self.flops_per_elem = 16.0

    GOLDEN = 0x9E3779B1

    def numpy2(self, a, b):
        ha = _mix32_np(a.astype(np.int64).astype(np.uint32))
        hb = _mix32_np(b.astype(np.int64).astype(np.uint32))
        h = _mix32_np(ha ^ (hb * np.uint32(self.GOLDEN)).astype(np.uint32))
        return np.mod(h, np.uint32(self.m)).astype(np.int32)

    def jnp_expr2(self, a, b):
        ha = _mix32_jnp(a)
        hb = _mix32_jnp(b)
        h = _mix32_jnp(ha ^ (hb * jnp.uint32(self.GOLDEN)))
        return jnp.mod(h, jnp.uint32(self.m)).astype(jnp.int32)

    def numpy(self, x):  # pragma: no cover - binary op uses numpy2
        raise TypeError("Cartesian is a binary operator; use numpy2(a, b)")

    def jnp_expr(self, x):  # pragma: no cover
        raise TypeError("Cartesian is a binary operator; use jnp_expr2(a, b)")


# --------------------------------------------------------------------------
# Stateful vocabulary operators
# --------------------------------------------------------------------------

_POS_INF = np.int64(2 ** 62)


@dataclasses.dataclass
class VocabGen(Operator):
    """Build a value -> first-appearance-rank table over a bounded key space.

    Fit phase (paper: keyed reduction across the stream):
      first_pos[v] = min global position at which value v occurs;
      counts[v]    = number of occurrences (paper §3.2.2: the table "enables
                     further operations like frequency-based filtering").
    Finalize: values with counts >= min_count ranked by first_pos;
    table[v] = rank, filtered/absent = -1 (they map to OOV at apply time).

    The table has ``capacity`` slots (the range of the upstream Modulus).  The
    planner places it in VMEM when small, HBM when large (BRAM/HBM analogue).
    """

    capacity: int = 65536
    min_count: int = 1  # frequency filter threshold (1 = keep everything)

    def __post_init__(self):
        self.category = SPARSE
        self.stateful = True
        self.fusable = False

    def state_bytes(self) -> int:
        return 16 * self.capacity  # int64 first_pos + int64 counts during fit

    def table_bytes(self) -> int:
        return 4 * self.capacity  # frozen int32 table

    # ---- streaming fit protocol (numpy oracle) ----
    def init_state(self):
        return (np.full(self.capacity, _POS_INF, np.int64),
                np.zeros(self.capacity, np.int64))

    def update(self, state, x: np.ndarray, row_offset: int):
        first_pos, counts = state
        flat = x.reshape(-1).astype(np.int64)
        if flat.size and (flat.min() < 0 or flat.max() >= self.capacity):
            raise ValueError("VocabGen input out of [0, capacity) — add Modulus first")
        pos = row_offset + np.arange(flat.size, dtype=np.int64)
        np.minimum.at(first_pos, flat, pos)
        np.add.at(counts, flat, 1)
        return first_pos, counts

    def finalize(self, state) -> np.ndarray:
        """(first_pos, counts) -> rank table (int32, -1 = absent/filtered)."""
        first_pos, counts = state
        present = first_pos < _POS_INF
        if self.min_count > 1:
            present = present & (counts >= self.min_count)
        keyed = np.where(present, first_pos, _POS_INF)
        order = np.argsort(keyed, kind="stable")
        rank = np.empty(self.capacity, np.int64)
        rank[order] = np.arange(self.capacity)
        table = np.where(present, rank, -1).astype(np.int32)
        return table

    @staticmethod
    def n_unique(table: np.ndarray) -> int:
        return int((table >= 0).sum())

    # (the compiled jnp/pallas fit path lives in kernels/ref.py +
    #  kernels/vocab.py: chunked build -> int32x2 merge -> finalize)

    def numpy(self, x):  # identity in the apply phase (table already built)
        return x

    def jnp_expr(self, x):
        return x


@dataclasses.dataclass
class VocabMap(Operator):
    """Map values through a frozen vocabulary table; unseen -> OOV index.

    The OOV index equals n_unique (one past the last assigned rank), so the
    embedding table downstream needs n_unique + 1 rows.
    """

    capacity: int = 65536

    def __post_init__(self):
        self.category = SPARSE
        self.stateful = True  # consumes state produced by VocabGen
        self.fusable = False  # gather from a shared table (broadcast fabric)
        self.flops_per_elem = 2.0

    def state_bytes(self) -> int:
        return 4 * self.capacity

    def numpy_apply(self, x: np.ndarray, table: np.ndarray) -> np.ndarray:
        n_unique = VocabGen.n_unique(table)
        hit = table[x.astype(np.int64)]
        return np.where(hit >= 0, hit, n_unique).astype(np.int32)

    def jnp_apply(self, x, table, n_unique):
        hit = table[x]
        return jnp.where(hit >= 0, hit, n_unique).astype(jnp.int32)

    def numpy(self, x):  # pragma: no cover
        raise TypeError("VocabMap requires a table; use numpy_apply(x, table)")

    def jnp_expr(self, x):  # pragma: no cover
        raise TypeError("VocabMap requires a table; use jnp_apply(x, table, n)")


ALL_OPERATORS = [Clamp, Logarithm, FillMissing, Bucketize, OneHot,
                 Hex2Int, Modulus, SigridHash, Cartesian, VocabGen, VocabMap]
