"""Relational optimizer over the ExecutionPlan (plan → optimize → lower).

The planner owns *what* each output computes (backward slices, legality);
this pass owns *how much of it is shared*.  It rewrites the plan between
planning and lowering with three relational rewrites, in order:

1. **Common-subexpression sharing (CSE).**  Stage subgraphs that are
   structurally identical — same kind, same (canonicalized) inputs, same
   operator parameters — are planned once.  Duplicate ``FusedStage`` chains
   (decode, bounding), ``CrossStage``/``OneHotStage`` nodes, and whole
   ``VocabFit``/``VocabLookupStage`` pairs (same value stream, capacity,
   min_count and placement ⇒ bit-identical fitted tables) collapse onto
   their first occurrence; every downstream reference is renamed onto the
   surviving buffer.  The rewrite cascades: once two prefixes merge, their
   structurally-equal consumers merge too.

2. **Generalized pushdown (dead-code elimination).**  Projection pushdown
   already narrows the *columns* a Source reads
   (``ExecutionPlan.referenced_columns``); this pass generalizes the same
   backward-reachability argument to *stages*: anything not in the
   transitive closure of the pack terminals and vocab-fit inputs — e.g.
   producers orphaned by CSE, or stages injected by plan surgery — is
   dropped before the legality checks ever see it, along with the source
   buffers/columns only dead stages read.  The fit closure
   (``fit_stage_ids``) is recomputed on the pruned stage list.

3. **Multi-output fused dataflows (grouping).**  Legal per-output
   ``DataflowProgram``s are greedily merged (pack order) into
   ``DataflowGroup``s while the *merged* slice still passes the same VMEM
   feasibility argument the planner applies per output: one row tile per
   touched buffer, each distinct table staged once, one packed tile per
   member output, double-buffered, within ``plan.dataflow_vmem_budget``.
   A group lowers to ONE row-tiled ``pallas_call`` emitting every member's
   packed tensor per tile (``kernels/dataflow.make_group_dataflow``), so
   stages shared across outputs execute exactly once per tile.  The
   fallback ladder is monotone: grouped → per-output fused → staged.

``optimize_plan`` never mutates its input; the rewritten plan carries an
``opt_info`` dict surfaced by ``ExecutionPlan.optimize_report()`` (and from
there by ``CompiledPipeline``/``EtlJob``) with CSE/pushdown counts and the
per-output grouping decision.
"""

from __future__ import annotations

import dataclasses

from repro.core.planner import (CrossStage, DataflowGroup, ExecutionPlan,
                                FusedStage, OneHotStage, Planner,
                                VocabLookupStage, build_plan_programs,
                                compiled_extra_bytes, packed_output_bytes,
                                stream_tile_bytes)

_INPUT_ATTRS = ("in_buf", "in_a", "in_b")


def _stage_inputs(stage) -> tuple:
    return tuple(b for b in (getattr(stage, a, None) for a in _INPUT_ATTRS)
                 if b)


def _op_signature(stage) -> tuple:
    """Parameter part of a stage's structural signature (operators are
    declarative dataclasses, so ``repr`` is a stable parameter fingerprint)."""
    if isinstance(stage, FusedStage):
        return ("fused", tuple(repr(op) for op in stage.ops),
                str(stage.in_dtype), str(stage.out_dtype), stage.in_hex_width)
    if isinstance(stage, CrossStage):
        return ("cross", repr(stage.op))
    if isinstance(stage, OneHotStage):
        return ("onehot", repr(stage.op))
    # unknown kinds never merge; identity keeps them unique
    return ("opaque", stage.stage_id)


def _rewrite_stage(stage, rename: dict, vocab_rename: dict):
    """Copy of ``stage`` with inputs (and vocab id) canonicalized."""
    changes = {a: rename[getattr(stage, a)] for a in _INPUT_ATTRS
               if getattr(stage, a, None) in rename}
    if isinstance(stage, VocabLookupStage) and stage.vocab_id in vocab_rename:
        changes["vocab_id"] = vocab_rename[stage.vocab_id]
    return dataclasses.replace(stage, **changes) if changes else stage


def _merge_sources(plan: ExecutionPlan, rename: dict) -> int:
    """Seed the rename map with duplicate raw source buffers.

    Each ``p.dense("dense_*")``-style call mints a fresh source node, so
    structurally equal prefixes built in separate expressions start from
    *distinct* buffers reading the *same* columns.  Two sources with the
    same column list and buffer spec deliver byte-identical streams; fold
    them so downstream stage CSE can fire."""
    seen: dict = {}
    merged = 0
    for b in list(plan.source_buffers):
        spec = plan.buffers[b]
        key = (tuple(plan.source_columns[b]), spec.width, str(spec.dtype),
               spec.hex_width)
        canon = seen.setdefault(key, b)
        if canon != b:
            rename[b] = canon
            plan.source_buffers.remove(b)
            del plan.source_columns[b]
            del plan.buffers[b]
            merged += 1
    return merged


def _cse(plan: ExecutionPlan) -> tuple[int, int, int]:
    """Merge structurally identical sources / stages / vocab fits."""
    fit_by_vid = {vf.vocab_id: vf for vf in plan.vocab_fits}
    rename: dict = {}        # dropped out_buf -> surviving out_buf
    vocab_rename: dict = {}  # dropped vocab_id -> surviving vocab_id
    merged_sources = _merge_sources(plan, rename)
    seen: dict = {}          # stage signature -> surviving stage
    fit_seen: dict = {}      # fit signature -> surviving vocab_id
    new_stages: list = []
    merged_stages = 0
    for s in plan.stages:
        ins = tuple(rename.get(b, b) for b in _stage_inputs(s))
        if isinstance(s, VocabLookupStage):
            vf = fit_by_vid[s.vocab_id]
            fit_key = (ins[0], vf.capacity, vf.min_count, vf.placement)
            canon = fit_seen.setdefault(fit_key, s.vocab_id)
            if canon != s.vocab_id:
                vocab_rename[s.vocab_id] = canon
            sig = ("lookup", ins, canon, s.capacity, s.placement)
        else:
            sig = (type(s).__name__, ins, _op_signature(s))
        survivor = seen.get(sig)
        if survivor is not None:
            rename[s.out_buf] = survivor.out_buf
            merged_stages += 1
            continue
        s2 = _rewrite_stage(s, rename, vocab_rename)
        seen[sig] = s2
        new_stages.append(s2)
    plan.stages = new_stages
    plan.pack = [dataclasses.replace(po, buffers=[rename.get(b, b)
                                                  for b in po.buffers])
                 for po in plan.pack]
    plan.vocab_fits = [
        dataclasses.replace(vf, in_buf=rename.get(vf.in_buf, vf.in_buf))
        for vf in plan.vocab_fits if vf.vocab_id not in vocab_rename]
    return merged_sources, merged_stages, len(vocab_rename)


def _prune_dead(plan: ExecutionPlan) -> tuple[int, int]:
    """Drop stages/sources outside the closure of outputs + vocab fits."""
    needed = {b for po in plan.pack for b in po.buffers}
    needed |= {vf.in_buf for vf in plan.vocab_fits}
    kept: list = []
    for s in reversed(plan.stages):
        if s.out_buf in needed:
            kept.append(s)
            needed.update(_stage_inputs(s))
    dead_stages = len(plan.stages) - len(kept)
    plan.stages = list(reversed(kept))
    live_sources = [b for b in plan.source_buffers if b in needed]
    dead_sources = len(plan.source_buffers) - len(live_sources)
    plan.source_buffers = live_sources
    plan.source_columns = {b: cols for b, cols in plan.source_columns.items()
                           if b in needed}
    plan.buffers = {name: spec for name, spec in plan.buffers.items()
                    if name in needed}
    plan.fit_stage_ids = Planner._fit_closure(plan.stages, plan.vocab_fits)
    return dead_stages, dead_sources


def _merged_working_set(plan: ExecutionPlan, members) -> int:
    """The per-output VMEM argument, applied to a merged slice: one tile per
    touched buffer, each distinct table once, one packed tile per output."""
    stage_ids = {sid for _, dp in members for sid in dp.stage_ids}
    stages = [s for s in plan.stages if s.stage_id in stage_ids]
    sources: list = []
    for _, dp in members:
        sources.extend(b for b in dp.source_buffers if b not in sources)
    tile_bytes = stream_tile_bytes(plan, stages, sources)
    table_bytes = sum(4 * s.capacity for s in stages
                      if isinstance(s, VocabLookupStage))
    out_bytes = sum(packed_output_bytes(plan, po) for po, _ in members)
    ws = 2 * (tile_bytes + out_bytes) + table_bytes
    if plan.compiled_mode:
        # merged slices are judged with the same compiled-lowering extra
        # (lane padding + gather scratch) the per-output legality used
        ws += compiled_extra_bytes(plan, stages, sources)
    return ws


def _make_group(plan: ExecutionPlan, members) -> DataflowGroup:
    stage_ids = {sid for _, dp in members for sid in dp.stage_ids}
    sources: list = []
    vocab_ids: list = []
    for _, dp in members:
        sources.extend(b for b in dp.source_buffers if b not in sources)
        vocab_ids.extend(v for v in dp.vocab_ids if v not in vocab_ids)
    return DataflowGroup(
        outputs=[po.name for po, _ in members],
        stage_ids=[s.stage_id for s in plan.stages
                   if s.stage_id in stage_ids],
        source_buffers=sources, vocab_ids=vocab_ids)


def _group_outputs(plan: ExecutionPlan) -> tuple[list, dict]:
    """Greedy pack-order binning of legal programs under the VMEM budget."""
    legal = {dp.output: dp for dp in plan.dataflows if dp.legal}
    groups: list = []
    grouping: dict = {}
    current: list = []  # [(PackOutput, DataflowProgram)]

    def flush():
        if len(current) >= 2:
            for po, _ in current:
                grouping[po.name] = f"grouped[{len(groups)}]"
            groups.append(_make_group(plan, current))
        elif current:
            grouping[current[0][0].name] = "per-output fused (no co-resident partner)"
        current.clear()

    for po in plan.pack:
        dp = legal.get(po.name)
        if dp is None:
            bad = next(d for d in plan.dataflows if d.output == po.name)
            grouping[po.name] = (f"staged ({bad.reason_kind or 'illegal'}: "
                                 f"{bad.reason})")
            continue
        if current and (_merged_working_set(plan, current + [(po, dp)])
                        > plan.dataflow_vmem_budget):
            flush()
        current.append((po, dp))
    flush()
    return groups, grouping


def optimize_plan(plan: ExecutionPlan) -> ExecutionPlan:
    """Rewrite ``plan`` (CSE → pushdown → regrouped fusion programs).

    Returns a new ``ExecutionPlan``; the input is left untouched.  The
    rewritten plan is observationally equivalent: every backend produces
    bit-identical packed outputs and (modulo deduplicated vocab ids)
    bit-identical pipeline state — ``tests/test_property.py`` pins this
    over randomly generated DAGs with shared prefixes.
    """
    plan = dataclasses.replace(
        plan,
        buffers=dict(plan.buffers),
        stages=list(plan.stages),
        fit_stage_ids=list(plan.fit_stage_ids),
        vocab_fits=list(plan.vocab_fits),
        pack=list(plan.pack),
        source_buffers=list(plan.source_buffers),
        source_columns={b: list(c) for b, c in plan.source_columns.items()},
        dataflows=[], fit_dataflows=[], groups=[], opt_info={})
    merged_sources, merged_stages, merged_vocabs = _cse(plan)
    dead_stages, dead_sources = _prune_dead(plan)
    # legality re-runs on the rewritten stage list (pushdown before legality)
    build_plan_programs(plan)
    groups, grouping = _group_outputs(plan)
    plan.groups = groups
    plan.opt_info = {
        "optimized": True,
        "cse": {"merged_sources": merged_sources,
                "merged_stages": merged_stages,
                "merged_vocabs": merged_vocabs},
        "pushdown": {"dead_stages": dead_stages,
                     "dead_sources": dead_sources},
        "groups": [list(g.outputs) for g in groups],
        "grouping": grouping,
    }
    return plan
