"""Planner: lowers the symbolic DAG into an ExecutionPlan (paper §3.1).

The five planning steps mirror the paper's planner-compiler:
  (1) freeze operator parameters + verify type/shape constraints
      (done eagerly at DAG construction; re-checked here),
  (2) fuse compatible stateless operators into streaming stages,
  (3) choose parallelism: N lanes x W vector width per stage,
  (4) place vocabulary state in VMEM (BRAM analogue) or HBM and size tables,
  (5) emit the runtime plan: stage list, buffer specs, batching policy.

A sixth, plan-level pass groups the per-output stage chains into
``DataflowProgram`` nodes (the paper's full streaming dataflow: operators
connected by on-chip FIFOs ending in the format-aware packer).  Each program
is the backward slice of stages feeding one ``PackOutput``; a legality check
decides whether the slice can lower to a *single* streaming kernel (all
tables VMEM-resident, per-tile working set within budget).  Illegal programs
fall back to stage-at-a-time lowering, so fusion is an optimization, never a
constraint on expressible plans.

The same pass covers the *fit* phase: each ``VocabFit`` gets a ``FitProgram``
— the backward stage slice from its input buffer — whose legality check
mirrors the apply one but accounts for the build-side accumulators (the
chunk first-occurrence and count tables live in VMEM across the whole grid,
so an HBM-placed capacity is illegal and falls back to the staged build).

The plan is backend-neutral; compiler.py lowers it to numpy / jnp / Pallas.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import operators as ops_lib
from repro.core.dag import Graph, Node, NodeType
from repro.kernels import lanes

VMEM_TABLE_BUDGET = 4 * 1024 * 1024  # tables at or under this live in VMEM
DATAFLOW_BLOCK_ROWS = 256  # row-tile granularity of the fused dataflow kernels

# fallback taxonomy for the legality passes (lowering_report.reason_kind):
#   "hex-terminal"    terminal is a raw hex block the packer cannot emit
#   "stage-kind"      a sliced stage has no tile codegen
#   "hbm-table"       a table / accumulator set is HBM-resident
#   "budget"          the per-tile working set exceeds dataflow_vmem_budget
#   "mosaic-illegal"  legal in interpret mode, but the compiled (Mosaic /
#                     Triton) lowering's extra VMEM — lane-padded blocks and
#                     banked-gather scratch — pushes the tile over budget
FALLBACK_HEX_TERMINAL = "hex-terminal"
FALLBACK_STAGE_KIND = "stage-kind"
FALLBACK_HBM_TABLE = "hbm-table"
FALLBACK_BUDGET = "budget"
FALLBACK_MOSAIC = "mosaic-illegal"


@dataclasses.dataclass
class BufferSpec:
    name: str
    width: int
    dtype: np.dtype
    hex_width: int = 0

    @property
    def bytes_per_row(self) -> int:
        per = self.dtype.itemsize * self.width
        return per * (self.hex_width or 1)


@dataclasses.dataclass
class FusedStage:
    """A chain of fusable stateless ops -> one streaming kernel (Stage-A)."""

    stage_id: str
    in_buf: str
    out_buf: str
    ops: list
    in_dtype: np.dtype
    out_dtype: np.dtype
    in_hex_width: int = 0
    # parallelism hints (step 3): N lanes x W vector width
    lanes: int = 8
    vector_width: int = 128

    @property
    def flops_per_elem(self) -> float:
        return sum(op.flops_per_elem for op in self.ops)


@dataclasses.dataclass
class CrossStage:
    stage_id: str
    op: ops_lib.Cartesian
    in_a: str
    in_b: str
    out_buf: str


@dataclasses.dataclass
class OneHotStage:
    stage_id: str
    op: ops_lib.OneHot
    in_buf: str
    out_buf: str


@dataclasses.dataclass
class VocabLookupStage:
    stage_id: str
    vocab_id: str
    in_buf: str
    out_buf: str
    capacity: int
    placement: str  # "vmem" | "hbm"


@dataclasses.dataclass
class VocabFit:
    vocab_id: str
    in_buf: str
    capacity: int
    placement: str
    min_count: int = 1


@dataclasses.dataclass
class PackOutput:
    """One tensor of the packed, training-ready batch."""

    name: str
    buffers: list[str]
    dtype: np.dtype
    pad_cols_to: int = 1  # pad concat width up to a multiple (128 for TPU)
    squeeze: bool = False  # emit (rows,) instead of (rows, 1)


@dataclasses.dataclass
class DataflowProgram:
    """Backward stage slice feeding one PackOutput (plan-level fusion node).

    When ``legal``, the compiler lowers the whole slice — elementwise chains,
    hex decode, vocab rank-lookup, one-hot expansion and the packing epilogue
    — to ONE row-tiled streaming kernel with no intermediate HBM tensors.
    When illegal (``reason`` says why), the output lowers stage-at-a-time.
    """

    output: str                    # PackOutput.name
    stage_ids: list[str]           # topo-ordered slice of plan.stages
    source_buffers: list[str]      # raw inputs the slice reads
    vocab_ids: list[str]           # tables consumed, in lookup-stage order
    legal: bool = True
    reason: str = ""
    reason_kind: str = ""          # one of the FALLBACK_* kinds, "" if legal

    @property
    def n_stages(self) -> int:
        return len(self.stage_ids)


@dataclasses.dataclass
class FitProgram:
    """Backward stage slice feeding one VocabFit (fit-phase fusion node).

    When ``legal``, the compiler lowers the whole fit chunk for this vocab —
    decode, elementwise bounding chains, cross joins — plus the chunk
    first-occurrence + count build to ONE row-tiled streaming kernel, with
    no intermediate HBM tensors between the upstream chains and the build.
    When illegal (``reason`` says why, e.g. an HBM-placed capacity whose
    accumulators cannot stay VMEM-resident), the vocab fits stage-at-a-time.
    """

    vocab_id: str
    in_buf: str                    # VocabFit.in_buf (the value stream)
    capacity: int
    stage_ids: list[str]           # topo-ordered slice of plan.stages
    source_buffers: list[str]      # raw inputs the slice reads
    legal: bool = True
    reason: str = ""
    reason_kind: str = ""          # one of the FALLBACK_* kinds, "" if legal

    @property
    def n_stages(self) -> int:
        return len(self.stage_ids)


@dataclasses.dataclass
class DataflowGroup:
    """Several PackOutputs lowered together as ONE streaming kernel.

    Emitted by the optimizer (core/optimizer.py): legal per-output
    ``DataflowProgram``s whose *merged* backward slice still fits one VMEM
    budget are grouped, so stages shared between outputs (decode, bounding
    chains) execute exactly once per tile instead of once per output.
    Groups always hold >= 2 outputs; ungrouped outputs keep their
    per-output program (the first rung of the fallback ladder:
    grouped -> per-output fused -> staged).
    """

    outputs: list[str]             # PackOutput names, pack order
    stage_ids: list[str]           # merged topo-ordered slice
    source_buffers: list[str]      # union of raw inputs, plan order
    vocab_ids: list[str]           # union of tables, lookup-stage order

    @property
    def n_outputs(self) -> int:
        return len(self.outputs)


@dataclasses.dataclass
class ExecutionPlan:
    buffers: dict[str, BufferSpec]
    stages: list  # topological order, apply phase
    fit_stage_ids: list[str]  # subset of stages also needed during fit
    vocab_fits: list[VocabFit]
    pack: list[PackOutput]
    source_buffers: list[str]
    dataflows: list[DataflowProgram] = dataclasses.field(default_factory=list)
    fit_dataflows: list[FitProgram] = dataclasses.field(default_factory=list)
    # source buffer -> raw column names it reads (planner column-set export;
    # consumed by repro.session to push projection into any Source)
    source_columns: dict = dataclasses.field(default_factory=dict)
    # multi-output fused groups (filled by the optimizer pass; empty when
    # the plan was not optimized or nothing grouped)
    groups: list[DataflowGroup] = dataclasses.field(default_factory=list)
    # fused-kernel per-tile working-set bound the legality passes used;
    # recorded here so the optimizer re-checks merged slices with the same
    # budget the planner checked per-output slices with
    dataflow_vmem_budget: int = 0
    # row-tile granularity of the fused dataflow kernels.  A tunable knob
    # (the controller's ``row_tile``): every legality pass and every kernel
    # builder reads it, so re-planning at a new tile re-judges legality —
    # bigger tiles amortize grid overhead but can push a slice over the
    # VMEM budget and back to the staged path
    row_tile: int = DATAFLOW_BLOCK_ROWS
    # whether the legality passes judged slices for the *compiled* Pallas
    # lowering (lane-padded blocks + banked-gather scratch on top of the
    # logical working set) rather than interpret mode; set through
    # build_plan_programs(compiled=...) so optimizer rebuilds re-judge
    # with the same mode the compiler resolved
    compiled_mode: bool = False
    # what the optimizer did to this plan (see ExecutionPlan.optimize_report)
    opt_info: dict = dataclasses.field(default_factory=dict)

    def stage_by_id(self, sid: str):
        for s in self.stages:
            if s.stage_id == sid:
                return s
        raise KeyError(sid)

    def _columns_for(self, bufs) -> list[str]:
        seen: set = set()
        out: list[str] = []
        for buf in self.source_buffers:
            if buf not in bufs:
                continue
            for c in self.source_columns.get(buf, ()):
                if c not in seen:
                    seen.add(c)
                    out.append(c)
        return out

    def referenced_columns(self) -> list[str]:
        """Raw column names the apply program reads, in schema order.

        A Source projected to exactly this set feeds the pipeline without
        materializing any unreferenced column (projection pushdown)."""
        return self._columns_for(set(self.source_buffers))

    def fit_buffers(self) -> set:
        """Every buffer the fit phase touches (the vocab-fit closure):
        VocabFit inputs plus all inputs of the fit stages.  Single source
        of truth — the compiler's fit gather and the fit-read projection
        both derive from this set."""
        needed = {vf.in_buf for vf in self.vocab_fits}
        fit_ids = set(self.fit_stage_ids)
        for s in self.stages:
            if s.stage_id in fit_ids:
                for attr in ("in_buf", "in_a", "in_b"):
                    b = getattr(s, attr, None)
                    if b:
                        needed.add(b)
        return needed

    def fit_source_buffers(self) -> list[str]:
        """Source buffers (in plan order) the fit phase reads."""
        needed = self.fit_buffers()
        return [b for b in self.source_buffers if b in needed]

    def fit_referenced_columns(self) -> list[str]:
        """Raw column names the *fit* phase reads (the vocab-fit closure) —
        a subset of ``referenced_columns``; dense-only inputs never load
        during fit when the fit Source is projected to this set."""
        return self._columns_for(self.fit_buffers())

    def _slice_to(self, needed: set) -> list[str]:
        """Topo-ordered stage ids in the backward slice of ``needed`` bufs."""
        needed = set(needed)
        ids: list[str] = []
        for s in reversed(self.stages):
            if getattr(s, "out_buf", None) in needed:
                ids.append(s.stage_id)
                for attr in ("in_buf", "in_a", "in_b"):
                    b = getattr(s, attr, None)
                    if b:
                        needed.add(b)
        return list(reversed(ids))

    def output_slice(self, po: PackOutput) -> list[str]:
        """Topo-ordered stage ids in the backward slice of one output."""
        return self._slice_to(set(po.buffers))

    def fit_slice(self, vf: VocabFit) -> list[str]:
        """Topo-ordered stage ids in the backward slice of one vocab fit."""
        return self._slice_to({vf.in_buf})

    def optimize_report(self) -> dict:
        """What the optimizer pass did to this plan.

        Keys: ``optimized`` (bool), ``cse`` (merged stage/vocab counts),
        ``pushdown`` (dead stages/sources dropped), ``groups`` (output-name
        lists, one per ``DataflowGroup``), ``grouping`` (per-output decision
        string).  An unoptimized plan reports ``optimized=False`` with zero
        counts.
        """
        base = {"optimized": False,
                "cse": {"merged_sources": 0, "merged_stages": 0,
                        "merged_vocabs": 0},
                "pushdown": {"dead_stages": 0, "dead_sources": 0},
                "groups": [], "grouping": {}}
        base.update(self.opt_info)
        return base

    # ---- Table-4 analogue: resource summary -----------------------------
    def resource_summary(self) -> dict:
        vmem = sum(4 * v.capacity for v in self.vocab_fits if v.placement == "vmem")
        hbm = sum(4 * v.capacity for v in self.vocab_fits if v.placement == "hbm")
        flops_row = 0.0
        bytes_row = 0
        for s in self.stages:
            if isinstance(s, FusedStage):
                w = self.buffers[s.in_buf].width
                flops_row += s.flops_per_elem * w
                bytes_row += (self.buffers[s.in_buf].bytes_per_row
                              + self.buffers[s.out_buf].bytes_per_row)
            elif isinstance(s, (CrossStage, OneHotStage, VocabLookupStage)):
                bytes_row += self.buffers[s.out_buf].bytes_per_row
        return {"vmem_table_bytes": vmem, "hbm_table_bytes": hbm,
                "flops_per_row": flops_row, "bytes_per_row": bytes_row,
                "n_stages": len(self.stages), "n_vocabs": len(self.vocab_fits)}


class Planner:
    def __init__(self, graph: Graph, *, vmem_budget: int = VMEM_TABLE_BUDGET,
                 lanes: int = 8, vector_width: int = 128,
                 dataflow_vmem_budget: Optional[int] = None,
                 row_tile: int = DATAFLOW_BLOCK_ROWS):
        self.graph = graph
        self.vmem_budget = vmem_budget
        self.lanes = lanes
        self.vector_width = vector_width
        self.row_tile = max(1, int(row_tile))
        # Fused-kernel per-tile working-set bound (stream tiles +
        # intermediates + tables + output tile, double-buffered).  It tracks
        # the user's declared VMEM headroom: tables (each <= vmem_budget by
        # placement) plus equal tile space — 8 MiB at the 4 MiB default,
        # ~half a TPU core's VMEM, leaving room for the compiler.
        self.dataflow_vmem_budget = (2 * vmem_budget
                                     if dataflow_vmem_budget is None
                                     else dataflow_vmem_budget)

    def plan(self, pack_outputs: list[tuple[str, list[Node], np.dtype, int, bool]]
             ) -> ExecutionPlan:
        sinks = [n for _, nodes, _, _, _ in pack_outputs for n in nodes]
        order = self.graph.topo_order(sinks)

        # consumers count: multi-consumer intermediates must materialize
        consumers: dict[str, int] = {}
        for n in order:
            for p in n.parents:
                consumers[p.id] = consumers.get(p.id, 0) + 1
        sink_ids = {n.id for n in sinks}

        buffers: dict[str, BufferSpec] = {}
        stages: list = []
        vocab_fits: list[VocabFit] = []
        source_buffers: list[str] = []
        source_columns: dict[str, list[str]] = {}
        # node.id -> (base buffer name, pending fusable ops, in_dtype, hex_w)
        chain: dict[str, tuple] = {}
        materialized: dict[str, str] = {}  # node.id -> buffer name
        stage_n = 0

        def new_stage_id():
            nonlocal stage_n
            stage_n += 1
            return f"s{stage_n}"

        def materialize(node: Node) -> str:
            """Ensure node's value exists as a named buffer; emit stages."""
            if node.id in materialized:
                return materialized[node.id]
            base, pending, in_dtype, hexw = chain[node.id]
            if not pending:
                materialized[node.id] = base
                return base
            out = node.id
            buffers[out] = BufferSpec(out, node.width, np.dtype(node.dtype))
            stages.append(FusedStage(
                stage_id=new_stage_id(), in_buf=base, out_buf=out,
                ops=list(pending), in_dtype=np.dtype(in_dtype),
                out_dtype=np.dtype(node.dtype), in_hex_width=hexw,
                lanes=self.lanes, vector_width=self.vector_width))
            materialized[node.id] = out
            return out

        for node in order:
            if node.kind == NodeType.SOURCE:
                buffers[node.id] = BufferSpec(node.id, node.width,
                                              np.dtype(node.dtype),
                                              hex_width=node.hex_width)
                source_buffers.append(node.id)
                source_columns[node.id] = [f.name for f in node.features]
                chain[node.id] = (node.id, [], node.dtype, node.hex_width)
                materialized[node.id] = node.id
            elif node.kind == NodeType.OP and node.op.fusable:
                (p,) = node.parents
                base, pending, in_dtype, hexw = chain[p.id]
                if consumers.get(p.id, 0) > 1 and pending:
                    # parent reused elsewhere: materialize it, start new chain
                    pbuf = materialize(p)
                    base, pending, in_dtype, hexw = pbuf, [], p.dtype, 0
                chain[node.id] = (base, pending + [node.op], in_dtype, hexw)
                if node.id in sink_ids or consumers.get(node.id, 0) != 1:
                    materialize(node)
            else:
                # fusion boundary: cross / onehot / vocab
                parent_bufs = [materialize(p) for p in node.parents]
                out = node.id
                sid = new_stage_id()
                if node.kind == NodeType.CROSS:
                    buffers[out] = BufferSpec(out, node.width, np.dtype(np.int32))
                    stages.append(CrossStage(sid, node.op, parent_bufs[0],
                                             parent_bufs[1], out))
                elif node.kind == NodeType.VOCAB:
                    cap = node.op.capacity
                    placement = ("vmem" if node.op.table_bytes() <= self.vmem_budget
                                 else "hbm")
                    vocab_id = f"vocab_{out}"
                    vocab_fits.append(VocabFit(vocab_id, parent_bufs[0], cap,
                                               placement,
                                               min_count=node.op.min_count))
                    buffers[out] = BufferSpec(out, node.width, np.dtype(np.int32))
                    stages.append(VocabLookupStage(sid, vocab_id, parent_bufs[0],
                                                   out, cap, placement))
                elif isinstance(node.op, ops_lib.OneHot):
                    buffers[out] = BufferSpec(out, node.width,
                                              np.dtype(node.op.out_dtype(None)))
                    stages.append(OneHotStage(sid, node.op, parent_bufs[0], out))
                else:
                    raise NotImplementedError(f"node {node}")
                chain[node.id] = (out, [], node.dtype, 0)
                materialized[node.id] = out

        # force-materialize every pack input
        pack = []
        for name, nodes, dtype, pad_to, squeeze in pack_outputs:
            bufs = [materialize(n) for n in nodes]
            pack.append(PackOutput(name, bufs, np.dtype(dtype), pad_to, squeeze))

        fit_stage_ids = self._fit_closure(stages, vocab_fits)
        plan = ExecutionPlan(buffers=buffers, stages=stages,
                             fit_stage_ids=fit_stage_ids,
                             vocab_fits=vocab_fits, pack=pack,
                             source_buffers=source_buffers,
                             source_columns=source_columns,
                             dataflow_vmem_budget=self.dataflow_vmem_budget,
                             row_tile=self.row_tile)
        build_plan_programs(plan)
        return plan

    @staticmethod
    def _fit_closure(stages, vocab_fits) -> list[str]:
        """Stage ids needed to produce every VocabFit input buffer."""
        needed: set[str] = {vf.in_buf for vf in vocab_fits}
        fit_ids: list[str] = []
        for s in reversed(stages):
            outs = {getattr(s, "out_buf", None)}
            if outs & needed:
                fit_ids.append(s.stage_id)
                for attr in ("in_buf", "in_a", "in_b"):
                    b = getattr(s, attr, None)
                    if b:
                        needed.add(b)
        return list(reversed(fit_ids))


# ---- step 6: plan-level fusion (one streaming program per output) ----------
#
# Module-level so the optimizer (core/optimizer.py) re-runs the same legality
# checks after rewriting the plan — per-output programs and merged groups are
# judged by identical VMEM arguments against ``plan.dataflow_vmem_budget``.

FUSABLE_STAGES = (FusedStage, CrossStage, OneHotStage, VocabLookupStage)
# stateless kinds the fit-side tile codegen knows; a lookup can never
# legally precede a fit (tables are unfitted then), so it is excluded
FIT_FUSABLE_STAGES = (FusedStage, CrossStage, OneHotStage)


def slice_sources(stages, terminals) -> list[str]:
    """Slice inputs (incl. terminals) that no slice stage produces."""
    produced = {s.out_buf for s in stages}
    consumed: list[str] = []
    for s in stages:
        for attr in ("in_buf", "in_a", "in_b"):
            b = getattr(s, attr, None)
            if b:
                consumed.append(b)
    sources: list[str] = []
    for b in consumed + list(terminals):
        if b not in produced and b not in sources:
            sources.append(b)
    return sources


def stream_tile_bytes(plan: ExecutionPlan, stages, sources,
                      *, block_rows: Optional[int] = None) -> int:
    """VMEM bytes of one row tile of every buffer a slice touches.

    ``block_rows`` defaults to ``plan.row_tile`` (as do the other sizing
    helpers below), so legality is always judged at the tile the kernels
    will actually run."""
    if block_rows is None:
        block_rows = plan.row_tile
    produced = {s.out_buf for s in stages}
    return sum(block_rows * plan.buffers[b].bytes_per_row
               for b in set(sources) | produced)


def packed_output_bytes(plan: ExecutionPlan, po: PackOutput,
                        *, block_rows: Optional[int] = None) -> int:
    """VMEM bytes of one packed output tile (width padded per the layout)."""
    if block_rows is None:
        block_rows = plan.row_tile
    out_w = sum(plan.buffers[b].width for b in po.buffers)
    padded_w = -(-out_w // po.pad_cols_to) * po.pad_cols_to
    return block_rows * padded_w * po.dtype.itemsize


def compiled_extra_bytes(plan: ExecutionPlan, stages, sources,
                         *, block_rows: Optional[int] = None) -> int:
    """Extra per-tile VMEM the *compiled* (Mosaic/Triton) lowering holds on
    top of the logical working set: lane-padding on every streamed buffer
    tile and table, plus the banked-gather scratch each in-kernel lookup
    materializes (``lanes.lane_gather`` broadcasts one bank per pass).
    Interpret mode streams the logical widths, so this is zero there.
    """
    if block_rows is None:
        block_rows = plan.row_tile
    produced = {s.out_buf for s in stages}
    pad = 0
    for b in set(sources) | produced:
        spec = plan.buffers[b]
        extra_w = lanes.lane_pad(spec.width) - spec.width
        pad += block_rows * spec.dtype.itemsize * extra_w * (spec.hex_width or 1)
    for s in stages:
        if isinstance(s, VocabLookupStage):
            pad += 4 * (lanes.lane_pad(s.capacity) - s.capacity)
            pad += lanes.gather_scratch_bytes(block_rows, s.capacity)
    return pad


def build_dataflow_program(plan: ExecutionPlan, po: PackOutput,
                           *, block_rows: Optional[int] = None,
                           compiled: Optional[bool] = None
                           ) -> DataflowProgram:
    """Backward-slice the stages feeding ``po`` and check legality.

    Legal programs lower to a single row-tiled streaming kernel, so the
    check is a VMEM feasibility argument: every buffer the slice touches
    contributes one (block_rows x width) tile, every vocab table is
    staged whole (it must be VMEM-placed), and the packed output tile
    rides along.  Anything over budget — or any HBM-resident table, or a
    stage kind the tile codegen does not know — falls back to the staged
    path for this output only, with ``reason_kind`` naming the fallback
    class (budget vs stage kind vs HBM table vs hex terminal).

    ``compiled`` (default: ``plan.compiled_mode``) judges the slice for
    the compiled Pallas lowering: the lane-padded / gather-scratch extra
    of ``compiled_extra_bytes`` is added, and a slice that fits the
    logical budget but not the compiled one falls back "mosaic-illegal".
    """
    if compiled is None:
        compiled = plan.compiled_mode
    if block_rows is None:
        block_rows = plan.row_tile
    stage_ids = plan.output_slice(po)
    stages = [plan.stage_by_id(sid) for sid in stage_ids]
    sources = slice_sources(stages, po.buffers)

    vocab_ids: list[str] = []
    for s in stages:
        if isinstance(s, VocabLookupStage) and s.vocab_id not in vocab_ids:
            vocab_ids.append(s.vocab_id)

    def illegal(reason: str, kind: str) -> DataflowProgram:
        return DataflowProgram(po.name, stage_ids, sources, vocab_ids,
                               legal=False, reason=reason, reason_kind=kind)

    for b in po.buffers:
        if plan.buffers[b].hex_width:
            return illegal(f"terminal {b} is a raw hex block; the packer "
                           "epilogue writes 2-D lane tiles only",
                           FALLBACK_HEX_TERMINAL)
    for s in stages:
        if not isinstance(s, FUSABLE_STAGES):
            return illegal(f"unsupported stage {type(s).__name__}",
                           FALLBACK_STAGE_KIND)
    for s in stages:
        if isinstance(s, VocabLookupStage) and s.placement != "vmem":
            return illegal(f"vocab {s.vocab_id} is {s.placement}-resident; "
                           "the streaming kernel stages tables in VMEM",
                           FALLBACK_HBM_TABLE)

    tile_bytes = stream_tile_bytes(plan, stages, sources,
                                   block_rows=block_rows)
    table_bytes = sum(4 * s.capacity for s in stages
                      if isinstance(s, VocabLookupStage))
    out_bytes = packed_output_bytes(plan, po, block_rows=block_rows)
    working_set = 2 * (tile_bytes + out_bytes) + table_bytes
    if working_set > plan.dataflow_vmem_budget:
        return illegal(f"per-tile working set {working_set} exceeds "
                       f"budget {plan.dataflow_vmem_budget}",
                       FALLBACK_BUDGET)
    if compiled:
        extra = compiled_extra_bytes(plan, stages, sources,
                                     block_rows=block_rows)
        if working_set + extra > plan.dataflow_vmem_budget:
            return illegal(
                f"compiled lowering needs {working_set + extra} bytes "
                f"({extra} lane-pad/gather scratch on top of {working_set}) "
                f"over budget {plan.dataflow_vmem_budget}", FALLBACK_MOSAIC)
    return DataflowProgram(po.name, stage_ids, sources, vocab_ids)


def build_fit_program(plan: ExecutionPlan, vf: VocabFit,
                      *, block_rows: Optional[int] = None,
                      compiled: Optional[bool] = None) -> FitProgram:
    """Backward-slice the stages feeding ``vf`` and check fit legality.

    Legal programs lower decode + bound + first-occurrence/count build to
    a single row-tiled kernel, so the VMEM argument adds the build-side
    accumulators: two int32[capacity] tables (chunk first-pos + counts)
    stay resident across the whole grid.  An HBM-placed vocab therefore
    falls back (its capacity is exactly what exceeded the table budget),
    as does any stage kind the fit tile codegen does not know or an
    over-budget working set — staged per vocab, never per pipeline;
    ``reason_kind`` names the fallback class either way.

    ``compiled`` (default: ``plan.compiled_mode``) additionally accounts
    the lane-padded accumulator blocks and streamed-tile padding of the
    compiled lowering; over the top is "mosaic-illegal".
    """
    if compiled is None:
        compiled = plan.compiled_mode
    if block_rows is None:
        block_rows = plan.row_tile
    stage_ids = plan.fit_slice(vf)
    stages = [plan.stage_by_id(sid) for sid in stage_ids]
    sources = slice_sources(stages, [vf.in_buf])

    def illegal(reason: str, kind: str) -> FitProgram:
        return FitProgram(vf.vocab_id, vf.in_buf, vf.capacity,
                          stage_ids, sources, legal=False, reason=reason,
                          reason_kind=kind)

    if vf.placement != "vmem":
        return illegal(
            f"vocab {vf.vocab_id} is {vf.placement}-resident; the fused "
            "fit kernel keeps first-pos/count accumulators in VMEM",
            FALLBACK_HBM_TABLE)
    for s in stages:
        if not isinstance(s, FIT_FUSABLE_STAGES):
            return illegal(f"unsupported fit stage {type(s).__name__}",
                           FALLBACK_STAGE_KIND)

    tile_bytes = stream_tile_bytes(plan, stages, sources,
                                   block_rows=block_rows)
    accum_bytes = 2 * 4 * vf.capacity  # first-pos + counts, int32 each
    working_set = 2 * tile_bytes + accum_bytes
    if working_set > plan.dataflow_vmem_budget:
        return illegal(f"per-tile working set {working_set} exceeds "
                       f"budget {plan.dataflow_vmem_budget}", FALLBACK_BUDGET)
    if compiled:
        extra = compiled_extra_bytes(plan, stages, sources,
                                     block_rows=block_rows)
        extra += 2 * 4 * (lanes.lane_pad(vf.capacity) - vf.capacity)
        if working_set + extra > plan.dataflow_vmem_budget:
            return illegal(
                f"compiled lowering needs {working_set + extra} bytes "
                f"({extra} lane-pad scratch on top of {working_set}) over "
                f"budget {plan.dataflow_vmem_budget}", FALLBACK_MOSAIC)
    return FitProgram(vf.vocab_id, vf.in_buf, vf.capacity,
                      stage_ids, sources)


def build_plan_programs(plan: ExecutionPlan,
                        compiled: Optional[bool] = None) -> None:
    """(Re)build the per-output and per-vocab fusion programs in place.

    Called by the planner after step 5, by the optimizer after every plan
    rewrite, and by the compiler once it has resolved its interpret flag —
    slices and legality always describe the current stages.  ``compiled``
    re-judges every slice for the compiled Pallas lowering and sticks
    (recorded on ``plan.compiled_mode``) so later rebuilds — the optimizer
    passes no flag — keep judging with the mode the compiler resolved.
    """
    if compiled is not None:
        plan.compiled_mode = bool(compiled)
    plan.dataflows = [build_dataflow_program(plan, po) for po in plan.pack]
    plan.fit_dataflows = [build_fit_program(plan, vf)
                          for vf in plan.vocab_fits]
