"""Batched serving driver: prefill + greedy/sampled decode loop."""

from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.api import Model


@dataclasses.dataclass
class ServeStats:
    prefill_s: float = 0.0
    decode_s: float = 0.0
    tokens: int = 0

    @property
    def tokens_per_s(self) -> float:
        return self.tokens / self.decode_s if self.decode_s else 0.0


def generate(model: Model, params, prompts: jax.Array, *, max_new: int,
             max_len: int, temperature: float = 0.0,
             rng: Optional[jax.Array] = None) -> tuple[np.ndarray, ServeStats]:
    """prompts: (B, S) int32. Greedy (temperature=0) or sampled decode."""
    B, S = prompts.shape
    stats = ServeStats()
    t0 = time.perf_counter()
    logits, cache = model.prefill(params, {"tokens": prompts}, max_len)
    logits = logits[:, -1, :]
    jax.block_until_ready(logits)
    stats.prefill_s = time.perf_counter() - t0

    step = jax.jit(model.decode_step, donate_argnums=1)
    out = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    t0 = time.perf_counter()
    for i in range(max_new):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = step(params, cache, tok, jnp.int32(S + i))
        lg = logits[:, -1, :]
        if temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(k, lg / temperature)[:, None]
        else:
            tok = jnp.argmax(lg, -1)[:, None]
        tok = tok.astype(jnp.int32)
    jax.block_until_ready(tok)
    stats.decode_s = time.perf_counter() - t0
    stats.tokens = B * max_new
    return np.stack(out, 1), stats