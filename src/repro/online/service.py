"""OnlineTrainer: the continuous-training control loop (ROADMAP item 2).

One thread interleaves four duties over an endless event-bus feed:

  train        pull the next delivered batch, run the (jitted) step
  refit/swap   every ``refit_every`` steps, fit ONLY the window of events
               that arrived since the last refit (``fit_incremental`` —
               rank-stable, so live embedding rows keep meaning) and swap
               the ``PipelineState`` atomically with a version bump; the
               compiled pipeline's per-version resolved/staged table caches
               refresh themselves and the lookahead ``EmbedCache`` is
               invalidated (+ re-admitted via ``refresh``) on the spot
  eval         every ``eval_every`` steps, call the user's ``eval_fn``
  checkpoint   every ``checkpoint_every`` steps, async-save + prune to
               ``keep_ckpts`` committed checkpoints (rollover)

Version correctness: the transform stage runs in the executor's thread
concurrently with swaps, so the compiled program snapshots its state once
per batch (``apply_versioned``) and every delivered batch is tagged with
the version that transformed it — post-swap batches are bit-identical to a
from-scratch compile at the same state version (pinned by
``tests/test_online.py``).

Freshness: an optional ``FreshnessShedder`` (``shed_max_staleness_s``)
drops the globally-oldest in-flight event when ingest outruns training;
staleness percentiles ride ``RuntimeStats.staleness_percentiles`` and the
Prometheus histogram.
"""

from __future__ import annotations

import queue as queue_lib
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.online.shed import FreshnessShedder
from repro.training import checkpoint as ckpt_lib


class _VersionedApply:
    """Transform-stage wrapper: stamp each packed batch with the vocabulary
    state version that transformed it (``apply_versioned`` snapshots the
    state exactly once per batch).  With ``trace`` set, keeps bounded
    ``(version, raw, packed)`` triples on the host for the bit-equality
    acceptance check — test/debug only, it syncs device futures."""

    KEY = "_pipe_version"

    def __init__(self, compiled, trace=None):
        self.compiled = compiled
        self.trace = trace

    def __call__(self, raw: dict) -> dict:
        out, version = self.compiled.apply_versioned(raw)
        out = dict(out)
        out[self.KEY] = version
        if self.trace is not None:
            self.trace.append(
                (version, {k: np.asarray(v) for k, v in raw.items()},
                 {k: np.asarray(v) for k, v in out.items()
                  if k != self.KEY}))
        return out


@dataclass
class OnlineConfig:
    """Knobs of the online control loop (CLI: ``launch/online.py``)."""

    refit_every: int = 0          # steps between incremental refits (0=off)
    refit_min_batches: int = 1    # skip a refit tick with a smaller window
    window_batches: int = 64      # refit window bound (newest kept)
    shed_max_staleness_s: float = 0.0   # global shed bound (0 = off)
    shed_poll_s: float = 0.02
    shed_slack: float = 0.7
    checkpoint_every: int = 0     # steps between checkpoints (0 = off)
    ckpt_dir: str = ""
    keep_ckpts: int = 3
    eval_every: int = 0           # steps between eval_fn calls (0 = off)
    log_every: int = 0            # steps between progress lines (0 = quiet)
    get_timeout_s: float = 0.25   # deliver poll (deadline/stop granularity)


@dataclass
class OnlineStats:
    steps: int = 0
    swaps: int = 0                # incremental vocab refits applied
    refit_batches: int = 0        # window events consumed by refits
    refit_skipped: int = 0        # ticks skipped (window under the minimum)
    checkpoints: int = 0
    evals: int = 0
    last_eval: Optional[dict] = None
    versions: list = field(default_factory=list)  # version after each swap

    def as_dict(self) -> dict:
        return {"steps": self.steps, "swaps": self.swaps,
                "refit_batches": self.refit_batches,
                "checkpoints": self.checkpoints, "evals": self.evals,
                "versions": list(self.versions)}


class OnlineTrainer:
    """Continuous online training over an event bus; see module docstring.

    Parameters
    ----------
    job : ``EtlJob`` whose source is (typically) ``Source.events(bus,
        topic)``.  The trainer builds and owns the job's executor.
    state : initial train state (any pytree; ``training.TrainState`` for
        real models).
    step_fn : ``step_fn(state, batch) -> (state, metrics)`` — e.g. the
        ``jit_train_step`` product.
    cfg : ``OnlineConfig``.
    bus, topic : when refits are enabled, the trainer taps its own bounded
        subscription of the same topic for the refit window (every
        subscriber sees every event), so refit ingest never steals batches
        from training.
    embed_cache, embed_tables : as in ``train_loop`` — a lookahead
        ``EmbedCache`` advanced before every step plus the current-tables
        accessor (default ``params["tables"]``).  With refits enabled the
        cache config must set ``refresh=True`` (swap invalidation is only
        bit-exact when referenced residents are re-admitted every batch).
    eval_fn : optional ``eval_fn(state) -> dict`` for the eval duty.
    trace_batches : keep the last N ``(version, raw, packed)`` triples on
        the host (acceptance/debug; syncs device futures).
    """

    def __init__(self, job, state, step_fn: Callable, cfg: OnlineConfig, *,
                 bus=None, topic: str = "events",
                 embed_cache=None, embed_tables: Optional[Callable] = None,
                 eval_fn: Optional[Callable] = None,
                 trace_batches: int = 0):
        self.job = job
        self.state = state
        self.step_fn = step_fn
        self.cfg = cfg
        self.eval_fn = eval_fn
        self.embed_cache = embed_cache
        if embed_cache is not None and embed_tables is None:
            embed_tables = lambda params: params["tables"]
        self.embed_tables = embed_tables
        self.stats = OnlineStats()
        self.executor = None
        self.shedder: Optional[FreshnessShedder] = None
        self.state_history: dict = {}   # version -> PipelineState snapshot
        self._stop = False
        self._ckpt = ckpt_lib.AsyncCheckpointer()
        self._refit_sub = None
        import collections
        self._window: collections.deque = collections.deque(
            maxlen=max(1, cfg.window_batches))
        self.trace = (collections.deque(maxlen=trace_batches)
                      if trace_batches else None)
        if cfg.refit_every > 0:
            compiled = job.compiled
            if not hasattr(compiled, "fit_incremental"):
                raise TypeError("incremental refit needs a CompiledPipeline")
            if bus is None:
                raise ValueError("refit_every > 0 needs the bus (the "
                                 "trainer taps its own refit subscription)")
            if embed_cache is not None and not embed_cache.cfg.refresh:
                raise ValueError(
                    "online refits with an EmbedCache require "
                    "EmbedCacheConfig(refresh=True): swap invalidation is "
                    "only bit-exact when referenced residents are "
                    "re-admitted every batch")
            self._refit_sub = bus.subscribe(topic)

    # ---- duties ----------------------------------------------------------

    def _drain_window(self) -> list:
        """Events arrived since the last refit, newest ``window_batches``
        kept (the bounded subscription + bounded deque cap both ends)."""
        while True:
            ev = self._refit_sub.get_nowait()
            if ev is None:
                break
            self._window.append(ev[0])
        window = list(self._window)
        self._window.clear()
        return window

    def _refit(self) -> bool:
        window = self._drain_window()
        if len(window) < max(1, self.cfg.refit_min_batches):
            self.stats.refit_skipped += 1
            return False
        compiled = self.job.compiled
        new_state = compiled.fit_incremental(iter(window))
        # the swap happened inside fit_incremental (single attribute store);
        # drop stale cached rows NOW so no post-swap batch trains on them
        if self.embed_cache is not None:
            self.embed_cache.invalidate()
        self.stats.swaps += 1
        self.stats.refit_batches += len(window)
        self.stats.versions.append(new_state.version)
        self.state_history[new_state.version] = new_state
        return True

    def _checkpoint(self) -> None:
        cfg = self.cfg
        self._ckpt.save_async(self.state, cfg.ckpt_dir, self.stats.steps)
        ckpt_lib.prune(cfg.ckpt_dir, keep=cfg.keep_ckpts)
        self.stats.checkpoints += 1

    # ---- main loop -------------------------------------------------------

    def run(self, *, max_steps: Optional[int] = None,
            deadline_s: Optional[float] = None):
        """Consume delivered batches until ``max_steps`` steps, the
        ``deadline_s`` wall-clock budget, ``stop()``, or the bus closing —
        whichever first.  Returns the final train state."""
        import jax

        cfg = self.cfg
        compiled = self.job.compiled
        if hasattr(compiled, "state"):
            self.state_history.setdefault(compiled.state.version,
                                          compiled.state)
        transform = (_VersionedApply(compiled, trace=self.trace)
                     if hasattr(compiled, "apply_versioned") else compiled)
        ex = self.executor = self.job.executor(transform=transform)
        if cfg.shed_max_staleness_s > 0:
            self.shedder = FreshnessShedder(
                ex, cfg.shed_max_staleness_s,
                slack=cfg.shed_slack, poll_s=cfg.shed_poll_s)
            self.shedder.start()
        ex.start()
        t_end = (time.monotonic() + deadline_s) if deadline_s else None
        try:
            while not self._stop:
                if max_steps is not None and self.stats.steps >= max_steps:
                    break
                if t_end is not None and time.monotonic() >= t_end:
                    break
                try:
                    payload = ex.get_batch(timeout=cfg.get_timeout_s)
                except queue_lib.Empty:
                    continue        # quiet feed: re-check deadline/stop
                except StopIteration:
                    break           # bus closed (EOS) or executor stopped
                batch = dict(payload)
                batch.pop(_VersionedApply.KEY, None)
                if self.embed_cache is not None:
                    batch = self.embed_cache.advance(
                        self.embed_tables(self.state.params), batch)
                self.state, metrics = self.step_fn(self.state, batch)
                if isinstance(metrics, dict) and "loss" in metrics:
                    jax.block_until_ready(metrics["loss"])
                self.stats.steps += 1
                s = self.stats.steps
                if cfg.refit_every and s % cfg.refit_every == 0:
                    self._refit()
                if (cfg.checkpoint_every and cfg.ckpt_dir
                        and s % cfg.checkpoint_every == 0):
                    self._checkpoint()
                if cfg.eval_every and self.eval_fn is not None \
                        and s % cfg.eval_every == 0:
                    self.stats.last_eval = self.eval_fn(self.state)
                    self.stats.evals += 1
                if cfg.log_every and s % cfg.log_every == 0:
                    pct = ex.stats.staleness_percentiles()
                    print(f"[online] step {s} swaps {self.stats.swaps} "
                          f"staleness p95 {pct['p95'] * 1e3:.1f}ms "
                          f"shed {self.shed_stats().dropped}")
        finally:
            if self.shedder is not None:
                self.shedder.stop()
            ex.stop()
            ex.join(timeout=5.0)
            self._ckpt.wait()
            if self.stats.checkpoints and cfg.ckpt_dir:
                # the last async save commits after the prune that followed
                # it; one final prune restores the exact keep-window size
                ckpt_lib.prune(cfg.ckpt_dir, keep=cfg.keep_ckpts)
            if getattr(self.job, "metrics_file", ""):
                from repro.etl_runtime import metrics as metrics_lib
                metrics_lib.write_metrics_file(
                    self.job.metrics_file,
                    metrics_lib.stats_to_prometheus(
                        ex.stats, labels=self.job.metrics_labels))
        return self.state

    def stop(self) -> None:
        self._stop = True
        if self.executor is not None:
            self.executor.stop()

    # ---- observability ---------------------------------------------------

    def shed_stats(self):
        from repro.online.shed import ShedStats
        return self.shedder.stats if self.shedder else ShedStats()

    def staleness_percentiles(self) -> dict:
        return (self.executor.stats.staleness_percentiles()
                if self.executor else {"p50": 0.0, "p95": 0.0, "p99": 0.0})
