"""In-process event bus with bounded topics and a TCP transport.

The online-training ingest surface: producers ``publish`` raw columnar
batches to named topics; consumers hold ``Subscription``s (every subscriber
of a topic sees every event published after it subscribed — the trainer and
the vocab-refit window can tap the same stream independently).  Each event
is stamped with an arrival timestamp at publish time; ``Source.events(bus)``
threads those stamps through the ``Source.arrival`` spec, so the runtime's
freshness machinery (delivered-staleness histogram, global shedding) sees
true event ages.

Topics are **bounded**: a subscription that falls behind sheds its oldest
queued events (drop-oldest, counted in ``Subscription.dropped``) instead of
blocking the producer — the bus-side half of the freshness contract; the
queue-side half is ``repro.online.shed``.

The TCP transport (``BusServer`` / ``BusClient``) moves events between
processes as length-prefixed frames::

    u32 topic_len | topic utf-8 | u64 payload_len | npz(columns)

so a remote log tailer can feed a trainer with nothing but a socket.  It is
a demo-grade transport (no auth, trusted peers only), loopback by default.
"""

from __future__ import annotations

import collections
import io
import socket
import struct
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np


class Subscription:
    """One consumer's bounded view of a topic (drop-oldest on overflow)."""

    def __init__(self, topic: str, capacity: int):
        self.topic = topic
        self.capacity = max(1, capacity)
        self.dropped = 0          # events shed because this consumer lagged
        self.delivered = 0
        self._dq: collections.deque = collections.deque()
        self._cv = threading.Condition()
        self._closed = False

    def _publish(self, batch: dict, arrival: float) -> int:
        with self._cv:
            if self._closed:
                return 0
            shed = 0
            while len(self._dq) >= self.capacity:
                self._dq.popleft()
                self.dropped += 1
                shed += 1
            self._dq.append((batch, arrival))
            self._cv.notify_all()
            return shed

    def get(self, timeout: Optional[float] = None,
            cancel: Optional[threading.Event] = None
            ) -> Optional[Tuple[dict, float]]:
        """Next ``(batch, arrival)``; ``None`` when the bus closed (and the
        queue drained), the ``cancel`` event is set, or ``timeout`` elapsed.
        """
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while not self._dq:
                if self._closed or (cancel is not None and cancel.is_set()):
                    return None
                if deadline is None:
                    self._cv.wait()
                else:
                    rem = deadline - time.monotonic()
                    if rem <= 0:
                        return None
                    self._cv.wait(rem)
            self.delivered += 1
            return self._dq.popleft()

    def get_nowait(self) -> Optional[Tuple[dict, float]]:
        with self._cv:
            if not self._dq:
                return None
            self.delivered += 1
            return self._dq.popleft()

    def wake(self) -> None:
        """Wake a blocked ``get`` so it can observe its cancel event."""
        with self._cv:
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        return self._closed

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    def __len__(self) -> int:
        with self._cv:
            return len(self._dq)

    def __iter__(self) -> Iterator[Tuple[dict, float]]:
        while True:
            ev = self.get()
            if ev is None:
                return
            yield ev


class _Topic:
    def __init__(self, name: str, capacity: int):
        self.name = name
        self.capacity = capacity
        self.published = 0
        self.unrouted = 0   # events published with no live subscriber
        self.subs: List[Subscription] = []


class EventBus:
    """Bounded in-process pub/sub; see module docstring.

    ``capacity`` bounds each *subscription* (per consumer, per topic).  The
    ``clock`` stamps arrivals and defaults to ``time.monotonic`` so ages are
    immune to wall-clock jumps; pass a fake for deterministic tests.
    """

    def __init__(self, capacity: int = 256, *,
                 clock: Callable[[], float] = time.monotonic):
        self.capacity = max(1, capacity)
        self.clock = clock
        self.closed = False
        self._lock = threading.Lock()
        self._topics: Dict[str, _Topic] = {}

    def _topic(self, name: str) -> _Topic:
        with self._lock:
            t = self._topics.get(name)
            if t is None:
                t = self._topics[name] = _Topic(name, self.capacity)
            return t

    def publish(self, topic: str, batch: dict, *,
                arrival: Optional[float] = None) -> int:
        """Fan ``batch`` out to every subscriber of ``topic``; returns the
        number of events shed from lagging subscriptions to make room.
        Publishing never blocks (bounded topics drop oldest instead)."""
        if self.closed:
            raise RuntimeError("publish on a closed EventBus")
        t = self._topic(topic)
        ts = self.clock() if arrival is None else arrival
        with self._lock:
            subs = list(t.subs)
            t.published += 1
            if not subs:
                t.unrouted += 1
        return sum(s._publish(batch, ts) for s in subs)

    def subscribe(self, topic: str,
                  capacity: Optional[int] = None) -> Subscription:
        """New bounded subscription seeing events published from now on."""
        t = self._topic(topic)
        sub = Subscription(topic, capacity or t.capacity)
        with self._lock:
            t.subs.append(sub)
        return sub

    def unsubscribe(self, sub: Subscription) -> None:
        t = self._topic(sub.topic)
        with self._lock:
            if sub in t.subs:
                t.subs.remove(sub)
        sub.close()

    def close(self) -> None:
        """End every subscription (consumers drain, then see the end)."""
        self.closed = True
        with self._lock:
            subs = [s for t in self._topics.values() for s in t.subs]
        for s in subs:
            s.close()

    def counts(self) -> dict:
        """Per-topic accounting: published / unrouted / per-sub drops."""
        with self._lock:
            return {name: {"published": t.published,
                           "unrouted": t.unrouted,
                           "subscribers": len(t.subs),
                           "dropped": sum(s.dropped for s in t.subs)}
                    for name, t in self._topics.items()}


# ---------------------------------------------------------------------------
# TCP transport: length-prefixed npz frames
# ---------------------------------------------------------------------------

def _encode_frame(topic: str, batch: dict) -> bytes:
    buf = io.BytesIO()
    np.savez(buf, **{k: np.asarray(v) for k, v in batch.items()})
    payload = buf.getvalue()
    tb = topic.encode("utf-8")
    return struct.pack(">I", len(tb)) + tb + \
        struct.pack(">Q", len(payload)) + payload


def _read_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    chunks = []
    while n:
        b = sock.recv(min(n, 1 << 16))
        if not b:
            return None
        chunks.append(b)
        n -= len(b)
    return b"".join(chunks)


def _decode_stream(sock: socket.socket) -> Iterator[Tuple[str, dict]]:
    while True:
        hdr = _read_exact(sock, 4)
        if hdr is None:
            return
        (tlen,) = struct.unpack(">I", hdr)
        topic = _read_exact(sock, tlen)
        plen_b = _read_exact(sock, 8)
        if topic is None or plen_b is None:
            return
        (plen,) = struct.unpack(">Q", plen_b)
        payload = _read_exact(sock, plen)
        if payload is None:
            return
        with np.load(io.BytesIO(payload)) as z:
            batch = {k: z[k] for k in z.files}
        yield topic.decode("utf-8"), batch


class BusServer:
    """Accept loop turning socket frames into ``bus.publish`` calls.

    Binds ``host:port`` (port 0 = ephemeral; read ``.address``) and runs a
    daemon accept thread plus one reader thread per connection.  Arrival is
    stamped at decode time on the receiving host — the bus clock, not the
    sender's.
    """

    def __init__(self, bus: EventBus, host: str = "127.0.0.1", port: int = 0):
        self.bus = bus
        self._sock = socket.create_server((host, port))
        self.address = self._sock.getsockname()
        self.frames = 0
        self._stop = threading.Event()
        self._conns: List[socket.socket] = []
        self._threads: List[threading.Thread] = []
        self._accept = threading.Thread(target=self._accept_loop,
                                        name="bus-accept", daemon=True)
        self._accept.start()

    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return
            self._conns.append(conn)
            t = threading.Thread(target=self._read_loop, args=(conn,),
                                 name="bus-conn", daemon=True)
            t.start()
            self._threads.append(t)

    def _read_loop(self, conn: socket.socket) -> None:
        try:
            for topic, batch in _decode_stream(conn):
                if self._stop.is_set():
                    return
                self.bus.publish(topic, batch)
                self.frames += 1
        except OSError:
            pass
        finally:
            conn.close()

    def close(self) -> None:
        self._stop.set()
        self._sock.close()
        for c in self._conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        for t in self._threads:
            t.join(timeout=2.0)


class BusClient:
    """Publisher end of the TCP transport (one connection, any topics)."""

    def __init__(self, address: Tuple[str, int]):
        self._sock = socket.create_connection(address)
        self._lock = threading.Lock()

    def publish(self, topic: str, batch: dict) -> None:
        frame = _encode_frame(topic, batch)
        with self._lock:
            self._sock.sendall(frame)

    def close(self) -> None:
        self._sock.close()


# ---------------------------------------------------------------------------
# producer helper (examples / benchmarks / tests)
# ---------------------------------------------------------------------------

def replay(bus: EventBus, topic: str, batches, *, rate_hz: float = 0.0,
           burst: int = 1, stop: Optional[threading.Event] = None) -> int:
    """Publish ``batches`` to ``topic``, optionally paced.

    ``rate_hz`` > 0 targets that many events/s on average; ``burst`` sends
    that many back-to-back per pacing interval (bursty arrivals are the
    interesting regime for shedding).  Blocking — wrap in a Thread for a
    background producer.  Returns the number of events published.
    """
    n = 0
    it = iter(batches)
    interval = (burst / rate_hz) if rate_hz > 0 else 0.0
    next_at = time.monotonic()
    while stop is None or not stop.is_set():
        sent = 0
        for b in it:
            bus.publish(topic, b)
            n += 1
            sent += 1
            if sent >= burst:
                break
        if sent < burst:
            return n  # source exhausted
        if interval:
            next_at += interval
            delay = next_at - time.monotonic()
            if delay > 0:
                if stop is not None:
                    if stop.wait(delay):
                        return n
                else:
                    time.sleep(delay)
    return n
