"""Continuous online-training service (paper §1: "continuous integration of
massive volumes of new user interaction data into training pipelines").

The batch reproduction runs finite epochs over static sources; this package
turns it into a long-running daemon:

- ``bus``     — in-process event bus (bounded topics, per-event arrival
  timestamps, optional TCP transport) feeding ``Source.events(bus)``.
- ``shed``    — freshness-aware global shedding: when ingest outruns
  training, drop the oldest-by-arrival event across ALL stage queues.
- ``service`` — ``OnlineTrainer``: interleaves the jitted train step with
  incremental vocab refresh (rank-stable ``fit_incremental`` + atomic state
  swap), periodic eval, and checkpoint rollover.
"""

from repro.online.bus import BusClient, BusServer, EventBus, replay
from repro.online.service import OnlineConfig, OnlineStats, OnlineTrainer
from repro.online.shed import FreshnessShedder, ShedStats

__all__ = ["BusClient", "BusServer", "EventBus", "replay",
           "OnlineConfig", "OnlineStats", "OnlineTrainer",
           "FreshnessShedder", "ShedStats"]
