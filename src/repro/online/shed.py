"""Freshness-aware global shedding: drop the oldest in-flight event.

The executor's per-queue ``put(drop_oldest=True)`` (``FreshnessPolicy.
online``) sheds only at one queue, only under local backpressure.  A
long-running online trainer needs the *global* policy the paper implies:
when ingest outruns training, the event that should die is the stalest one
**anywhere** in the pipeline — raw, packed, sorted, placed or ready — not
whichever happens to sit at a full queue.

``FreshnessShedder`` polls every stage queue of a ``StreamingExecutor``,
finds the envelope with the globally-oldest ``Source.arrival`` stamp, and
drops it while its age exceeds the shed threshold.  Drops are strictly
oldest-first among *visible* events (an envelope mid-stage — between a get
and the next put — is invisible for one poll; it is picked up as soon as it
lands in the next queue).  Each drop increments the owning
``CreditQueue.dropped`` counter (the PR-7 ``drop_oldest`` accounting) and
the executor's ``stats.dropped_stale``, so the Prometheus export needs no
new series for the drop path; staleness itself lands in the delivered-age
histogram.

Threshold: queued events are shed at ``max_staleness_s * slack``
(default slack 0.7) — the headroom covers the shed poll interval plus the
deliver→train latency of the final in-flight batch, so the *reported* p95
event-age-at-delivery stays under the configured bound rather than
oscillating just above it.

With a lookahead stage the ready queue carries planned batches whose cache
admits must all execute in delivery order (PR-7 host-mirror contract), so
the shedder excludes the ready queue in that configuration and sheds from
the placed queue upstream of planning.
"""

from __future__ import annotations

import collections
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional


def _arrival_key(item) -> Optional[float]:
    # non-envelopes (EOS markers) have no arrival and are invisible
    return getattr(item, "arrival", None)


@dataclass
class ShedStats:
    """Global-shed accounting, kept separately from per-queue counters."""

    dropped: int = 0
    max_age_at_drop_s: float = 0.0
    # arrival stamps of dropped events, in drop order (oldest-first check)
    dropped_arrivals: collections.deque = field(
        default_factory=lambda: collections.deque(maxlen=4096))

    def note(self, arrival: float, age_s: float) -> None:
        self.dropped += 1
        self.dropped_arrivals.append(arrival)
        self.max_age_at_drop_s = max(self.max_age_at_drop_s, age_s)


class FreshnessShedder:
    """Poll-driven global oldest-first shedder over an executor's queues.

    Parameters
    ----------
    executor : a started (or about-to-start) ``StreamingExecutor`` whose
        Source stamps arrivals (``Source.events`` / ``Source.arrival``).
    max_staleness_s : the freshness bound on event age at delivery.
    slack : fraction of the bound at which *queued* events are shed (see
        module docstring); 1.0 sheds exactly at the bound.
    poll_s : sweep interval — bounds how long a stale event can linger.
    clock : arrival-comparable clock (``time.monotonic`` matches the bus).
    """

    def __init__(self, executor, max_staleness_s: float, *,
                 slack: float = 0.7, poll_s: float = 0.02,
                 clock: Callable[[], float] = time.monotonic):
        if max_staleness_s <= 0:
            raise ValueError("max_staleness_s must be positive")
        self.max_staleness_s = float(max_staleness_s)
        self.threshold_s = self.max_staleness_s * float(slack)
        self.poll_s = poll_s
        self.clock = clock
        self.stats = ShedStats()
        self._rt_stats = executor.stats
        queues = executor.stage_queues()
        if getattr(executor, "lookahead", None) is not None:
            # planned batches must not be dropped (host-mirror coherence)
            queues.pop("ready", None)
        self._queues = list(queues.values())
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, name="etl-shed",
                                        daemon=True)
        self._started = False

    # ---- one sweep (also the unit-test surface) --------------------------

    def shed_once(self, now: Optional[float] = None) -> int:
        """Drop every visible event older than the threshold, strictly
        oldest-first across all queues; returns the number dropped."""
        now = self.clock() if now is None else now
        dropped = 0
        while True:
            oldest: Optional[float] = None
            owner = None
            for q in self._queues:
                k = q.peek_oldest_key(_arrival_key)
                if k is not None and (oldest is None or k < oldest):
                    oldest, owner = k, q
            if oldest is None or (now - oldest) <= self.threshold_s:
                return dropped
            item = owner.drop_by_key(_arrival_key, oldest)
            if item is None:
                continue  # raced downstream between peek and drop: rescan
            self.stats.note(oldest, now - oldest)
            self._rt_stats.dropped_stale += 1
            dropped += 1

    # ---- lifecycle -------------------------------------------------------

    def _run(self) -> None:
        while not self._stop.is_set():
            self.shed_once()
            self._stop.wait(self.poll_s)

    def start(self) -> "FreshnessShedder":
        if not self._started:
            self._thread.start()
            self._started = True
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._started:
            self._thread.join(timeout=2.0)

    def __enter__(self) -> "FreshnessShedder":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
