"""Quickstart: compose a pipeline, declare a Source, run it as an EtlJob.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Pipeline II on a Criteo-like schema with the Python
template interface, fits the vocabulary on a declarative Source, and
transforms a raw batch into training-ready tensors on all three backends
through the session facade.
"""

import numpy as np

from repro.core.operators import Clamp, FillMissing, Hex2Int, Logarithm, Modulus
from repro.core.dag import Vocab
from repro.core.pipeline import Pipeline
from repro.core.schema import Schema
from repro.data.source import Source
from repro.session import EtlJob


def main():
    schema = Schema.criteo_kaggle()

    # -- compose (paper §3.4: software-defined operators -> symbolic DAG) --
    p = Pipeline(schema, name="quickstart", batch_size=4096)
    dense = (p.dense("dense_*") | FillMissing(0.0) | Clamp(0.0)
             | Logarithm())
    sparse = (p.sparse("sparse_*") | Hex2Int(8) | Modulus(8192)
              | Vocab(8192))
    p.output("dense", [dense], dtype=np.float32, pad_cols_to=128)
    p.output("sparse", [sparse], dtype=np.int32, pad_cols_to=128)
    p.output("label", [p.label("label")], dtype=np.float32, squeeze=True)

    # -- declare ingest once; the job owns compile -> fit -> apply ---------
    raw = next(iter(Source.synth("I", rows=4096, batch_size=4096, seed=9)))
    for backend in ["numpy", "jnp", "pallas"]:
        job = EtlJob(p, backend=backend,
                     fit_source=Source.synth("I", rows=8192, batch_size=4096))
        job.fit()  # fit phase: learn vocab tables from the stream
        out = job.apply(raw)
        print(f"[{backend:6s}] " + "  ".join(
            f"{k}:{tuple(np.asarray(v).shape)}:{np.asarray(v).dtype}"
            for k, v in sorted(out.items())))
        print(f"          n_unique={list(job.state.n_unique.values())} "
              f"version={job.state.version} "
              f"resources={job.compiled.resource_summary()}")


if __name__ == "__main__":
    main()