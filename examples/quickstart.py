"""Quickstart: compose, compile, fit, and run a streaming ETL pipeline.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's Pipeline II on a Criteo-like schema with the Python
template interface, fits the vocabulary on a stream, and transforms a raw
batch into training-ready tensors on all three backends.
"""

import numpy as np

from repro.core.operators import Clamp, FillMissing, Hex2Int, Logarithm, Modulus
from repro.core.dag import Vocab
from repro.core.pipeline import Pipeline
from repro.core.schema import Schema
from repro.data import synth


def main():
    schema = Schema.criteo_kaggle()

    # -- compose (paper §3.4: software-defined operators -> symbolic DAG) --
    p = Pipeline(schema, name="quickstart", batch_size=4096)
    dense = (p.dense("dense_*") | FillMissing(0.0) | Clamp(0.0)
             | Logarithm())
    sparse = (p.sparse("sparse_*") | Hex2Int(8) | Modulus(8192)
              | Vocab(8192))
    p.output("dense", [dense], dtype=np.float32, pad_cols_to=128)
    p.output("sparse", [sparse], dtype=np.int32, pad_cols_to=128)
    p.output("label", [p.label("label")], dtype=np.float32, squeeze=True)

    for backend in ["numpy", "jnp", "pallas"]:
        compiled = p.compile(backend=backend)
        # fit phase: learn vocab tables from a stream (keyed reduction)
        compiled.fit(synth.dataset_batches("I", rows=8192, batch_size=4096))
        raw = next(synth.dataset_batches("I", rows=4096, batch_size=4096,
                                         seed=9))
        out = compiled(raw)
        print(f"[{backend:6s}] " + "  ".join(
            f"{k}:{tuple(np.asarray(v).shape)}:{np.asarray(v).dtype}"
            for k, v in sorted(out.items())))
        print(f"          n_unique={list(compiled.state.n_unique.values())} "
              f"version={compiled.state.version} "
              f"resources={compiled.resource_summary()}")


if __name__ == "__main__":
    main()