"""End-to-end driver (paper Fig 3): streaming ETL -> P2P handoff -> DLRM.

    PYTHONPATH=src python examples/train_dlrm_e2e.py [--steps 300]

Trains a ~100M-parameter DLRM for a few hundred steps on a continuously
generated Criteo-like event stream.  Ingest is declarative: a ``Source``
names the stream and an ``EtlJob`` owns compile -> fit -> the staged
prefetching executor (Pipeline II runs in the producer threads,
double-buffered against the trainer with credit backpressure); the script
reports trainer utilization with and without the overlap — the paper's
headline effect (Fig 14 / §4.4).
"""

import argparse
import time

import jax

from repro.configs.base import TrainConfig
from repro.core.pipeline import paper_pipeline
from repro.data.source import Source
from repro.models import dlrm
from repro.session import EtlJob
from repro.training.train_loop import (LoopConfig, TrainState, make_train_step,
                                       train_loop)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2048)
    ap.add_argument("--vocab", type=int, default=65536)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    # ~100M params: 26 tables x 64k x 64
    cfg = dlrm.DLRMConfig(vocab_size=args.vocab + 1, d_emb=64,
                          bot_mlp=(512, 256, 64),
                          top_mlp=(512, 256, 128, 1))
    print(f"[e2e] DLRM params: {cfg.param_count():,}")

    job = EtlJob(
        paper_pipeline("II", small_vocab=args.vocab, batch_size=args.batch),
        Source.synth("I", rows=args.steps * args.batch,
                     batch_size=args.batch, seed=11),
        backend="jnp",
        fit_source=Source.synth("I", rows=50_000, batch_size=10_000))
    t0 = time.perf_counter()
    job.fit()
    print(f"[e2e] vocab fit in {time.perf_counter()-t0:.2f}s; "
          f"n_unique={max(job.state.n_unique.values())}")

    tcfg = TrainConfig(lr=1e-3)
    state = TrainState.create(dlrm.init(jax.random.key(0), cfg), tcfg)
    # donate the packed batch too: it arrives pre-placed from the executor
    # and is consumed exactly once, so XLA may reuse its HBM in-step
    # (the CPU backend cannot alias donated inputs, so gate on device)
    donate = (0, 1) if jax.default_backend() != "cpu" else (0,)
    step = jax.jit(make_train_step(lambda p, b: dlrm.loss_fn(p, b, cfg),
                                   tcfg), donate_argnums=donate)

    t0 = time.perf_counter()
    with job.batches() as ex:
        state = train_loop(state, step, ex,
                           LoopConfig(total_steps=args.steps,
                                      ckpt_dir=args.ckpt_dir,
                                      ckpt_every=100 if args.ckpt_dir else 0,
                                      log_every=50))
    wall = time.perf_counter() - t0
    s = job.stats()
    rows = args.steps * args.batch
    train_s = wall - s.consumer_wait_s
    print(f"[e2e] {args.steps} steps / {rows:,} rows in {wall:.1f}s "
          f"({rows/wall:,.0f} rows/s)")
    print(f"[e2e] trainer utilization {s.trainer_utilization(train_s):.1%} "
          f"(trainer starved {s.consumer_wait_s:.2f}s; "
          f"ETL blocked on credits {s.producer_wait_s:.2f}s; "
          f"ETL hidden behind training {s.overlapped_etl_s:.2f}s)")
    for name, st in s.stage_breakdown().items():
        print(f"[e2e]   stage {name:9s} items={st['items']:<5d} "
              f"busy={st['busy_s']:.2f}s wait_in={st['wait_in_s']:.2f}s "
              f"wait_out={st['wait_out_s']:.2f}s occ={st['occupancy']:.1%}")


if __name__ == "__main__":
    main()
