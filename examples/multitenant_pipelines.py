"""Multi-tenant ETL: heterogeneous pipelines sharing one accelerator
(paper §3.4 Q1/Q2 + §4.8), including a hot swap (partial-reconfiguration
analogue).

    PYTHONPATH=src python examples/multitenant_pipelines.py
"""

import time

import numpy as np

from repro.core.pipeline import paper_pipeline
from repro.data import synth
from repro.etl_runtime.multitenant import PipelineManager


def main():
    mgr = PipelineManager()
    # heterogeneous tenants: stateless, small-vocab, large-vocab
    for name, which in [("stateless", "I"), ("vocab8k", "II"),
                        ("vocab512k", "III")]:
        pipe = paper_pipeline(which, small_vocab=8192, large_vocab=524288,
                              batch_size=4096).compile(backend="jnp")
        pipe.fit(synth.dataset_batches("I", rows=8192, batch_size=8192))
        mgr.add(name, pipe,
                lambda name=name: synth.dataset_batches(
                    "I", rows=4 * 4096, batch_size=4096,
                    seed=hash(name) % 100))

    res = mgr.run(n_batches=4)
    for name, r in res.items():
        print(f"[tenant {name:10s}] {r.rows_per_s:>10,.0f} rows/s "
              f"({r.batches} batches)")

    # hot swap: replace the stateless tenant with a new pipeline in O(1)
    new_pipe = paper_pipeline("I", modulus=1024,
                              batch_size=4096).compile(backend="jnp")
    t0 = time.perf_counter()
    mgr.swap("stateless", new_pipe,
             lambda: synth.dataset_batches("I", rows=2 * 4096,
                                           batch_size=4096, seed=5))
    print(f"[swap] reconfigured tenant in {1e3*(time.perf_counter()-t0):.2f}ms"
          " (compiled-executable swap; no recompilation)")
    res = mgr.run(n_batches=2)
    print(f"[tenant stateless] {res['stateless'].rows_per_s:,.0f} rows/s "
          "after swap")


if __name__ == "__main__":
    main()