"""Multi-tenant ETL: heterogeneous pipelines sharing one accelerator
(paper §3.4 Q1/Q2 + §4.8), including a hot swap (partial-reconfiguration
analogue).

    PYTHONPATH=src python examples/multitenant_pipelines.py
"""

import time

from repro.core.pipeline import paper_pipeline
from repro.data.source import Source
from repro.etl_runtime.multitenant import PipelineManager
from repro.session import EtlJob


def main():
    mgr = PipelineManager()
    # heterogeneous tenants: stateless, small-vocab, large-vocab — each a
    # declarative (pipeline, Source) pair the manager turns into an EtlJob
    fit_src = Source.synth("I", rows=8192, batch_size=8192)
    for name, which in [("stateless", "I"), ("vocab8k", "II"),
                        ("vocab512k", "III")]:
        job = EtlJob(paper_pipeline(which, small_vocab=8192,
                                    large_vocab=524288, batch_size=4096),
                     backend="jnp", fit_source=fit_src)
        job.fit()
        mgr.add(name, job.compiled,
                Source.synth("I", rows=4 * 4096, batch_size=4096,
                             seed=hash(name) % 100))

    res = mgr.run(n_batches=4)
    for name, r in res.items():
        print(f"[tenant {name:10s}] {r.rows_per_s:>10,.0f} rows/s "
              f"({r.batches} batches)")

    # hot swap: replace the stateless tenant with a new pipeline in O(1)
    new_pipe = paper_pipeline("I", modulus=1024,
                              batch_size=4096).compile(backend="jnp")
    t0 = time.perf_counter()
    mgr.swap("stateless", new_pipe,
             Source.synth("I", rows=2 * 4096, batch_size=4096, seed=5))
    print(f"[swap] reconfigured tenant in {1e3*(time.perf_counter()-t0):.2f}ms"
          " (compiled-executable swap; no recompilation)")
    res = mgr.run(n_batches=2)
    print(f"[tenant stateless] {res['stateless'].rows_per_s:,.0f} rows/s "
          "after swap")


if __name__ == "__main__":
    main()