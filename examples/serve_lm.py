"""Serve a small model with batched requests (prefill + decode loop).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2_370m
"""

import argparse

from repro.launch import serve as serve_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2_370m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    serve_launch.main(["--arch", args.arch, "--reduced",
                       "--batch", str(args.batch),
                       "--max-new", str(args.max_new)])


if __name__ == "__main__":
    main()