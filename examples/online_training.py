"""Continuous online training: event bus -> incremental vocab -> DLRM.

    PYTHONPATH=src python examples/online_training.py [--duration 20]

Where ``train_dlrm_e2e.py`` trains on a bounded stream and exits, this
example runs the *service* posture (ROADMAP item 2): a producer publishes
an endless Criteo-like event stream onto an in-process ``EventBus``, and
an ``OnlineTrainer`` consumes it forever —

- training on each delivered batch (staged ETL executor in between),
- refitting the vocabulary every ``--refit-every`` steps on just the
  window of new events (rank-stable: existing embedding rows keep their
  meaning; new values append), swapping the pipeline state atomically,
- shedding the globally-oldest in-flight events whenever ingest outruns
  training, so delivered event age stays under ``--shed-max-staleness``,
- rolling checkpoints (async save + prune) every ``--checkpoint-every``.

The producer runs at 2x the trainer's rate on purpose: watch the shed
counter climb while the staleness p95 holds under the bound.
"""

import argparse
import threading
import time

from repro.launch.online import build_parser, build_service
from repro.training import checkpoint as ckpt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=20.0)
    ap.add_argument("--refit-every", type=int, default=15)
    ap.add_argument("--shed-max-staleness", type=float, default=0.5)
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/online_ckpt")
    args = ap.parse_args()

    svc_args = build_parser().parse_args([
        "--duration", str(args.duration),
        "--batch", "256", "--vocab", "4096", "--d-emb", "32",
        "--rate", "25", "--rate-mult", "2.0",       # bursty: 2x trainer
        "--refit-every", str(args.refit_every),
        "--shed-max-staleness", str(args.shed_max_staleness),
        "--checkpoint-every", str(args.checkpoint_every),
        "--ckpt-dir", args.ckpt_dir,
        "--eval-every", "50", "--log-every", "25",
    ])
    trainer, bus, producer = build_service(svc_args)
    t = threading.Thread(target=producer, name="producer")
    t.start()
    t0 = time.perf_counter()
    trainer.run(deadline_s=args.duration + 5.0)
    t.join()
    wall = time.perf_counter() - t0

    st, pct = trainer.stats, trainer.staleness_percentiles()
    print(f"\n[online] {st.steps} steps in {wall:.1f}s "
          f"({st.steps/max(wall,1e-9):.1f} steps/s), "
          f"{st.swaps} vocab swaps (version "
          f"{st.versions[-1] if st.versions else 1}), "
          f"{st.evals} evals: {st.last_eval}")
    print(f"[online] staleness p50/p95/p99 = "
          f"{pct['p50']*1e3:.1f}/{pct['p95']*1e3:.1f}/{pct['p99']*1e3:.1f}ms"
          f" (bound {args.shed_max_staleness*1e3:.0f}ms), "
          f"shed {trainer.shed_stats().dropped} stale events")
    latest = ckpt_lib.latest_step(args.ckpt_dir)
    print(f"[online] newest committed checkpoint: step {latest} "
          f"(restart resumes from it)")


if __name__ == "__main__":
    main()
