"""Train an assigned-architecture LM on the streaming token pipeline.

    PYTHONPATH=src python examples/train_lm.py --arch llama3_2_3b --steps 100

Uses the reduced (CPU-runnable) config of any of the 10 assigned
architectures; the ETL layer is the SigridHash token pipeline, overlapped
with training exactly like the recommender path.
"""

import argparse

from repro.launch import train as train_launch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3_2_3b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    train_launch.main([
        "--arch", args.arch, "--reduced", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--ckpt-dir", args.ckpt_dir])


if __name__ == "__main__":
    main()